"""rwkv6-7b [ssm]: RWKV-6 "Finch" — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,  # heads = D/headdim
    d_ff=14336, vocab_size=65536,
    norm="layernorm", mlp="swiglu", rope_theta=0.0,
    rwkv_headdim=64, subquadratic=True,
    source="arXiv:2404.05892; hf",
)
