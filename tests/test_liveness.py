"""The liveness certifier (liveness.py, DESIGN.md §14): static proofs
that no legal execution order can *stall* the pool-arbitrated runtime,
refuted — when they fail — by stuck-state witnesses the directed
scheduler replays to real bounded-timeout stalls.

Mirrors the §13 suite's structure:

* **clean side** — every buildable corpus plan certifies live under its
  implied pool model, the ``BuildConfig.certify_liveness`` wiring works,
  the CLI corpus gate passes, and liveness-certified plans run to
  completion under every dispatch policy;
* **hazard side** — seeded hazards (a forged revocation-drain cycle,
  lease floors jointly infeasible under revocation, a disk-credit cycle,
  an oversized all-or-nothing admission batch) are always flagged, and
  every finding's witness replays to an actual stall through
  ``helpers.confirm_hazard`` → ``runtime.replay_stall``;
* **checked invariants** — the proof's runtime assumptions (A1 certified
  floor, A2 declared drain routes, A4 detector demotion) raise
  ``LivenessModelError`` when violated, never deadlock silently.
"""
import random as pyrandom
import types

import numpy as np
import pytest

from repro.core import BuildConfig, HostPool, MemgraphOOM, build_memgraph
from repro.core.analyze import recover_residencies
from repro.core.dispatch import POLICY_NAMES
from repro.core.liveness import (ATOMIC_ADMISSION_STALL, DISK_CREDIT_STALL,
                                 FLOORS_INFEASIBLE, LEASE_FLOOR_STALL,
                                 REVOCATION_CYCLE, LeaseSpec,
                                 LivenessModelError, PoolConfig,
                                 ProgressCertificationError, StreamConfig,
                                 certify_progress, default_pool_config)
from repro.core.memgraph import DepKind, MemGraph
from repro.core.runtime import (TurnipRuntime, eval_taskgraph, replay_stall,
                                run_in_order)

from helpers import (confirm_hazard, fig3_taskgraph, int_inputs,
                     random_taskgraph)

UNITS = dict(size_fn=lambda v: 1)


def _build(tg, **kw):
    kw.setdefault("capacity", 3)
    return build_memgraph(tg, BuildConfig(**kw, **UNITS))


# ------------------------------------------------------------ clean side
def test_built_plans_certify_live():
    """No plan the compiler emits may fail liveness certification under
    its implied pool model (a single lease owning the whole budget), and
    the certified worst-case lease occupancy must fit the guarantee."""
    n = 0
    for seed in range(10):
        tg = random_taskgraph(pyrandom.Random(1000 + seed))
        cap = 1 + seed % 3
        try:
            res = _build(tg, host_capacity=cap, rng_seed=seed)
        except MemgraphOOM:
            continue
        cert = certify_progress(res.memgraph, default_pool_config(cap))
        assert cert.ok, cert.summary()
        assert cert.guaranteed_units == cap
        assert cert.worst_lease_units <= cap
        assert "LIVE" in cert.summary()
        n += 1
    assert n >= 5


def test_build_certify_liveness_flag_attaches_certificate():
    tg = fig3_taskgraph()
    res = _build(tg, host_capacity=1, certify_liveness=True)
    assert res.liveness_certificate is not None
    assert res.liveness_certificate.ok
    # opt-in: without the flag the field stays None
    assert _build(tg, host_capacity=1).liveness_certificate is None


def test_cli_corpus_gate():
    """The CI gate: the seeded example-plan corpus certifies live."""
    from repro.core.liveness import main
    assert main(["--seeds", "8"]) == 0


def test_certified_plan_completes_under_all_dispatch_policies():
    """The acceptance criterion: a liveness-certified plan charging a
    real arbitrated lease runs to completion (oracle-exact) under every
    dispatch policy, with the certified floor stamped on the lease
    (assumption A1) and never tripped."""
    tg = fig3_taskgraph()
    inputs = int_inputs(tg)
    ref = eval_taskgraph(tg, inputs)
    for policy in POLICY_NAMES:
        pool = HostPool(1 << 20)
        lease = pool.lease("rt", min_bytes=2)
        res = _build(tg, host_lease=lease, certify_liveness=True)
        cert = res.liveness_certificate
        assert cert is not None and cert.ok, cert.summary()
        rt = TurnipRuntime(tg, res, mode="nondet", policy=policy, seed=7,
                           host_lease=lease)
        assert lease.certified_floor == cert.guaranteed_units
        out = rt.run(inputs).outputs
        for k in ref:
            np.testing.assert_array_equal(out[k], ref[k])
        assert lease.used == 0      # drained on completion
        lease.close()


def test_empty_graph_structural_certification():
    """A pool configuration alone (no plan) gets the structural passes:
    feasible floors and acyclic drains certify live."""
    cfg = PoolConfig(capacity=8, leases=(
        LeaseSpec("kv", min_bytes=2, discipline="reserving"),
        LeaseSpec("prefetch", discipline="reserving")))
    cert = certify_progress(MemGraph(), cfg)
    assert cert.ok, cert.summary()


# ----------------------------------------------------------- hazard side
def test_infeasible_floors_flagged_structurally():
    cfg = PoolConfig(capacity=4, leases=(
        LeaseSpec("a", min_bytes=3), LeaseSpec("b", min_bytes=2)))
    cert = certify_progress(MemGraph(), cfg)
    assert not cert.ok
    haz = [h for h in cert.hazards if h.kind == FLOORS_INFEASIBLE]
    assert haz and not haz[0].confirmable


def test_forged_revocation_cycle_flagged_and_stalls():
    """Seeded hazard 1: two leases whose revocation drains each charge
    the other. The certifier must flag the cycle and the directed
    scheduler must wedge all drains against a real HostPool."""
    cfg = PoolConfig(capacity=6, leases=(
        LeaseSpec("a", min_bytes=1, discipline="reserving",
                  drains_via=("b",)),
        LeaseSpec("b", min_bytes=1, discipline="reserving",
                  drains_via=("a",))))
    cert = certify_progress(MemGraph(), cfg)
    assert not cert.ok
    haz = [h for h in cert.hazards if h.kind == REVOCATION_CYCLE]
    assert haz, cert.summary()
    assert haz[0].confirmable and haz[0].witness_kind == "stall"
    how = confirm_hazard(None, None, haz[0], cert=cert)
    assert "stalled" in how
    assert "drains" in how


def test_lease_floors_infeasible_under_revocation_stalls():
    """Seeded hazard 2: the plan's worst-case simultaneous host occupancy
    exceeds the floor a co-tenanted pool guarantees it. The certifier
    must emit a lease-floor-stall whose witness prefix, replayed against
    a real pool with the slack adversarially held, blocks for the full
    timeout."""
    tg = fig3_taskgraph()
    res = _build(tg, host_capacity=2)
    mg = res.memgraph
    base = certify_progress(mg, default_pool_config(2))
    assert base.ok
    worst = base.worst_lease_units
    assert worst >= 1, "spill plan has no host residencies — regressed"
    cfg = PoolConfig(capacity=worst + 1, leases=(
        LeaseSpec("plan", min_bytes=worst - 1),
        LeaseSpec("serve", discipline="reserving")), plan_lease="plan")
    cert = certify_progress(mg, cfg)
    assert not cert.ok
    haz = [h for h in cert.hazards if h.kind == LEASE_FLOOR_STALL]
    assert haz, cert.summary()
    h = haz[0]
    assert h.witness_kind == "stall" and h.lease == "plan"
    assert h.expect_units == worst and h.capacity == worst - 1
    assert len(h.witness) == len(mg) and 0 < h.prefix <= len(mg)
    how = confirm_hazard(tg, res, h, cert=cert)
    assert "stalled" in how


def test_disk_credit_cycle_flagged_and_stalls():
    """Seeded hazard 3: forge dependencies so a blob stays live across a
    later spill's admission (its drop downstream of the spill — the
    inverted image of the builder's drop→spill credit edges). Every
    order then stalls at the spill once the capacity is one unit short,
    and the replay must reproduce that against a bounded disk gate."""
    tg = fig3_taskgraph()
    res = _build(tg, host_capacity=1)
    mg = res.memgraph
    _, disk = recover_residencies(mg)
    assert len(disk) >= 2, "spill plan has no disk traffic — regressed"
    forged = None
    for r in disk:
        for s in disk:
            if r is s or mg.happens_before(s.admit, r.admit):
                continue
            if r.release is not None:
                if mg.happens_before(r.release, s.admit):
                    continue
                if not mg.happens_before(s.admit, r.release):
                    mg.add_dep(s.admit, r.release, DepKind.MEM)
            if not mg.happens_before(r.admit, s.admit):
                mg.add_dep(r.admit, s.admit, DepKind.MEM)
            forged = (r, s)
            break
        if forged:
            break
    assert forged is not None, "no forgeable disk residency pair"
    r, s = forged
    cert = certify_progress(mg, default_pool_config(1),
                            disk_capacity=r.units + s.units - 1)
    assert not cert.ok
    haz = [h for h in cert.hazards if h.kind == DISK_CREDIT_STALL]
    assert haz, cert.summary()
    h = haz[0]
    assert h.witness_kind == "stall" and h.tier == "disk"
    how = confirm_hazard(tg, res, h, cert=cert)
    assert "stalled" in how


def test_atomic_admission_batch_past_guarantee_stalls():
    """An all-or-nothing admission batch larger than the lease's
    guaranteed share refuses forever under full revocation."""
    cfg = PoolConfig(capacity=8, leases=(
        LeaseSpec("kv", min_bytes=2, discipline="reserving",
                  atomic_bytes=5),
        LeaseSpec("other", min_bytes=1)))
    cert = certify_progress(MemGraph(), cfg)
    assert not cert.ok
    haz = [h for h in cert.hazards if h.kind == ATOMIC_ADMISSION_STALL]
    assert haz, cert.summary()
    h = haz[0]
    assert h.lease == "kv" and h.expect_units == 5 and h.capacity == 2
    how = confirm_hazard(None, None, h, cert=cert)
    assert "stalled" in how


def test_progress_certification_error_carries_certificate():
    cfg = PoolConfig(capacity=6, leases=(
        LeaseSpec("a", discipline="reserving", drains_via=("b",)),
        LeaseSpec("b", discipline="reserving", drains_via=("a",))))
    cert = certify_progress(MemGraph(), cfg)
    assert not cert.ok
    with pytest.raises(ProgressCertificationError) as ei:
        raise ProgressCertificationError(cert)
    assert not ei.value.certificate.ok
    assert "hazard" in str(ei.value)


def test_replay_stall_rejects_unknown_kinds():
    from repro.core.analyze import PlanHazard
    h = PlanHazard("lease-floors-infeasible", (), "structural")
    with pytest.raises(AssertionError, match="no stall replay"):
        replay_stall(h, None)


def test_certified_clean_safety_witness_still_replays():
    """§13 and §14 coexist on one BuildResult: the safety certifier's
    occupancy witnesses keep confirming through the same helper after the
    stall branch landed (regression guard on confirm_hazard)."""
    from repro.core import certify
    tg = fig3_taskgraph()
    res = _build(tg, host_capacity=1)
    base = certify(res.memgraph)
    assert base.ok and base.worst_host_units > 0
    cert = certify(res.memgraph, host_capacity=base.worst_host_units - 1)
    hosts = [h for h in cert.hazards if h.kind == "host-budget"]
    assert hosts
    assert "occupancy" in confirm_hazard(tg, res, hosts[0])


# ----------------------------------------------------- checked invariants
def test_a1_certified_floor_violation_is_loud():
    """Assumption A1: an occupancy mirror past the certified floor is
    certifier unsoundness, not a quiet overage."""
    pool = HostPool(8)
    l = pool.lease("plan", min_bytes=2)
    l.certified_floor = 2
    l.account(2)                      # at the floor: fine
    with pytest.raises(LivenessModelError, match="assumption A1"):
        l.account(1)
    # uncertified leases keep the unconditional-mirror semantics
    m = pool.lease("other")
    m.account(5)
    assert m.used == 5


def test_a2_undeclared_drain_charge_is_loud():
    """Assumption A2: a revocation drain may charge itself and its
    declared drains_via targets; any other charge is a blocking edge
    outside the static model."""
    pool = HostPool(16)
    a = pool.lease("a", drains_via=("b",))
    b = pool.lease("b")
    c = pool.lease("c")
    with pool.draining(a):
        assert b.try_charge(1)        # declared route
        assert a.try_charge(1)        # draining into yourself is fine
        b.release(1)
        a.release(1)
        with pytest.raises(LivenessModelError, match="assumption A2"):
            c.try_charge(1)
    # outside the drain marker, the same charge is ordinary
    assert c.try_charge(1)
    c.release(1)


# -------------------------------------------------------- serving engine
@pytest.fixture(scope="module")
def lm():
    import jax

    from repro.configs import get_arch, reduced
    from repro.models import build_model
    cfg = reduced(get_arch("olmo-1b"))
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _pooled_engine(lm, pool):
    from repro.serve import Engine, ServeConfig
    model, params = lm
    cfg = ServeConfig(max_len=64, batch_buckets=(1,), block_size=8,
                      offload=True, hot_window=0, offload_fraction=1.0)
    return Engine(model, params, cfg, pool=pool)


def test_pooled_engine_statically_certified(lm):
    """A pooled engine certifies its lease population at init: acyclic
    drains and feasible floors ⇒ the no-progress detector is demoted to
    a certifier-soundness check (assumption A4)."""
    pool = HostPool(1 << 20)
    eng = _pooled_engine(lm, pool)
    assert eng._certified_live
    cert = eng._liveness_certificate
    assert cert is not None and cert.ok, cert.summary()
    model_cfg = eng.pool_model()
    names = {s.name for s in model_cfg.leases}
    assert {"kv", "prefetch"} <= names
    assert all(s.discipline == "reserving" for s in model_cfg.leases)
    assert all(s.drains_via == () for s in model_cfg.leases
               if s.name in ("kv", "prefetch"))


def test_engine_inherits_cotenant_hazards(lm):
    """Hostile co-tenants with cyclic drain declarations poison the
    pool's certificate: the engine must notice and keep the detector as
    a hard failure instead of claiming unreachability."""
    pool = HostPool(1 << 20)
    pool.lease("x", drains_via=("y",))
    pool.lease("y", drains_via=("x",))
    eng = _pooled_engine(lm, pool)
    assert not eng._certified_live
    assert any(h.kind == REVOCATION_CYCLE
               for h in eng._liveness_certificate.hazards)


def test_detector_demotion_asserts_unreachability(lm):
    """Assumption A4 end to end: when the no-progress detector fires on a
    certified configuration it raises LivenessModelError (certifier
    unsoundness); on an uncertified one it stays the operational
    deadlock report. Both dump the live waits-for graph."""
    pool = HostPool(1 << 20)
    eng = _pooled_engine(lm, pool)
    assert eng._certified_live
    # drive the engine to the detector's firing state directly: nothing
    # in flight, admissions queued, pool occupancy provably static
    idle = types.SimpleNamespace(pending=[])
    eng._d2h = eng._h2d = idle
    eng._queue = [0]
    eng._idle_pool_state = (pool.used_bytes, eng._kv_lease.grant)
    eng._idle_spins = 100
    with pytest.raises(LivenessModelError,
                       match="statically unreachable") as ei:
        eng._stall_wait()
    assert "waits-for graph" in str(ei.value)
    eng._certified_live = False
    eng._idle_spins = 100
    with pytest.raises(RuntimeError, match="shared-pool deadlock") as ei2:
        eng._stall_wait()
    assert "waits-for graph" in str(ei2.value)
