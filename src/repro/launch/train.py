"""End-to-end training driver (example application + launch entrypoint).

Runs a real training loop on the available devices (CPU smoke ⇒ reduced
configs; TPU pod ⇒ full configs with the production mesh): data pipeline →
pjit'd train step (remat + sharding rules) → checkpoint cadence → restart on
failure via the FT supervisor.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 50 --batch 8 --seq 128 [--lora] [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch, reduced
from ..data.pipeline import DataConfig, SyntheticLMStream
from ..ft.supervisor import Supervisor
from ..models import build_model
from ..models.lora import lora_init, make_lora_loss
from ..train.optim import AdamW
from ..train.step import init_train_state, make_train_step
from ..ckpt.store import latest_step, restore_checkpoint


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--lora", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--remat", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg, remat=args.remat)
    key = jax.random.PRNGKey(0)
    opt = AdamW(lr=args.lr)

    stream = SyntheticLMStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    if args.lora:
        base = model.init(key)
        adapters = lora_init(jax.random.PRNGKey(1), base)
        loss_fn = make_lora_loss(model, base)
        state = {"params": adapters, "opt": opt.init(adapters),
                 "step": jnp.zeros((), jnp.int32)}
        step_fn = jax.jit(make_train_step(model, opt,
                                          grad_accum=args.grad_accum,
                                          loss_fn=loss_fn))
    else:
        state = init_train_state(model, key, opt)
        step_fn = jax.jit(make_train_step(model, opt,
                                          grad_accum=args.grad_accum))

    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    def batch_fn(step: int) -> dict:
        b = stream.batch(step)
        extra = {}
        if cfg.family == "encdec":
            extra["encoder_embeds"] = np.zeros(
                (args.batch, args.seq, cfg.d_model), np.float32)
        if cfg.frontend == "vit":
            extra["vision_embeds"] = np.zeros(
                (args.batch, cfg.n_frontend_tokens, cfg.d_model), np.float32)
        return {**b, **extra}

    sup = Supervisor(ckpt_dir=args.ckpt_dir, save_every=args.save_every)

    t0 = time.time()
    losses = []

    def timed_step(state, batch):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        print(f"step {int(state['step'])}: loss {float(metrics['loss']):.4f} "
              f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        return state, metrics

    state, report = sup.run(state, timed_step, batch_fn, args.steps,
                            start_step=start)
    dt = time.time() - t0
    print(f"done: {report.steps_run} steps in {dt:.1f}s "
          f"({report.restarts} restarts); loss {losses[0]:.3f} → "
          f"{losses[-1]:.3f}")


if __name__ == "__main__":
    main()
