"""Sharding rules: params (TP over 'model' + FSDP over 'data', DP over
'pod'), batches, and decode caches — with divisibility-aware fallbacks so
every assigned architecture × shape lowers on the production meshes.

Strategy (baseline — the §Perf iterations move these around):

* 2-D params ``[in, out]``: contracting/input dim → 'data' (ZeRO-3 style
  shard, all-gathered per layer under scan), output dim → 'model' (Megatron
  TP columns); transposed for output projections.
* MoE expert tensors ``[E, in, out]``: experts → 'model' (expert parallel).
* Activations: only batch is constrained; GSPMD propagates the rest.
* Caches/states: batch → ('pod','data') when divisible; heads → 'model'
  when divisible, else the cache sequence dim → 'model' (decode softmax
  then reduces over a sharded axis — XLA inserts the psum).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_sharding", "batch_sharding", "cache_sharding",
           "axis_size", "scalar_sharding", "constrain"]


def constrain(x, *spec, require: str | None = None):
    """with_sharding_constraint that degrades gracefully: axes absent from
    the current mesh (or non-divisible dims) are dropped, and without an
    active mesh it is the identity — so model code can annotate activations
    unconditionally (smoke tests run un-meshed on one CPU device).

    ``require='model'``: if that axis cannot be placed on any dim, return x
    UNCONSTRAINED — a constraint whose interesting axis was dropped would
    otherwise pin the tensor to replication, which is far worse than letting
    GSPMD choose (learned the hard way: §Perf iteration B2a)."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except AttributeError:       # jax < 0.5: no abstract-mesh API → un-meshed
        return x
    if m is None or not getattr(m, "axis_names", ()):
        return x
    axes = set(m.axis_names)
    fixed = []
    placed: set[str] = set()
    for dim, sp in zip(x.shape, spec):
        cand: Any = sp
        if isinstance(sp, tuple):
            cand = tuple(a for a in sp if a in axes)
            cand = cand if cand else None
        elif sp is not None and sp not in axes:
            cand = None
        if cand is not None:
            n = axis_size(m, *(cand if isinstance(cand, tuple) else (cand,)))
            if n <= 0 or dim % n != 0:
                cand = None
        if cand is not None:
            for a in (cand if isinstance(cand, tuple) else (cand,)):
                placed.add(a)
        fixed.append(cand)
    if require is not None and require not in placed:
        return x
    fixed += [None] * (len(x.shape) - len(fixed))
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def axis_size(mesh: Mesh, *names: str) -> int:
    n = 1
    for nm in names:
        if nm in mesh.shape:
            n *= mesh.shape[nm]
    return n


def _div(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


# ---------------------------------------------------------------- params
# (name, ndim) -> spec template; leading stacked axes get None prepended.
_2D_IN_OUT = ("data", "model")      # [d_in, d_out]
_2D_OUT_IN = ("model", "data")      # [d_out(model-sharded contracting), d_in]

_PARAM_RULES: dict[str, dict[int, tuple]] = {
    # embeddings
    "embed": {2: ("model", "data")},          # [Vp, D] vocab→TP
    "unembed": {2: ("data", "model")},        # [D, Vp]
    # attention
    "wq": {2: _2D_IN_OUT}, "wk": {2: _2D_IN_OUT}, "wv": {2: _2D_IN_OUT},
    "wo": {2: _2D_OUT_IN},
    "bq": {1: ("model",)}, "bk": {1: ("model",)}, "bv": {1: ("model",)},
    # dense mlp
    "wi": {2: _2D_IN_OUT}, "wi_gate": {2: _2D_IN_OUT, 3: ("model", "data", None)},
    "wi_up": {2: _2D_IN_OUT, 3: ("model", "data", None)},
    "bi": {1: ("model",)}, "bo": {1: (None,)},
    # moe
    "router": {2: ("data", None)},
    # rwkv
    "wr": {2: _2D_IN_OUT}, "wg": {2: _2D_IN_OUT}, "cr": {2: _2D_IN_OUT},
    "ck": {2: _2D_IN_OUT}, "cv": {2: _2D_OUT_IN},
    # ssd
    "in_proj": {2: _2D_IN_OUT}, "out_proj": {2: _2D_OUT_IN},
    "conv_w": {2: (None, "model")}, "conv_b": {1: ("model",)},
    "norm_g": {1: ("model",)},
}
# 3D wo = moe experts' output projection [E, F, D]
_PARAM_RULES["wo"][3] = ("model", None, "data")
_PARAM_RULES["wk"][3] = ("model", "data", None)   # (unused; safety)


def _spec_for_param(name: str, shape: tuple[int, ...], mesh: Mesh,
                    stacked_axes: int) -> P:
    base_nd = len(shape) - stacked_axes
    rule = _PARAM_RULES.get(name, {}).get(base_nd)
    if rule is None:
        return P()  # replicate (norm gains, loras, biases, small tensors)
    # verify divisibility; drop axes that don't divide
    spec: list[Any] = [None] * stacked_axes
    for dim, ax in zip(shape[stacked_axes:], rule):
        if ax is None:
            spec.append(None)
        else:
            n = axis_size(mesh, *(ax if isinstance(ax, tuple) else (ax,)))
            spec.append(ax if _div(dim, n) else None)
    return P(*spec)


def param_sharding(param_shapes: Any, mesh: Mesh) -> Any:
    """Tree of NamedShardings for a params pytree (of ShapeDtypeStructs or
    arrays). Layer-stacked arrays are detected by their path containing
    'layers' / 'mamba' / 'enc_layers' / 'dec_layers' / 'shared_adapters'."""
    def one(path, leaf) -> NamedSharding:
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        leafname = names[-1].lstrip("_")
        stacked = 0
        joined = "/".join(names)
        if re.search(r"(^|/)(layers|enc_layers|dec_layers|mamba_tail)(/|$)",
                     joined):
            stacked = 1
        elif re.search(r"(^|/)mamba(/|$)", joined):
            stacked = 2     # [n_groups, group, ...]
        elif leafname == "shared_adapters":
            stacked = 1
        # norm gains inside layers: e.g. ln1_g  → replicated
        if re.match(r"ln\d?_?.*", leafname) or leafname.endswith("_g") \
                and leafname not in _PARAM_RULES:
            spec = P(*([None] * stacked))
        else:
            spec = _spec_for_param(leafname, leaf.shape, mesh, stacked)
        # multiply-invoked shared blocks (zamba): FSDP-sharding their params
        # re-all-gathers them at every unrolled call site — shard over
        # 'model' only (§Perf iteration B1)
        if "/shared/" in f"/{joined}/":
            spec = P(*[(None if ax == "data" else ax) for ax in
                       (tuple(spec) + (None,) * (leaf.ndim - len(spec)))])
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, param_shapes)


# ---------------------------------------------------------------- batches
def batch_sharding(batch_shapes: Any, mesh: Mesh) -> Any:
    """Shard dim 0 (batch) over ('pod','data') when divisible."""
    daxes = _data_axes(mesh)
    n = axis_size(mesh, *daxes)

    def one(leaf) -> NamedSharding:
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if _div(leaf.shape[0], n):
            return NamedSharding(mesh, P(daxes, *([None] * (leaf.ndim - 1))))
        # try 'data' alone
        if "data" in mesh.shape and _div(leaf.shape[0], mesh.shape["data"]):
            return NamedSharding(mesh, P("data", *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))
    return jax.tree.map(one, batch_shapes)


# ---------------------------------------------------------------- caches
# per-key (head dim, head-feature dim, seq dim) positions in the unstacked
# suffix starting at batch (pos 0); -1 = absent. Fallback order for the
# 'model' axis: heads -> head-feature (Dh) -> sequence. Sharding Dh keeps the
# per-token dynamic_update_slice local - a seq-sharded cache forces a full
# reshard per decode step (Perf iteration A1).
_CACHE_LAYOUT: dict[str, tuple[int, int, int]] = {
    "k": (2, 3, 1), "v": (2, 3, 1),       # [B, S, K, Dh]
    "wkv": (1, -1, -1),                    # [B, H, P, P]
    "ssm": (1, -1, -1),                    # [B, H, P, N]
    "conv": (-1, -1, -1),                  # [B, dconv-1, convdim]
    "ssm_tail": (1, -1, -1), "conv_tail": (-1, -1, -1),
    "tm_shift": (-1, -1, -1), "cm_shift": (-1, -1, -1),
    "enc_out": (-1, -1, 1),                # [B, S_enc, D]
    "k_scale": (2, -1, 1), "v_scale": (2, -1, 1),   # int8-KV scales [B,S,K]
}
_STACK_AXES = {"k": 1, "v": 1, "wkv": 1, "ssm": 2, "conv": 2,
               "ssm_tail": 1, "conv_tail": 1, "tm_shift": 1, "cm_shift": 1,
               "enc_out": 0, "k_scale": 1, "v_scale": 1}


def cache_sharding(cache_shapes: Any, mesh: Mesh) -> Any:
    """Decode caches: batch → ('pod','data') if divisible; heads → 'model'
    if divisible, else the cache sequence dim → 'model' (decode softmax then
    reduces over a sharded axis — XLA inserts the psum)."""
    daxes = _data_axes(mesh)
    nd = axis_size(mesh, *daxes)
    nm = axis_size(mesh, "model")

    def one(path, leaf) -> NamedSharding:
        key = getattr(path[-1], "key", str(path[-1]))
        shape = leaf.shape
        spec: list[Any] = [None] * len(shape)
        if key not in _CACHE_LAYOUT:
            return NamedSharding(mesh, P(*spec))
        stacked = _STACK_AXES[key]
        # zamba kv caches are stacked once even though ssm is stacked twice
        bdim = stacked
        if bdim >= len(shape):
            return NamedSharding(mesh, P(*spec))
        if _div(shape[bdim], nd):
            spec[bdim] = daxes
        elif "data" in mesh.shape and _div(shape[bdim], mesh.shape["data"]):
            spec[bdim] = "data"
        hd, fd, sd = _CACHE_LAYOUT[key]
        for cand in (hd, fd, sd):
            if cand < 0:
                continue
            dim = stacked + cand
            if dim < len(shape) and _div(shape[dim], nm):
                spec[dim] = "model"
                break
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def scalar_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
