"""Fleet chaos harness (DESIGN.md §16): kill a replica mid-decode and
prove nothing changed but the timing.

The claim under test is the TURNIP property lifted to the fleet: placement,
migration, and replica death change *where* and *when* a request's tokens
are produced, never *what* they are. Every chaos run asserts, against the
single-model unbatched oracle (``naive_generate`` with the same
``(seed, rid, position)`` schedule):

* every affected request resumes on a survivor **token-exact** — warm
  (KV shipped over the NIC, bit-exact restore) and cold (re-prefill of
  ``prompt + out``) alike;
* zero leaked threads — the killed replica's run loop joins its DMA
  streams on the way out, the router joins its worker;
* every surviving replica's arbitrated :class:`~repro.core.pool.HostPool`
  stays within capacity at peak and drains to zero after the burst.

Swept over all placement policies × seeded kill instants
(``fault_after_steps`` — deterministic: the replica dies exactly when its
decode-step counter crosses the seed). The slow hypothesis lane widens the
sweep, scaled by ``FUZZ_EXAMPLES``.
"""
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.launch.mesh import FleetTopology, make_fleet_topology
from repro.models import build_model
from repro.serve import (MigrationRefused, MigrationTicket,
                         PLACEMENT_POLICY_NAMES, Engine, ReplicaKilled,
                         Router, ServeConfig, decode_ticket, encode_ticket,
                         get_placement, naive_generate)

KEY = jax.random.PRNGKey(0)
MAX_LEN = 128
SEED = 7
# thread-name prefixes the fleet owns: anything with one of these alive
# after close() is a leak (jax's own pool threads are long-lived and ours
# must not hide among them)
FLEET_THREADS = ("router-", "nic", "serve-dma-")


@pytest.fixture(scope="module")
def lm():
    cfg = reduced(get_arch("olmo-1b"))
    model = build_model(cfg)
    return model, model.init(KEY)


def fleet_cfg(**kw):
    base = dict(max_len=MAX_LEN, batch_buckets=(1, 2), block_size=16,
                offload=True, hot_window=16, preempt_every=2,
                h2d_bw=4e9, d2h_bw=4e9, seed=SEED)
    base.update(kw)
    return ServeConfig(**base)


def make_prompts(model, n, seed=1, lo=17, hi=40):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(1, model.cfg.vocab_size,
                                       size=int(k))))
            for k in rng.integers(lo, hi, size=n)]


def oracle(lm, prompts, rids, *, max_new):
    model, params = lm
    return [naive_generate(model, params, p, max_new=max_new,
                           max_len=MAX_LEN, rid=r, seed=SEED)
            for p, r in zip(prompts, rids)]


def assert_no_fleet_threads():
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline:
        leaked = [t.name for t in threading.enumerate() if t.is_alive()
                  and any(t.name.startswith(p) for p in FLEET_THREADS)]
        if not leaked:
            return
        time.sleep(0.05)
    assert not leaked, f"fleet threads leaked past close(): {leaked}"


def run_chaos(lm, *, placement, kill_step, prompt_seed=1, n_replicas=3,
              n_prompts=9, max_new=12, kill_index=0):
    """One chaos case: N replicas, one hard-killed once its decode-step
    counter crosses ``kill_step``. Returns the router summary."""
    model, params = lm
    topo = FleetTopology(n_replicas=n_replicas, heartbeat_timeout_s=60.0,
                         host_bytes_per_replica=64 << 20)
    prompts = make_prompts(model, n_prompts, seed=prompt_seed)
    with Router(model, params, fleet_cfg(), topology=topo,
                placement=placement) as router:
        # arm the fault BEFORE any submit: the victim cannot execute a
        # decode step first, so the kill fires at exactly ``kill_step``
        # on every schedule (armed after, a loaded machine can let the
        # victim finish — or even drain — before the counter is live)
        router.replicas[kill_index].engine.fault_after_steps = kill_step
        rids = [router.submit(p, max_new=max_new) for p in prompts]
        router.wait(rids, timeout=300)
        outs = [router.result(r) for r in rids]
        summ = router.summary()
        assert summ["replicas_killed"] == 1
        assert not router.replicas[kill_index].alive
        assert summ["drain_time"] > 0
        for rep in router.replicas:
            if rep.pool is not None and not rep.closed:
                assert rep.pool.peak_bytes <= rep.pool.capacity
                # drain is eventual, not instant: wait() returns at the
                # last DONE, while an in-flight mirror for a finished
                # request releases its charge when its event lands
                deadline = time.monotonic() + 30
                while (not rep.pool.drained
                       and time.monotonic() < deadline):
                    time.sleep(0.005)
                assert rep.pool.drained, rep.pool.snapshot()
    assert outs == oracle(lm, prompts, rids, max_new=max_new)
    assert summ["completed"] == n_prompts
    assert_no_fleet_threads()
    return summ


# ------------------------------------------------------------ chaos sweep
@pytest.mark.parametrize("placement,kill_step",
                         [("least-loaded", 3),
                          ("join-shortest-kv", 6),
                          ("random", 9)])
def test_replica_kill_mid_decode_token_exact(lm, placement, kill_step):
    """The headline chaos case: 1 of 3 replicas hard-killed mid-decode
    (seeded kill instant), swept over every placement policy. All requests
    complete token-exact vs the oracle, no leaked threads, surviving pools
    bounded and drained."""
    summ = run_chaos(lm, placement=placement, kill_step=kill_step)
    # the kill really interrupted in-flight work: the drain shipped
    # something (warm migrations and/or cold re-prefills)
    assert summ["migrations"] + summ["reprefills"] > 0


def test_no_fault_fleet_matches_oracle(lm):
    """Control: the same burst with no kill — pure placement + batching
    across 3 replicas, still token-exact; nothing drained, nothing
    migrated."""
    model, params = lm
    topo = make_fleet_topology(3, heartbeat_timeout_s=60.0)
    prompts = make_prompts(model, 7, seed=2)
    with Router(model, params, fleet_cfg(), topology=topo,
                placement="least-loaded") as router:
        rids = [router.submit(p, max_new=10) for p in prompts]
        router.wait(rids, timeout=300)
        outs = [router.result(r) for r in rids]
        summ = router.summary()
    assert outs == oracle(lm, prompts, rids, max_new=10)
    assert summ["replicas_killed"] == 0
    assert summ["migrations"] == 0 and summ["reprefills"] == 0
    # per-replica TTFT accounting covered every replica that hosted work
    assert summ["ttft_p99"] and all(v > 0 for v in summ["ttft_p99"].values())
    assert_no_fleet_threads()


def test_paused_replica_detected_and_drained(lm):
    """The silent-wedge failure mode: a replica that stops beating without
    crashing (``pause()``) must be drained exactly like a crash — detected
    via missed heartbeats, hard-killed, its requests resumed token-exact
    elsewhere. The beat is backdated to make detection deterministic
    instead of sleeping out a real timeout."""
    model, params = lm
    topo = FleetTopology(n_replicas=2, heartbeat_timeout_s=60.0)
    prompts = make_prompts(model, 6, seed=3)
    with Router(model, params, fleet_cfg(), topology=topo,
                placement="least-loaded") as router:
        rids = [router.submit(p, max_new=10) for p in prompts]
        victim = router.replicas[0]
        # freeze the victim while it provably holds live work: pause()
        # wedges run() at its next iteration, so work observed live under
        # a paused loop can never complete (checking busy before pausing
        # would race the last decode step finishing in the gap)
        deadline = time.monotonic() + 120
        busy = False
        while not busy and time.monotonic() < deadline:
            victim.engine.pause()
            with victim.engine._lock:
                busy = bool(victim.engine._live)
            if not busy:
                victim.engine.resume()
                time.sleep(0.005)
        assert busy, "victim never picked up work"
        router.heartbeat.beat(victim.name,
                              now=time.monotonic() - 2 * 60.0 - 1)
        router.wait(rids, timeout=300)
        outs = [router.result(r) for r in rids]
        summ = router.summary()
        assert not victim.alive and victim.closed
    assert outs == oracle(lm, prompts, rids, max_new=10)
    assert summ["replicas_killed"] == 1
    assert_no_fleet_threads()


# --------------------------------------------------- warm migration, direct
def _capture_warm_ticket(engine, deadline_s=120.0):
    """Run ``engine`` on a thread and pause it the moment a swapped
    request's full block set is quiescent, then detach that request as a
    warm ticket. Deterministic capture: pausing freezes the scheduler so
    the observed SWAPPED state cannot be readmitted under us."""
    err = []

    def _run():
        try:
            engine.run()
        except ReplicaKilled:
            pass
        except BaseException as e:   # noqa: BLE001
            err.append(e)

    t = threading.Thread(target=_run)
    t.start()
    ticket = None
    deadline = time.monotonic() + deadline_s
    try:
        while ticket is None and time.monotonic() < deadline:
            engine.pause()
            ticket = engine.export_one_swapped()
            if ticket is None:
                engine.resume()
                time.sleep(0.002)
    finally:
        engine.resume()
    assert not err, err
    assert ticket is not None, "no swapped request became exportable"
    return ticket, t


def test_warm_ticket_ships_bit_exact_and_resumes(lm):
    """Engine-level warm path, deterministically: capture a swapped
    request off a busy single-slot engine, serialize → wire-decode →
    import on a second replica, and the migrated request (and everything
    that stayed behind) completes token-exact. The decoded payload is
    byte-identical to the exported one."""
    model, params = lm
    cfg = fleet_cfg(batch_buckets=(1,))
    a = Engine(model, params, cfg, name="src")
    b = Engine(model, params, cfg, name="dst")
    prompts = make_prompts(model, 3, seed=4)
    rids = [a.submit(p, max_new=10, rid=100 + i)
            for i, p in enumerate(prompts)]
    ticket, worker = _capture_warm_ticket(a)
    assert ticket.warm and ticket.rid in rids
    blob = encode_ticket(ticket)
    wire = decode_ticket(blob)
    assert wire.rid == ticket.rid and wire.out == ticket.out
    assert len(wire.blocks) == len(ticket.blocks)
    for got, want in zip(wire.blocks, ticket.blocks):
        assert set(got) == set(want)
        for k in want:
            assert got[k].tobytes() == np.ascontiguousarray(
                want[k]).tobytes()
    b.import_migration(wire)
    assert b.stats.migrations_in == 1 and a.stats.migrations_out == 1
    worker.join(timeout=300)
    assert not worker.is_alive()
    b.run()
    outs = {}
    for eng in (a, b):
        for rid, req in eng.reqs.items():
            if rid in rids:
                outs[rid] = list(req.out)
    want = oracle(lm, prompts, rids, max_new=10)
    assert [outs[r] for r in rids] == want
    a.close()
    b.close()


def test_import_refusal_is_all_or_nothing(lm):
    """A ticket the destination cannot validate or fund leaves *nothing*
    behind: no request record, no host bytes, no lease charge — the §12
    invariants hold as if the import never happened."""
    from repro.core.pool import HostPool
    model, params = lm
    cfg = fleet_cfg(batch_buckets=(1,))
    a = Engine(model, params, cfg, name="src")
    prompts = make_prompts(model, 3, seed=5)
    rids = [a.submit(p, max_new=10, rid=200 + i)
            for i, p in enumerate(prompts)]
    ticket, worker = _capture_warm_ticket(a)
    a.hard_kill()
    worker.join(timeout=300)

    # wrong block geometry → refused before any state lands
    b = Engine(model, params, fleet_cfg(block_size=32), name="dst-geom")
    with pytest.raises(MigrationRefused, match="block_size"):
        b.import_migration(ticket)
    assert ticket.rid not in b.reqs
    b.close()

    # a pool too small to fund the set → refused with every charge rolled
    # back and zero bytes resident
    pool = HostPool(1024)
    c = Engine(model, params, fleet_cfg(), pool=pool, name="dst-poor")
    with pytest.raises(MigrationRefused, match="cannot reserve"):
        c.import_migration(ticket)
    assert ticket.rid not in c.reqs
    assert pool.used_bytes == 0 and pool.drained
    assert c.host.peek_offload((ticket.rid, 0)) is None
    c.close()

    # cold tickets are never importable — the contract is resubmission
    cold = MigrationTicket(rid=1, prompt=[1, 2], out=[3], max_new=4,
                           pos=2, last=3, block_size=16)
    d = Engine(model, params, fleet_cfg(), name="dst-cold")
    with pytest.raises(MigrationRefused, match="cold"):
        d.import_migration(cold)
    d.close()
    a.close()


def test_rebalance_moves_a_swapped_request(lm):
    """Live (no-fault) migration: with one replica saturated and one idle,
    ``rebalance_once`` detaches a swapped request over the NIC and the
    burst still completes token-exact."""
    model, params = lm
    topo = FleetTopology(n_replicas=2, heartbeat_timeout_s=60.0)
    prompts = make_prompts(model, 6, seed=6)
    with Router(model, params, fleet_cfg(batch_buckets=(1,)),
                topology=topo, placement="least-loaded") as router:
        rids = [router.submit(p, max_new=12) for p in prompts]
        moved = False
        deadline = time.monotonic() + 120
        while not moved and time.monotonic() < deadline:
            moved = router.rebalance_once()
            if not moved:
                time.sleep(0.002)
            if all(router.done(r) for r in rids):
                break
        router.wait(rids, timeout=300)
        outs = [router.result(r) for r in rids]
        summ = router.summary()
    assert outs == oracle(lm, prompts, rids, max_new=12)
    if moved:     # a move is near-certain under (1,)-bucket saturation,
        #           but completion can win the race; exactness never waits
        assert summ["migrations"] + summ["reprefills"] >= 1
    assert_no_fleet_threads()


# ------------------------------------------------------------- unit pieces
def test_codec_rejects_corruption():
    t = MigrationTicket(
        rid=3, prompt=[1, 2, 3], out=[4], max_new=8, pos=4, last=4,
        block_size=4, blocks=[{"k": np.arange(8, dtype=np.float32)
                               .reshape(2, 4)}])
    blob = encode_ticket(t)
    assert decode_ticket(blob).blocks[0]["k"].dtype == np.float32
    with pytest.raises(ValueError, match="magic"):
        decode_ticket(b"XXXX" + blob[4:])
    with pytest.raises(ValueError, match="torn"):
        decode_ticket(blob[:-3])
    with pytest.raises(ValueError, match="trailing"):
        decode_ticket(blob + b"\x00")


def test_placement_policies():
    class _Eng:
        def __init__(self, n, kv):
            self._n, self._kv = n, kv

        def load(self):
            return self._n, self._kv

    class _Rep:
        def __init__(self, i, n, kv):
            self.index, self.engine = i, _Eng(n, kv)

    reps = [_Rep(0, 3, 10), _Rep(1, 1, 99), _Rep(2, 1, 5)]
    assert get_placement("least-loaded").pick(reps).index == 1  # tie → index
    assert get_placement("join-shortest-kv").pick(reps).index == 2
    rng_picks = {get_placement("random", seed=s).pick(reps).index
                 for s in range(16)}
    assert len(rng_picks) > 1                   # seeded but not degenerate
    assert set(PLACEMENT_POLICY_NAMES) == {"least-loaded",
                                           "join-shortest-kv", "random"}
    with pytest.raises(ValueError, match="unknown placement"):
        get_placement("nope")


def test_fleet_topology_validation():
    topo = make_fleet_topology(3, name_prefix="r")
    assert topo.replica_names == ("r-0", "r-1", "r-2")
    with pytest.raises(ValueError):
        FleetTopology(n_replicas=0)


# --------------------------------------------------------------- slow lane
@pytest.mark.slow
def test_fuzz_chaos_kill_instants(lm):
    """Hypothesis lane (nightly: ``-m slow``, scaled by ``FUZZ_EXAMPLES``):
    random placement policy × kill instant × burst shape, every run
    token-exact with no leaked threads."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    max_examples = max(2, int(os.environ.get("FUZZ_EXAMPLES", "25")) // 10)

    @settings(max_examples=max_examples, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.function_scoped_fixture])
    # kill_step stays below max_new: a victim holding a single request
    # completes it in exactly max_new decode steps, so a later instant
    # could let the run finish unkilled
    @given(placement=st.sampled_from(PLACEMENT_POLICY_NAMES),
           kill_step=st.integers(1, 9),
           prompt_seed=st.integers(0, 2**16),
           kill_index=st.integers(0, 2))
    def inner(placement, kill_step, prompt_seed, kill_index):
        run_chaos(lm, placement=placement, kill_step=kill_step,
                  prompt_seed=prompt_seed, n_prompts=7, max_new=10,
                  kill_index=kill_index)

    inner()
