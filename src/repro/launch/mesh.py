"""Production mesh builders + fleet topology.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import) —
the jax imports themselves are deferred into the mesh builders, so the
fleet-topology half of the module (consumed by serve/router.py) stays
importable even where the installed jax predates ``AxisType``.

Besides the single-host device meshes, this module describes the
*fleet*: an N-replica serving topology (one serving engine + host/disk
tier pair per replica, linked by a priced NIC) that
:class:`~repro.serve.router.Router` consumes — the mesh layer's answer to
ROADMAP items 1–2 (the network as another engine class, fleet-scale
serving)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FleetTopology:
    """N serving replicas behind one router.

    Each replica is an independent :class:`~repro.serve.Engine` with its
    own host/disk tier population (``host_bytes_per_replica`` sizes a
    per-replica :class:`~repro.core.pool.HostPool`; ``None`` = unpooled).
    The inter-replica link is priced with the same constants the
    simulator's sixth channel uses (``HardwareModel.nic_bw`` /
    ``nic_latency``), so the router's migrate-vs-re-prefill choice and the
    simulator's crossover prediction talk about the same wire."""

    n_replicas: int = 3
    host_bytes_per_replica: int | None = None
    nic_bw: float = 3.1e9            # 25 GbE-class
    nic_latency: float = 50e-6
    heartbeat_timeout_s: float = 2.0
    name_prefix: str = "replica"

    def __post_init__(self) -> None:
        if self.n_replicas < 1:
            raise ValueError("a fleet needs at least one replica")

    @property
    def replica_names(self) -> tuple[str, ...]:
        return tuple(f"{self.name_prefix}-{i}"
                     for i in range(self.n_replicas))


def make_fleet_topology(n_replicas: int = 3, **kw) -> FleetTopology:
    """Convenience builder mirroring the mesh makers' shape."""
    return FleetTopology(n_replicas=n_replicas, **kw)


def make_production_mesh(*, multi_pod: bool = False,
                         shape: tuple[int, ...] | None = None):
    """16×16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod
    dry-run. Axes: ('pod',) 'data', 'model'. ``shape`` overrides the
    per-pod (data, model) factorization — e.g. (32, 8) suits archs whose
    head counts divide 8 but not 16 (§Perf iteration A4)."""
    import jax
    from jax.sharding import AxisType
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    elif multi_pod and len(shape) == 2:
        shape = (2, *shape)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    assert len(shape) == len(axes)
    return jax.make_mesh(tuple(shape), axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh():
    """Single-device mesh with the production axis names (CPU tests)."""
    import jax
    from jax.sharding import AxisType
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
