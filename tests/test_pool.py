"""HostPool arbitration (DESIGN.md §12): grants honor floors and never
overcommit; static/demand/priority splits behave as documented; refused
charges record pressure; revocation fires the callback with the deficit
(outside the pool lock, as a cheap signal); leases attached to a
TieredStore mirror occupancy and bound auto-LRU admission by the dynamic
grant."""
import threading

import numpy as np
import pytest

from repro.core import (ARBITRATION_POLICY_NAMES, HostPool, LeaseRefusal,
                        TieredStore, get_arbitration_policy)


class TestArbitration:
    def test_static_split_floors_then_weights(self):
        p = HostPool(1000, policy="static")
        a = p.lease("a", min_bytes=400, weight=1.0)
        b = p.lease("b", weight=2.0)
        assert a.grant == 400 + 200 and b.grant == 400
        assert a.grant + b.grant <= p.capacity

    def test_floor_feasibility_enforced_at_lease_time(self):
        p = HostPool(100)
        p.lease("a", min_bytes=80)
        with pytest.raises(ValueError, match="infeasible"):
            p.lease("b", min_bytes=30)

    def test_demand_split_follows_load(self):
        p = HostPool(1000, policy="demand")
        a = p.lease("a")
        b = p.lease("b")
        assert a.try_charge(600)          # demand rebalance grows a's grant
        assert a.used == 600
        assert b.try_charge(300)
        assert a.used + b.used <= p.capacity

    def test_priority_outranks(self):
        p = HostPool(1000, policy="priority")
        low = p.lease("memgraph", min_bytes=200, priority=1)
        high = p.lease("kv", priority=2)
        assert high.try_charge(800)       # squeezed everything but the floor
        assert low.grant == 200
        assert not low.try_charge(300)    # only the floor is chargeable
        assert low.try_charge(200)

    def test_grants_never_violate_floor_or_capacity(self):
        for name in ARBITRATION_POLICY_NAMES:
            p = HostPool(997, policy=name)
            leases = [p.lease("a", min_bytes=100, weight=1, priority=2),
                      p.lease("b", min_bytes=37, weight=3, priority=1),
                      p.lease("c", weight=2, priority=0)]
            for i, l in enumerate(leases):
                l.try_charge(137 * (i + 1))
            total = sum(l.grant for l in p.leases())
            assert total <= p.capacity, name
            for l in p.leases():
                assert l.grant >= l.min_bytes, (name, l.name)

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="unknown arbitration"):
            get_arbitration_policy("belady")
        with pytest.raises(ValueError):
            HostPool(10, policy="nope")


class TestChargeDiscipline:
    def test_refusal_counts_and_pressure(self):
        p = HostPool(100, policy="static")
        a = p.lease("a")
        assert a.try_charge(60)
        assert not a.try_charge(60)
        assert a.refusals == 1 and a.pressure == 20
        # opportunistic refusals never record pressure
        a.pressure = 0
        assert not a.try_charge(60, urgent=False)
        assert a.refusals == 2 and a.pressure == 0
        a.release(30)
        assert a.try_charge(60)           # success clears pressure
        assert a.pressure == 0 and a.used == 90

    def test_charge_raises_typed_refusal(self):
        p = HostPool(50)
        a = p.lease("a")
        with pytest.raises(LeaseRefusal, match="does not fit"):
            a.charge(60)

    def test_peak_and_pool_counters(self):
        p = HostPool(1000)
        a = p.lease("a")
        b = p.lease("b")
        a.charge(300)
        b.charge(200)
        a.release(300)
        assert a.peak == 300 and a.used == 0
        assert p.used_bytes == 200 and p.peak_bytes == 500
        snap = p.snapshot()
        assert snap["leases"]["a"]["peak"] == 300
        assert snap["peak_bytes"] == 500

    def test_transfer_moves_bytes_between_leases(self):
        p = HostPool(1000)
        a, b = p.lease("a"), p.lease("b")
        a.charge(400)
        p.transfer(a, b, 150)
        assert a.used == 250 and b.used == 150
        assert p.used_bytes == 400        # pool-level occupancy unchanged

    def test_close_lease_returns_share(self):
        p = HostPool(100, policy="static")
        a = p.lease("a")
        b = p.lease("b")
        a.charge(40)
        a.close()
        assert a.closed and p.used_bytes == 0
        assert b.grant == 100             # the whole pool again


class TestRevocation:
    def test_priority_pressure_revokes_lower_lease(self):
        fired = []
        p = HostPool(1000, policy="priority")
        low = p.lease("prefetch", priority=0,
                      on_revoke=lambda d: fired.append(d))
        high = p.lease("kv", priority=2)
        assert low.try_charge(700)        # idle pool: prefetch takes slack
        # the outranking charge shrinks low's grant (revocation fires with
        # the deficit) but does NOT admit yet: low still physically holds
        # its 700 B, and granting held bytes away would burst the pool
        assert not high.try_charge(600)
        assert fired and fired[0] > 0     # deficit delivered to the callback
        assert low.revoked_bytes >= fired[0]
        assert p.revocations >= 1
        assert low.overage > 0            # what low's spill path must drain
        assert high.pressure > 0          # the deferral is recorded
        low.release(low.overage)          # the spill stream drains it...
        assert high.try_charge(600)       # ...and the deferred charge fits
        assert p.used_bytes <= p.capacity
        assert p.peak_bytes <= p.capacity  # the bound held throughout

    def test_callback_fires_outside_pool_lock(self):
        """The callback may call straight back into the pool (a consumer
        waking its scheduler might read counters) — firing under the pool
        lock would deadlock."""
        p = HostPool(100, policy="priority")
        seen = []
        low = p.lease("low", priority=0,
                      on_revoke=lambda d: seen.append(p.snapshot()))
        high = p.lease("high", priority=1)
        low.try_charge(90)
        high.try_charge(50)
        assert seen                        # re-entry completed, no deadlock


class TestLeasedTieredStore:
    def test_occupancy_mirrors_into_lease(self):
        p = HostPool(10_000)
        l = p.lease("memgraph")
        ts = TieredStore({}, auto_spill=False, lease=l)
        ts.put_offload("k", np.ones(16))             # 128 B
        assert l.used == 128 and p.used_bytes == 128
        ts.spill("k")
        assert l.used == 0
        ts.load("k")
        assert l.used == 128
        ts.pop_offload("k")
        assert l.used == 0 and l.peak == 128
        ts.close()

    def test_auto_lru_bounded_by_dynamic_grant(self):
        """An auto-LRU store under a lease spills to the *arbitrated*
        grant: a competitor's pressure shrinks the grant (revocation), the
        next admission spills down to it — lazily, on the store's own
        thread — and once the overage drains the competitor's deferred
        charge fits. Timing moved; no bytes were lost."""
        p = HostPool(700, policy="demand")
        l = p.lease("a")
        other = p.lease("b")
        ts = TieredStore({}, auto_spill=True, lease=l)
        vals = {k: np.full(16, i, np.float64) for i, k in
                enumerate("wxyz")}                   # 128 B each
        for k, v in vals.items():
            ts.put_offload(k, v)
        # demand-proportional: the store's own growth grew its grant
        assert ts.resident_bytes == 512 <= l.grant
        # a competitor demands more than the pool has free: refused (the
        # store still *holds* 512), but the rebalance shrinks our grant
        # below occupancy — recorded as a revocation with an overage
        assert not other.try_charge(350)
        assert l.grant < 512 and l.overage > 0
        assert p.revocations >= 1 and other.pressure > 0
        # the store's next admissions LRU-spill down to the shrunk grant
        ts.put_offload("new", np.ones(16))
        assert ts.resident_bytes <= l.grant
        for k in list(ts.lru_keys())[:-1]:           # drain the rest
            ts.spill(k)
        assert other.try_charge(350)                 # deferred charge fits
        assert p.used_bytes <= p.capacity
        assert p.peak_bytes <= p.capacity
        # tier transparency survived the squeeze: every value intact
        for k, v in vals.items():
            np.testing.assert_array_equal(ts.peek_offload(k), v)
        ts.close()
        assert l.used == 0

    def test_build_refuses_floorless_lease(self):
        """Compile-time feasibility may only charge the lease's inviolable
        floor — a floorless lease's grant is revocable, so compiling
        against it could later burst the pool bound."""
        from repro.core import BuildConfig
        p = HostPool(100)
        cfg = BuildConfig(capacity=3, host_lease=p.lease("memgraph"))
        with pytest.raises(ValueError, match="no floor"):
            cfg.host_budget()
        floored = BuildConfig(
            capacity=3, host_lease=p.lease("planned", min_bytes=40))
        assert floored.host_budget() == 40

    def test_store_close_drains_lease(self):
        p = HostPool(1000)
        l = p.lease("a")
        ts = TieredStore({}, auto_spill=False, lease=l)
        ts.put_offload("k", np.ones(32))
        ts.close()
        assert l.used == 0 and p.used_bytes == 0
