"""Serving-engine tests: continuous batching, ragged prompts, window-edge
prompts, sampling determinism, oracle equality, and KV-cache CPU offload
(mirror + swap/reload) under every reload policy.

The oracle is :func:`repro.serve.naive_generate` — an unbatched prefill +
single-row decode loop with the engine's (seed, rid, position) key
schedule. Every engine configuration (bucketing, padding, offload,
preemption, reload order) must reproduce it token-for-token."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.serve import (Engine, PagedKVCache, RELOAD_POLICY_NAMES,
                         ServeConfig, naive_generate)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def lm():
    cfg = reduced(get_arch("olmo-1b"))
    model = build_model(cfg)
    return model, model.init(KEY)


def oracle(lm, prompts, *, max_new, max_len, seed=0, temperature=0.0):
    model, params = lm
    return [naive_generate(model, params, p, max_new=max_new,
                           max_len=max_len, rid=i, seed=seed,
                           temperature=temperature)
            for i, p in enumerate(prompts)]


# ------------------------------------------------------------------ basics
def test_ragged_batch_matches_oracle(lm):
    model, params = lm
    prompts = [[1, 2, 3], [4, 5, 6, 7, 8, 9, 10], [11], [12, 13, 14, 15, 16]]
    cfg = ServeConfig(max_len=64, batch_buckets=(1, 2, 4), block_size=16)
    out = Engine(model, params, cfg).generate(prompts, max_new=6)
    assert out == oracle(lm, prompts, max_new=6, max_len=64)


def test_padded_rows_inert(lm):
    """One request in a multi-slot bucket: padding slots must not perturb
    the live row (the old engine teacher-forced zeros into them forever)."""
    model, params = lm
    cfg = ServeConfig(max_len=64, batch_buckets=(4,), block_size=16)
    out = Engine(model, params, cfg).generate([[1, 2, 3]], max_new=5)
    solo = ServeConfig(max_len=64, batch_buckets=(1,), block_size=16)
    assert out == Engine(model, params, solo).generate([[1, 2, 3]],
                                                       max_new=5)
    assert out == oracle(lm, [[1, 2, 3]], max_new=5, max_len=64)


def test_prompt_exactly_fills_window(lm):
    """P == max_len crashed the old engine (None into np.where); now the
    first token samples from prefill logits and the request completes."""
    model, params = lm
    cfg = ServeConfig(max_len=32, batch_buckets=(1, 2), block_size=8)
    prompts = [list(range(1, 33)), [5, 6, 7]]
    out = Engine(model, params, cfg).generate(prompts, max_new=4)
    assert len(out[0]) == 1                     # window full after prefill
    assert len(out[1]) == 4
    assert out == oracle(lm, prompts, max_new=4, max_len=32)


def test_prompt_near_window_truncates(lm):
    model, params = lm
    cfg = ServeConfig(max_len=32, batch_buckets=(1,), block_size=8)
    out = Engine(model, params, cfg).generate([list(range(1, 31))],
                                              max_new=10)
    assert len(out[0]) == 3                     # 32 - 30 + 1
    assert out == oracle(lm, [list(range(1, 31))], max_new=10, max_len=32)


def test_queue_exceeds_largest_bucket(lm):
    """Continuous batching: 6 requests through 2 slots, admissions as slots
    free up."""
    model, params = lm
    prompts = [[i + 1, i + 2, i + 3, i + 4] for i in range(6)]
    cfg = ServeConfig(max_len=64, batch_buckets=(1, 2), block_size=16)
    eng = Engine(model, params, cfg)
    out = eng.generate(prompts, max_new=4)
    assert out == oracle(lm, prompts, max_new=4, max_len=64)
    assert eng.stats.tokens == 24
    for rid in range(len(prompts)):     # online hygiene: free finished reqs
        eng.release(rid)
    assert not eng.reqs and not eng._block_seq


def test_temperature_determinism_and_oracle(lm):
    model, params = lm
    prompts = [[1, 2, 3], [9, 8, 7, 6], [5]]
    cfg = ServeConfig(max_len=64, batch_buckets=(1, 2, 4), block_size=16,
                      temperature=0.7)
    a = Engine(model, params, cfg).generate(prompts, max_new=6, seed=11)
    b = Engine(model, params, cfg).generate(prompts, max_new=6, seed=11)
    assert a == b                               # fixed seed → reproducible
    assert a == oracle(lm, prompts, max_new=6, max_len=64, seed=11,
                       temperature=0.7)
    c = Engine(model, params, cfg).generate(prompts, max_new=6, seed=12)
    assert c != a                               # seed actually matters


def test_bad_requests_rejected(lm):
    model, params = lm
    eng = Engine(model, params, ServeConfig(max_len=32, block_size=8))
    with pytest.raises(ValueError):
        eng.submit([], 4)
    with pytest.raises(ValueError):
        eng.submit(list(range(40)), 4)
    with pytest.raises(ValueError):
        eng.submit([1, 2], 0)


def test_recurrent_families_rejected():
    cfg = reduced(get_arch("rwkv6-7b"))
    model = build_model(cfg)
    with pytest.raises(ValueError):
        Engine(model, {}, ServeConfig())


# ----------------------------------------------------------------- offload
def test_offload_smoke_two_requests(lm):
    """Fast-lane serving smoke: tiny model, 2 requests, offload forced on
    (every block cold), with preemption forcing a real swap/reload cycle.
    Outputs must match the no-offload oracle and traffic must be real."""
    model, params = lm
    prompts = [list(range(1, 25)), list(range(30, 48))]
    cfg = ServeConfig(max_len=64, batch_buckets=(1,), block_size=8,
                      offload=True, hot_window=0, offload_fraction=1.0,
                      preempt_every=3, h2d_bw=500e6, d2h_bw=500e6)
    eng = Engine(model, params, cfg)
    out = eng.generate(prompts, max_new=8)
    assert out == oracle(lm, prompts, max_new=8, max_len=64)
    st = eng.stats
    assert st.offload_bytes > 0 and st.reload_bytes > 0
    assert st.offloaded_fraction >= 0.5
    assert st.swaps >= 1
    # everything freed once requests finish
    assert eng.host.resident_bytes == 0


@pytest.mark.parametrize("policy", RELOAD_POLICY_NAMES)
def test_reload_policy_order_independence(lm, policy):
    """The TURNIP property, serving edition: reload order changes timing,
    never results."""
    model, params = lm
    prompts = [list(range(1, 20)), list(range(5, 33)), [7, 8, 9, 10]]
    cfg = ServeConfig(max_len=64, batch_buckets=(1, 2), block_size=8,
                      offload=True, hot_window=8, preempt_every=2,
                      reload_policy=policy, h2d_bw=300e6, d2h_bw=300e6)
    out = Engine(model, params, cfg).generate(prompts, max_new=6)
    assert out == oracle(lm, prompts, max_new=6, max_len=64)


def test_mirrored_cold_blocks_survive_double_preempt(lm):
    """A request preempted twice must restore bit-identical state both
    times (stale-tail-block invalidation is the regression target)."""
    model, params = lm
    prompts = [list(range(1, 30)), list(range(2, 28)), list(range(3, 31))]
    cfg = ServeConfig(max_len=64, batch_buckets=(1,), block_size=8,
                      offload=True, hot_window=0, preempt_every=2,
                      h2d_bw=500e6, d2h_bw=500e6)
    eng = Engine(model, params, cfg)
    out = eng.generate(prompts, max_new=8)
    assert out == oracle(lm, prompts, max_new=8, max_len=64)
    assert eng.stats.swaps >= 6                  # every request swapped twice


def test_stale_transfer_after_release_is_safe(lm):
    """A transfer completing after its request was released must be a
    no-op on the DMA thread, not a KeyError that silently kills the
    stream and wedges the engine."""
    from repro.serve.engine import _Transfer, get_reload_policy
    from repro.core.dispatch import D2H
    model, params = lm
    eng = Engine(model, params, ServeConfig(max_len=32, block_size=8))
    rid = eng.submit([1, 2, 3], 2)
    eng.run()
    eng.release(rid)
    stale = _Transfer(D2H, rid, 0, seq=0, nbytes=64)
    eng._service_d2h(stale)                      # must not raise
    pol = get_reload_policy("critical-path")
    pol.prepare(eng)
    assert pol.priority(stale) < 0               # drains stale items first


# -------------------------------------------------------------- disk tier
@pytest.mark.parametrize("policy", RELOAD_POLICY_NAMES)
def test_tiered_kv_matches_oracle_every_policy(lm, policy):
    """Tier transparency, serving edition: a bounded host KV mirror with
    disk spill (two-hop reloads on the dedicated disk stream) reproduces
    the unbounded oracle token-for-token under every reload policy."""
    model, params = lm
    prompts = [list(range(1, 25)), list(range(30, 48)), [7, 8, 9, 10, 11]]
    cfg = ServeConfig(max_len=64, batch_buckets=(1,), block_size=8,
                      offload=True, hot_window=0, offload_fraction=1.0,
                      preempt_every=3, reload_policy=policy,
                      h2d_bw=500e6, d2h_bw=500e6,
                      host_kv_bytes=1, disk_bw=300e6)  # everything spills
    with Engine(model, params, cfg) as eng:
        out = eng.generate(prompts, max_new=8)
        assert out == oracle(lm, prompts, max_new=8, max_len=64)
        st = eng.stats
        assert st.disk_spill_bytes > 0 and st.disk_load_bytes > 0
        assert st.swaps >= 1
        # hierarchy fully drained once every request finished
        assert eng.host.resident_bytes == 0
        assert eng.host.disk.resident_bytes == 0


def test_swapped_queue_prefetch_fires_and_stays_oracle_exact(lm):
    """NEO-style predictive prefetch (DESIGN.md §11): with a host budget
    wide enough to hold a couple of blocks, the engine stages the
    next-scheduled swapped request's disk-resident blocks back to host
    *before* admission (prefetch_bytes > 0) — and tokens are identical to
    the oracle and to a prefetch-off run (timing only, never results)."""
    model, params = lm
    prompts = [list(range(1, 25)), list(range(30, 48)), [7, 8, 9, 10, 11]]
    want = oracle(lm, prompts, max_new=8, max_len=64)
    # a ~3-block host budget: swapped-out requests' mirrors spill to disk,
    # yet the prefetcher keeps headroom (net of in-flight reload
    # reservations) to stage the next resume back in
    blk = PagedKVCache(model, 1, 64, block_size=8).block_nbytes

    def run(prefetch):
        cfg = ServeConfig(max_len=64, batch_buckets=(1,), block_size=8,
                          offload=True, hot_window=0, offload_fraction=1.0,
                          preempt_every=3, h2d_bw=500e6, d2h_bw=500e6,
                          disk_bw=300e6, host_kv_bytes=3 * blk,
                          prefetch_swapped=prefetch)
        with Engine(model, params, cfg) as eng:
            out = eng.generate(prompts, max_new=8)
            return out, eng.stats

    out_on, st_on = run(True)
    out_off, st_off = run(False)
    assert out_on == want and out_off == want
    assert st_on.disk_spill_bytes > 0            # the disk tier was real
    assert st_on.prefetch_bytes > 0              # prediction actually fired
    assert st_off.prefetch_bytes == 0


def test_tiered_kv_roomy_host_never_touches_disk(lm):
    """A host tier wider than the KV working set must behave exactly like
    the plain HostStore path: zero disk traffic."""
    model, params = lm
    prompts = [list(range(1, 20)), [4, 5, 6]]
    cfg = ServeConfig(max_len=64, batch_buckets=(1, 2), block_size=8,
                      offload=True, hot_window=0, preempt_every=2,
                      h2d_bw=500e6, d2h_bw=500e6,
                      host_kv_bytes=1 << 30)
    with Engine(model, params, cfg) as eng:
        out = eng.generate(prompts, max_new=6)
        assert out == oracle(lm, prompts, max_new=6, max_len=64)
        assert eng.stats.disk_spill_bytes == 0
        assert eng.stats.disk_load_bytes == 0


# ------------------------------------------------------------ shared pool
@pytest.mark.parametrize("arb", ("static", "demand", "priority"))
def test_pooled_engine_matches_oracle_and_bounds_pool(lm, arb):
    """Shared-pool lane (DESIGN.md §12): the engine's KV mirror living in
    an arbitrated HostPool — reservations gate every host-bound transfer —
    must stay token-exact vs the oracle under every arbitration policy,
    with combined occupancy never past the pool budget and every lease
    drained once the queue empties."""
    from repro.core import HostPool
    model, params = lm
    prompts = [list(range(1, 25)), list(range(30, 48)), [7, 8, 9, 10, 11]]
    want = oracle(lm, prompts, max_new=8, max_len=64)
    blk = PagedKVCache(model, 1, 64, block_size=8).block_nbytes
    # priority pool is deliberately tight (revocations + deferrals fire);
    # static must cover the largest resume set out of its fixed kv share
    pool = HostPool((6 if arb == "priority" else 8) * blk, policy=arb)
    cfg = ServeConfig(max_len=64, batch_buckets=(1,), block_size=8,
                      offload=True, hot_window=0, offload_fraction=1.0,
                      preempt_every=3, h2d_bw=500e6, d2h_bw=500e6,
                      disk_bw=300e6)
    with Engine(model, params, cfg, pool=pool) as eng:
        out = eng.generate(prompts, max_new=8)
        assert out == want
        snap = pool.snapshot()
        assert snap["peak_bytes"] > 0
        assert snap["peak_bytes"] <= snap["capacity"]
        assert eng.host.resident_bytes == 0
        assert eng.host.disk.resident_bytes == 0
        for name in ("kv", "prefetch"):
            assert snap["leases"][name]["used"] == 0
        if arb == "priority":
            assert eng.stats.disk_spill_bytes > 0    # tier really pressed
            assert eng.stats.lease_deferrals > 0


def test_runtime_and_serving_share_one_arbitrated_pool(lm):
    """The headline scenario: a MEMGRAPH plan's offload traffic and the
    serving engine's KV mirror running *concurrently* against ONE
    HostPool. Both consumers' outputs must be byte-identical to isolated
    runs, and the pool bound must hold throughout."""
    import threading
    from repro.core import BuildConfig, HostPool, build_memgraph
    from repro.core.runtime import TurnipRuntime, eval_taskgraph
    from helpers import fig3_taskgraph, int_inputs
    model, params = lm
    tg = fig3_taskgraph()
    inputs = int_inputs(tg)
    ref = eval_taskgraph(tg, inputs)
    res = build_memgraph(tg, BuildConfig(capacity=3, host_capacity=1,
                                         size_fn=lambda v: 1))
    assert res.n_spills > 0
    # isolated baselines: runtime on a private store, engine on its own
    rr_iso = TurnipRuntime(tg, res, mode="nondet", policy="random",
                           seed=3).run(inputs)
    prompts = [list(range(1, 25)), list(range(30, 48)), [7, 8, 9]]
    want = oracle(lm, prompts, max_new=6, max_len=64)
    blk = PagedKVCache(model, 1, 64, block_size=8).block_nbytes
    scfg = ServeConfig(max_len=64, batch_buckets=(1,), block_size=8,
                       offload=True, hot_window=0, offload_fraction=1.0,
                       preempt_every=3, h2d_bw=500e6, d2h_bw=500e6,
                       disk_bw=300e6)

    pool = HostPool(8 * blk + 2 * rr_iso.peak_host_bytes + 1,
                    policy="priority")
    mem_lease = pool.lease("memgraph", min_bytes=rr_iso.peak_host_bytes,
                           priority=1)
    rt_out: dict = {}

    def run_runtime():
        rt = TurnipRuntime(tg, res, mode="nondet", policy="random",
                           seed=3, host_lease=mem_lease)
        rt_out["rr"] = rt.run(inputs)

    with Engine(model, params, scfg, pool=pool) as eng:
        t = threading.Thread(target=run_runtime)
        t.start()
        out = eng.generate(prompts, max_new=6)
        t.join(60)
        assert not t.is_alive(), "pooled runtime wedged"
    assert out == want                          # serving: oracle-exact
    rr = rt_out["rr"]
    for k in ref:                               # runtime: oracle-exact
        np.testing.assert_array_equal(rr.outputs[k], ref[k])
    snap = pool.snapshot()
    assert snap["peak_bytes"] > 0
    assert snap["peak_bytes"] <= snap["capacity"]
    assert snap["leases"]["memgraph"]["peak"] <= mem_lease.min_bytes
    assert snap["used_bytes"] == 0              # everything drained


# ------------------------------------------------------------ paged cache
def test_paged_cache_block_roundtrip(lm):
    model, _ = lm
    kv = PagedKVCache(model, 2, 32, block_size=8)
    assert kv.n_blocks == 4
    assert kv.n_token_blocks(0) == 0 and kv.n_token_blocks(9) == 2
    leaf = kv.cache["k"]
    kv.cache["k"] = leaf.at[:, 1, 8:16].set(1.5)
    data = kv.read_block(1, 1)
    assert float(np.asarray(data["k"]).mean()) == 1.5
    assert sum(d.nbytes for d in data.values()) == kv.block_nbytes
    kv.drop_slot(1)
    assert float(np.abs(np.asarray(kv.cache["k"][:, 1])).max()) == 0.0
    kv.write_block(1, 1, data)
    assert float(np.asarray(kv.cache["k"][:, 1, 8:16]).mean()) == 1.5
    kv.grow(4)
    assert kv.cache["k"].shape[1] == 4
    assert float(np.asarray(kv.cache["k"][:, 1, 8:16]).mean()) == 1.5


def test_paged_cache_rejects_recurrent_cache():
    cfg = reduced(get_arch("rwkv6-7b"))
    model = build_model(cfg)
    with pytest.raises(ValueError):
        PagedKVCache(model, 2, 32, block_size=8)


def test_host_store_block_hooks():
    from repro.core.runtime import HostStore
    hs = HostStore({})
    blk = {"k": np.ones((2, 8), np.float32), "v": np.ones((2, 8), np.float32)}
    hs.put_offload(("r0", 0), blk)
    assert hs.offload_bytes == 128 and hs.resident_bytes == 128
    got = hs.get_offload(("r0", 0))
    assert hs.reload_bytes == 128
    np.testing.assert_array_equal(got["k"], blk["k"])
    hs.pop_offload(("r0", 0))
    assert hs.resident_bytes == 0
    hs.pop_offload(("r0", 0))                    # idempotent


def test_bytearena_drop_invalidates():
    """Audit fix: ByteArena.drop was a silent no-op — dropped extents must
    now raise RaceError on read, matching SlotTable's contract."""
    from repro.core.memgraph import Loc, RaceError
    from repro.core.runtime import ByteArena
    arena = ByteArena({0: 64})
    loc = Loc(device=0, offset=0, size=16)
    arena.write(loc, np.arange(4, dtype=np.float32))
    np.testing.assert_array_equal(arena.read(loc),
                                  np.arange(4, dtype=np.float32))
    arena.drop(loc)
    with pytest.raises(RaceError):
        arena.read(loc)
