"""Architecture + shape configuration (the assigned 10-arch × 4-shape grid).

Every architecture is an :class:`ArchConfig`; every workload shape a
:class:`ShapeConfig`. ``input_specs(arch, shape)`` produces the
ShapeDtypeStruct stand-ins consumed by the dry-run (no allocation), and
``reduced(arch)`` the tiny same-family config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "input_specs", "reduced",
           "applicable_shapes"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | rwkv | zamba | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    norm: str = "rmsnorm"         # rmsnorm | layernorm | layernorm_np
    mlp: str = "swiglu"           # swiglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 1e6
    # moe
    n_experts: int = 0
    top_k: int = 0
    # ssm (zamba) / rwkv
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    zamba_group: int = 6          # mamba layers per shared-attn invocation
    rwkv_headdim: int = 64
    # enc-dec
    n_decoder_layers: int = 0
    # modality stub frontend (assignment: frontend embeddings are provided)
    frontend: str | None = None   # vit | audio
    n_frontend_tokens: int = 256
    dtype: str = "bfloat16"
    subquadratic: bool = False    # may run long_500k
    source: str = ""              # citation tag from the assignment

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def param_count(self) -> float:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, f, V = self.d_model, self.d_ff, self.padded_vocab
        H, K, Dh = self.n_heads, self.n_kv_heads, self.d_head
        attn = d * H * Dh + 2 * d * K * Dh + H * Dh * d
        mlp = 3 * d * f if self.mlp == "swiglu" else 2 * d * f
        if self.family == "dense":
            per_layer = attn + mlp
            n = self.n_layers * per_layer
        elif self.family == "moe":
            expert = 3 * d * f
            per_layer = attn + self.n_experts * expert + d * self.n_experts
            n = self.n_layers * per_layer
        elif self.family == "rwkv":
            per_layer = 5 * d * d + 2 * d * f + 7 * 32 * d   # approx loras
            n = self.n_layers * per_layer
        elif self.family == "zamba":
            di = self.ssm_expand * d
            ssm = d * (2 * di + 2 * self.ssm_state + di // self.ssm_headdim) \
                + di * d
            shared = attn + mlp
            n = self.n_layers * ssm + shared
        elif self.family == "encdec":
            enc = self.n_layers * (attn + mlp)
            dec = self.n_decoder_layers * (2 * attn + mlp)
            n = enc + dec
        else:
            raise ValueError(self.family)
        return float(n + V * d)

    @property
    def active_param_count(self) -> float:
        """Active params per token (= params for non-MoE)."""
        if self.family != "moe":
            return self.param_count
        d, f = self.d_model, self.d_ff
        expert = 3 * d * f
        inactive = self.n_layers * (self.n_experts - self.top_k) * expert
        return self.param_count - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(arch: ArchConfig) -> list[str]:
    """long_500k needs sub-quadratic attention (assignment rule); skips are
    recorded in DESIGN.md §Arch-applicability."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch.subquadratic:
        out.append("long_500k")
    return out


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the step function
    (weights/caches are produced separately via ``eval_shape`` of init)."""
    B, S = shape.global_batch, shape.seq_len
    adt = arch.dtype
    if shape.kind == "train":
        specs: dict[str, Any] = {}
        if arch.family == "encdec":
            # assignment: frontend is a stub — precomputed frame embeddings
            specs["encoder_embeds"] = _sds((B, S // 2, arch.d_model), adt)
            specs["tokens"] = _sds((B, S // 2), "int32")
            specs["labels"] = _sds((B, S // 2), "int32")
        elif arch.frontend == "vit":
            nf = arch.n_frontend_tokens
            specs["vision_embeds"] = _sds((B, nf, arch.d_model), adt)
            specs["tokens"] = _sds((B, S - nf), "int32")
            specs["labels"] = _sds((B, S - nf), "int32")
        else:
            specs["tokens"] = _sds((B, S), "int32")
            specs["labels"] = _sds((B, S), "int32")
        return specs
    if shape.kind == "prefill":
        if arch.family == "encdec":
            return {"encoder_embeds": _sds((B, S // 2, arch.d_model), adt),
                    "tokens": _sds((B, S // 2), "int32")}
        if arch.frontend == "vit":
            nf = arch.n_frontend_tokens
            return {"vision_embeds": _sds((B, nf, arch.d_model), adt),
                    "tokens": _sds((B, S - nf), "int32")}
        return {"tokens": _sds((B, S), "int32")}
    # decode: one new token against a seq_len-deep cache/state
    specs = {"token": _sds((B, 1), "int32"),
             "cache_len": _sds((), "int32")}
    return specs


def reduced(arch: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw: dict[str, Any] = dict(
        name=arch.name + "-smoke", family=arch.family,
        n_layers=min(arch.n_layers, 2 if arch.family != "zamba" else 5),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(arch.n_kv_heads, 2) if arch.n_kv_heads < arch.n_heads
        else 4,
        d_ff=256, vocab_size=512,
        norm=arch.norm, mlp=arch.mlp, qkv_bias=arch.qkv_bias,
        rope_theta=arch.rope_theta, dtype="float32",
        subquadratic=arch.subquadratic, frontend=arch.frontend,
        n_frontend_tokens=8,
    )
    if arch.family == "moe":
        kw.update(n_experts=4, top_k=2, d_ff=64)
    if arch.family == "zamba":
        kw.update(ssm_state=16, ssm_headdim=32, ssm_expand=2, zamba_group=2,
                  n_layers=5)
    if arch.family == "rwkv":
        kw.update(rwkv_headdim=32)
    if arch.family == "encdec":
        kw.update(n_decoder_layers=2)
    return ArchConfig(**kw)
