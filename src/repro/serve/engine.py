"""Continuous-batching serving engine with block-paged KV-cache CPU offload.

The paper's §9 limitation — TURNIP executes *static* graphs, so recursive
generation must run over pre-compiled plans — becomes the design here
rather than a caveat:

* **Request queue → bucketed static batches.** Requests are submitted to a
  queue and admitted into fixed batch *slots*; decode is jitted once per
  batch bucket, so every step executes the same compiled program over
  ``[bucket, 1]`` tokens with per-row cache positions. Rows at different
  depths share one plan (continuous batching); slots without a live request
  are *inert* — ``decode_step``'s ``active`` mask keeps them from writing
  to the cache, and their logits are never sampled.
* **Real batched prefill.** A prompt enters the cache through ONE forward
  (:meth:`~repro.models.lm.LM.prefill`) instead of token-by-token teacher
  forcing; the request's first token samples from the prefill logits.
* **MEMGRAPH memory discipline.** The KV cache is a
  :class:`~repro.serve.kv_cache.PagedKVCache`: block-granular static
  extents over a preallocated cache. Cold blocks are *mirrored* to the
  TURNIP :class:`~repro.core.runtime.HostStore` on a dedicated d2h stream,
  and swapped-out requests are restored on an h2d stream — transfers run on
  their own engine classes (:data:`~repro.core.dispatch.D2H` /
  :data:`~repro.core.dispatch.H2D`) and overlap under decode, so steps
  never block on a transfer (paper §5). The main loop owns all cache
  mutation; DMA threads only snapshot blocks and post completion events.
* **Nondeterministic reload order.** Which pending transfer a DMA stream
  services next is a :class:`~repro.core.dispatch.DispatchPolicy` decision:
  ``fixed`` replays block-creation order (the compile-time-order ablation —
  blocks of concurrently decoding requests interleave, so no request
  resumes until nearly all transfers finish: §8's head-of-line pathology),
  while ``critical-path`` completes the request that can resume soonest.
* **A bounded host tier with disk spill.** ``host_kv_bytes`` caps the
  host-RAM KV mirror (online serving hits the CPU-RAM ceiling first —
  NEO, PAPERS.md): past it, least-recently-used mirrored blocks spill to
  a file-backed :class:`~repro.core.stores.TieredStore` disk tier on a
  dedicated disk stream (:data:`~repro.core.dispatch.DISK` — spills and
  loads never occupy a DMA lane), and a swapped request's disk-resident
  blocks resume through pipelined two-hop ``disk→host→device`` chains,
  with ``critical-path`` issuing the slow disk loads ahead of background
  spills. Tier placement changes timing only — never tokens.
* **Predictive cross-tier prefetch (NEO-style).** The scheduler knows
  which swapped request resumes next — waiting for its admission to
  discover its blocks live on disk is exactly the reactive stall the
  compiler-side PrefetchPlan removes from MEMGRAPH plans (DESIGN.md §11).
  While decode runs, the engine stages the next-scheduled swapped
  requests' disk-resident blocks back into host RAM on the disk stream
  (``prefetch_swapped``), bounded by the host budget's free headroom so a
  prefetch can never trigger spill thrash; a resume then needs only the
  h2d hop. Prefetch is opportunistic — a block that misses the window
  simply takes the two-hop chain as before.

* **Arbitrated shared host pool (DESIGN.md §12).** Pass
  ``Engine(pool=HostPool(...))`` and the KV mirror lives in a pool-level
  budget shared with other consumers (a runtime's MEMGRAPH offloads):
  the engine holds ``kv`` and ``prefetch`` leases and *reserves* every
  host-bound block against its lease before the transfer is submitted —
  a refusal defers the transfer (mirrors skip, preemption waits,
  admissions re-queue) and the recorded pressure drives the engine's own
  LRU spills on the disk stream. Revocations (another consumer
  outranking us) arrive as a flag; the next scheduler pass drains the
  overage. Arbitration changes timing only — tokens never move.

Sampling uses a per-``(seed, request, position)`` key schedule, so a
request's tokens are independent of batch composition, padding, offload,
and reload order — :func:`naive_generate` is the unbatched oracle any
engine configuration must match.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import lockcheck
from ..core.dispatch import D2H, DISK, H2D, DispatchPolicy
from ..core.executor import select_best
from ..core.liveness import (LeaseSpec, LivenessCertificate,
                             LivenessModelError, PoolConfig,
                             certify_progress)
from ..core.memgraph import MemGraph
from ..core.stores import HostStore, TieredStore
from .kv_cache import PagedKVCache

__all__ = ["ServeConfig", "Engine", "Request", "ServeStats",
           "ReloadPolicy", "RELOAD_POLICY_NAMES", "get_reload_policy",
           "ReplicaKilled", "MigrationRefused", "MigrationTicket",
           "naive_generate"]

# request lifecycle
QUEUED, RUNNING, SWAPPING, SWAPPED, RELOADING, DONE = (
    "queued", "running", "swapping-out", "swapped", "reloading", "done")


class ReplicaKilled(RuntimeError):
    """The replica's run loop was hard-killed (fault-injection seam or
    ``hard_kill()``): device state is gone, but the host/disk tiers — owned
    by the host process, not the dead worker — survive for draining."""


class MigrationRefused(RuntimeError):
    """All-or-nothing import refused: the destination could not reserve the
    whole KV set against its lease (or the ticket failed validation).
    Nothing landed — the caller falls back to cold re-prefill."""


@dataclasses.dataclass
class MigrationTicket:
    """A request checkpointed at its last emitted token, portable between
    replicas. ``blocks`` carries the KV payloads of a *warm* ticket (one
    ``{leaf: ndarray}`` dict per block, exactly ``read_block``'s layout);
    ``None`` means cold — device state died with the source replica and the
    destination must re-prefill ``prompt + out`` (token-exact because the
    sampling key schedule folds only (seed, rid, position), all three of
    which the ticket preserves)."""

    rid: int
    prompt: list[int]
    out: list[int]
    max_new: int
    pos: int
    last: int
    block_size: int
    t_submit: float = 0.0
    t_first: float = 0.0
    blocks: "list[dict] | None" = None

    @property
    def warm(self) -> bool:
        return self.blocks is not None


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    # the cache grows to the smallest bucket covering demand and stays
    # there (no shrink/compaction): after a burst, decode keeps running
    # the largest-bucket plan with inert rows masked
    batch_buckets: tuple[int, ...] = (1, 4, 8)
    temperature: float = 0.0          # 0 = greedy
    block_size: int = 32              # tokens per KV block (offload extent)
    # ---- offload / swapping ------------------------------------------
    offload: bool = False             # mirror cold KV blocks to host RAM
    hot_window: int = 32              # trailing tokens that never offload
    offload_fraction: float = 1.0     # cap: mirrored fraction of a request
    preempt_every: int = 0            # decode quantum before a running
    #                                   request may be swapped out for a
    #                                   waiter (0 = never preempt)
    reload_policy: str = "critical-path"   # fixed|random|critical-path
    # ---- disk tier (second threshold of the hierarchy) ----------------
    # host_kv_bytes bounds the host-RAM KV mirror: once occupancy passes
    # it, the engine spills least-recently-used mirrored blocks to a
    # file-backed disk tier on a dedicated disk stream (NEO's CPU-RAM
    # ceiling made runnable). Reloading a disk-resident block is a
    # pipelined two-hop disk→host→device chain. None = unbounded host.
    host_kv_bytes: int | None = None
    disk_bw: float = 2.4e9
    # NEO-style predictive prefetch: stage the next-scheduled swapped
    # requests' disk-resident blocks back into host RAM ahead of their
    # admission, within the host budget's free headroom (timing only —
    # tokens never depend on it)
    prefetch_swapped: bool = True
    # simulated PCIe (the container has no accelerator; wire time is slept
    # on the DMA thread, exactly like TurnipRuntime's `latency` injection)
    h2d_bw: float = 12e9
    d2h_bw: float = 12e9
    dma_latency: float = 10e-6
    # fused DMA submissions (DESIGN.md §15, serving face): a stream that
    # wakes with several transfers pending issues them as one batched
    # submission — one enqueue + one fixed-latency completion wait for the
    # whole run instead of per transfer. Timing-only: tokens are
    # byte-identical either way (service order = pop order).
    fuse_dma: bool = False
    max_fuse_dma: int = 8
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    state: str = QUEUED
    slot: int = -1
    pos: int = 0                      # tokens resident in the cache
    last: int = 0                     # last sampled token (next decode feed)
    quantum: int = 0                  # decode steps since (re)admission
    mirrored: set[int] = dataclasses.field(default_factory=set)
    inflight: set[int] = dataclasses.field(default_factory=set)
    pending_reload: set[int] = dataclasses.field(default_factory=set)
    reload_data: dict[int, dict] = dataclasses.field(default_factory=dict)
    # TTFT stamps (router-level p99 accounting): submission and first-token
    # instants in time.monotonic() seconds. Carried across migrations in
    # the ticket, so a resumed request keeps its original latency history.
    t_submit: float = 0.0
    t_first: float = 0.0


@dataclasses.dataclass
class ServeStats:
    tokens: int = 0                   # all emitted (incl. prefill-sampled)
    decode_tokens: int = 0            # emitted by decode steps only
    prefill_tokens: int = 0
    decode_steps: int = 0
    decode_time: float = 0.0
    prefill_time: float = 0.0
    stall_time: float = 0.0           # wall time with no resident row to step
    swaps: int = 0
    revocations: int = 0              # pool grant shrinkages signalled to us
    lease_deferrals: int = 0          # transfers deferred by a refused
    #                                   reservation (shared-pool mode)
    offload_bytes: int = 0
    reload_bytes: int = 0
    disk_spill_bytes: int = 0         # host→disk tier traffic
    disk_load_bytes: int = 0          # disk→host tier traffic
    prefetch_bytes: int = 0           # disk→host bytes staged *ahead* of a
    #                                   resume (subset of disk_load_bytes)
    fused_dma_batches: int = 0        # multi-transfer submissions issued
    #                                   (ServeConfig.fuse_dma)
    kv_bytes_written: int = 0
    migrations_in: int = 0            # warm tickets imported (router fleet)
    migrations_out: int = 0           # warm tickets exported off this
    #                                   replica (drain + live rebalance)

    @property
    def offloaded_fraction(self) -> float:
        return self.offload_bytes / max(self.kv_bytes_written, 1)

    @property
    def decode_tok_s(self) -> float:
        """Decode-step throughput: first tokens (sampled from prefill
        logits during prefill_time) are excluded from the numerator."""
        return self.decode_tokens / max(self.decode_time + self.stall_time,
                                        1e-9)


# --------------------------------------------------------------------------
# DMA transfers + reload-order policies
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Transfer:
    kind: str                         # dispatch.D2H | dispatch.H2D | dispatch.DISK
    rid: int
    blk: int
    seq: int                          # block-creation order (see below)
    nbytes: int
    disk_op: str = ""                 # DISK: "spill" | "load" | "prefetch"


class ReloadPolicy(DispatchPolicy):
    """DispatchPolicy over pending serve transfers.

    Unlike the MEMGRAPH policies (static priorities per graph), urgency
    here is *dynamic*: it depends on which requests are currently blocked,
    so ``priority`` is evaluated at pop time under the engine lock."""

    name = "serve-base"

    def prepare(self, engine) -> None:              # type: ignore[override]
        self.engine = engine

    def priority(self, tr: _Transfer) -> float:     # type: ignore[override]
        raise NotImplementedError

    def pick(self, pending: list[_Transfer]) -> _Transfer:
        # the executor kernel's dispatch primitive (DESIGN.md §17): a
        # serve DMA stream's choice among pending transfers is the same
        # "policy minimum of the simultaneously-ready set" as a MEMGRAPH
        # seam's choice among ready vertices
        best = select_best(pending,
                           lambda tr: (self.priority(tr), tr.seq))
        return pending.pop(best)


class FixedReloadPolicy(ReloadPolicy):
    """Strict block-creation order — the predetermined schedule.

    Block seq numbers are assigned as blocks turn cold, which happens in
    lockstep across concurrently decoding slots, so two swapped requests'
    reloads interleave: neither resumes until nearly every transfer is done
    — the head-of-line pathology of the paper's fixed mode (§8)."""

    name = "fixed"

    def priority(self, tr: _Transfer) -> float:
        return float(tr.seq)


class RandomReloadPolicy(ReloadPolicy):
    """Seeded uniform-random priority (the any-order-must-work stance)."""

    name = "random"

    def __init__(self, seed: int | None = None) -> None:
        self.seed = random.randrange(2**31) if seed is None else seed

    def priority(self, tr: _Transfer) -> float:
        # integer-only mixing: builtin hash() of strings is salted per
        # process (PYTHONHASHSEED), which would defeat the seed
        ident = (tr.rid * 2654435761 + tr.blk * 40503 + (tr.kind == H2D)
                 + (tr.kind == DISK) * 7919 + (tr.disk_op == "spill") * 104729)
        return random.Random(
            (self.seed * 1000003 + 0x9E3779B9) ^ ident).random()


class CriticalPathReloadPolicy(ReloadPolicy):
    """Complete the request that can resume soonest: fewest outstanding
    transfers first, most remaining decode work as tie-break — the serving
    analogue of longest-path-first list scheduling.

    On the disk stream, loads (a blocked request's two-hop reload — the
    long pole) always outrank spills (background tier maintenance), so
    disk-resident blocks of resuming requests are issued earliest."""

    name = "critical-path"

    def priority(self, tr: _Transfer) -> float:
        req = self.engine.reqs.get(tr.rid)
        if req is None:                    # released mid-flight: drain first
            return -1e12
        if tr.disk_op == "spill":
            return 1e12                    # never ahead of a pending load
        if tr.disk_op == "prefetch":
            # opportunistic staging: behind any blocked request's load,
            # ahead of background spills
            return 1e9
        remaining_work = req.max_new - len(req.out)
        return len(req.inflight) * 1e6 - remaining_work


RELOAD_POLICY_NAMES = ("fixed", "random", "critical-path")


def get_reload_policy(policy: str | ReloadPolicy | None, *,
                      seed: int | None = None) -> ReloadPolicy:
    if isinstance(policy, ReloadPolicy):
        return policy
    if policy is None or policy == "critical-path":
        return CriticalPathReloadPolicy()
    if policy == "fixed":
        return FixedReloadPolicy()
    if policy == "random":
        return RandomReloadPolicy(seed)
    raise ValueError(f"unknown reload policy {policy!r}; "
                     f"expected one of {RELOAD_POLICY_NAMES}")


class _DmaStream(threading.Thread):
    """A dedicated transfer engine for one DMA direction.

    Pops the best-ranked pending transfer (policy choice = the runtime's
    nondeterministic dispatch), sleeps the simulated wire time *off* the
    engine lock so transfers overlap under decode, then runs the service
    callback (a short memcpy / completion event under the lock)."""

    def __init__(self, kind: str, bw: float, latency: float,
                 policy: ReloadPolicy, service, lock: threading.Lock, *,
                 fuse: bool = False, max_fuse: int = 8,
                 on_batch=None) -> None:
        super().__init__(name=f"serve-dma-{kind}")
        self.kind = kind
        self.bw = bw
        self.latency = latency
        self.policy = policy
        self.service = service
        self.pending: list[_Transfer] = []
        self.cond = threading.Condition(lock)
        self.stopped = False
        self.error: BaseException | None = None
        # fused submissions (ServeConfig.fuse_dma): drain up to max_fuse
        # pending transfers per wake-up into one batched submission — one
        # enqueue + one fixed-latency completion wait for the run. Wire
        # time still charges every byte; service order = pop order, so
        # token streams are byte-identical with fusion on or off.
        self.fuse = fuse
        self.max_fuse = max_fuse
        self.on_batch = on_batch      # called (lock held) per fused batch

    def submit(self, tr: _Transfer) -> None:
        """Engine lock held."""
        self.pending.append(tr)
        self.cond.notify_all()

    def shutdown(self) -> None:
        """Engine lock held. Unserviced transfers are abandoned."""
        self.stopped = True
        self.pending.clear()
        self.cond.notify_all()

    def run(self) -> None:
        try:
            while True:
                with self.cond:
                    while not self.pending and not self.stopped:
                        self.cond.wait()
                    if self.stopped:
                        return
                    batch = [self.policy.pick(self.pending)]
                    while (self.fuse and self.pending
                           and len(batch) < self.max_fuse):
                        batch.append(self.policy.pick(self.pending))
                    if len(batch) > 1 and self.on_batch is not None:
                        self.on_batch(len(batch))
                # one submission for the run: a single fixed launch
                # latency plus every member's wire bytes
                wire = self.latency + sum(t.nbytes for t in batch) / self.bw
                time.sleep(wire)
                for tr in batch:
                    self.service(tr)
        except BaseException as e:       # surface in the engine loop — a
            with self.cond:              # silently dead stream would wedge
                self.error = e           # every waiter forever
                self.stopped = True
                self.cond.notify_all()


# --------------------------------------------------------------------------
# sampling — shared by the engine and the unbatched oracle
# --------------------------------------------------------------------------
def _sample_token(row_logits: np.ndarray, *, seed: int, rid: int, pos: int,
                  temperature: float, vocab_size: int) -> int:
    """Sample the token at absolute position ``pos`` of request ``rid``.

    The key schedule folds (seed, rid, pos), so a request's randomness is
    independent of batch composition and scheduling. Vocab padding is
    masked out (the padded tail of ``padded_vocab`` must be unsampleable).
    At temperature > 0 this is an eager per-token jax call — a deliberate
    correctness-first tradeoff (the engine and the oracle share this exact
    code path); a throughput-focused engine would vmap the fold_in +
    categorical over rows inside the jitted step."""
    row = row_logits[:vocab_size].astype(np.float32)
    if temperature <= 0:
        return int(np.argmax(row))
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), rid), pos)
    return int(jax.random.categorical(key, jnp.asarray(row) / temperature))


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------
class Engine:
    """Continuous-batching decode engine over a block-paged KV cache."""

    def __init__(self, model, params, cfg: ServeConfig = ServeConfig(), *,
                 host: HostStore | None = None, pool=None,
                 name: str = "serve"):
        """``host``: pass a runtime's :class:`HostStore` (or
        :class:`TieredStore`) to share one pinned host pool (and its
        traffic counters) with it; by default the engine owns a private
        arena — tiered (host + disk) when ``cfg.host_kv_bytes`` bounds the
        KV mirror, plain otherwise.

        ``pool``: a :class:`~repro.core.pool.HostPool` (DESIGN.md §12).
        The engine takes two leases — ``kv`` (resident KV mirror bytes,
        high priority: these blocks resume blocked requests) and
        ``prefetch`` (opportunistic predictive staging, lowest priority)
        — and *reserves* every host-bound block against its lease before
        the transfer is submitted, so KV bytes can never land past the
        arbitrated share: a refused reservation defers the transfer and
        the recorded pressure drives the engine's own LRU spills on its
        disk stream. Under a pool the budget is the lease's arbitrated
        *grant*, not ``cfg.host_kv_bytes`` — but a nonzero
        ``host_kv_bytes`` carries its sizing intent into the arbiter as
        the kv lease's inviolable floor (``min_bytes``; lease creation
        raises if the floors jointly exceed the pool). The engine keeps
        its own store; the pool — not a shared store object — is the
        sharing surface, so don't pass a lease-attached store as ``host``
        (its occupancy accounting would double-count the engine's
        reservations)."""
        if model.cfg.family not in ("dense", "moe"):
            raise ValueError("serving engine requires a KV-cache family "
                             f"(dense/moe), got {model.cfg.family!r}")
        if cfg.max_len % cfg.block_size:
            raise ValueError("max_len must be a multiple of block_size")
        if pool is not None and getattr(host, "lease", None) is not None:
            raise ValueError("shared store already lease-attached: pool "
                             "arbitration would double-count its bytes")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.name = name            # replica identity (router + diagnostics)
        self._pool = pool
        if host is not None:
            self.host = host
            self._owns_host = False
        elif pool is not None:
            # pooled: budget enforcement is reservation-driven at the
            # engine level (charge-before-submit), so the store itself is
            # unbounded and spills stay engine-driven on the disk stream
            self.host = TieredStore({}, auto_spill=False)
            self._owns_host = True
        elif cfg.host_kv_bytes is not None:
            # spills are engine-driven (auto_spill off) so the disk I/O
            # cost lands on the disk stream's timeline, not inside put
            self.host = TieredStore({}, host_capacity=cfg.host_kv_bytes,
                                    auto_spill=False)
            self._owns_host = True
        else:
            self.host = HostStore({})
            self._owns_host = True
        self._tiered = isinstance(self.host, TieredStore)
        # per-key reservation ledger: key -> (lease, charged bytes). A key
        # appears here from the moment its host-bound transfer is charged
        # until its host copy is spilled/popped — the release always uses
        # the exact bytes that were charged.
        self._charged: dict[tuple[int, int], tuple] = {}
        # revocation pressure signal (set from arbitrary threads via the
        # pool's callback — a leaf lock, never the engine lock, so a
        # same-thread revocation during our own charge cannot deadlock)
        self._revoke_lock = lockcheck.make_lock("ServeEngine.revoke")
        self._revoked_pending = 0
        if pool is not None:
            # drains_via=(): both leases' revocation drains (the disk-
            # stream spill path) only *release* bytes, never charge
            # another lease — the declaration the liveness model checks
            # at runtime (assumption A2, DESIGN.md §14)
            self._kv_lease = pool.lease(
                "kv", min_bytes=cfg.host_kv_bytes or 0, weight=2.0,
                priority=2, on_revoke=self._on_revoke, drains_via=())
            self._pf_lease = pool.lease(
                "prefetch", weight=1.0, priority=0,
                on_revoke=self._on_revoke, drains_via=())
            # statically certify the engine's pool configuration live
            # (DESIGN.md §14): structural passes only — floors jointly
            # feasible, no revocation-drain cycles, no waits-for cycle in
            # the lease/stream resource-allocation graph. When this holds,
            # the no-progress detector below is provably unreachable, so
            # its firing is escalated to certifier unsoundness.
            self._liveness_certificate: LivenessCertificate | None = \
                certify_progress(MemGraph(), self.pool_model())
            self._certified_live = self._liveness_certificate.ok
        else:
            self._kv_lease = self._pf_lease = None
            self._liveness_certificate = None
            self._certified_live = False
        self.reqs: dict[int, Request] = {}
        self._live: set[int] = set()                # rids not yet DONE
        self.stats = ServeStats()
        self.kv: PagedKVCache | None = None
        # single jit wrappers: jax.jit retraces per input shape, so one
        # wrapper covers every batch bucket / prompt pad length
        self._step = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self._next_rid = 0
        self._queue: list[int] = []                 # QUEUED rids, FIFO
        self._swapped: list[int] = []               # SWAPPED rids, FIFO
        self._slots: list[int | None] = []
        self._events: list[tuple] = []              # completions to apply
        self._block_seq: dict[tuple[int, int], int] = {}
        self._seq_counter = 0
        self._seed = cfg.seed
        self._lock = lockcheck.make_lock("ServeEngine")
        self._wake = threading.Condition(self._lock)
        self._d2h: _DmaStream | None = None
        self._h2d: _DmaStream | None = None
        self._disk: _DmaStream | None = None
        self._spill_inflight: set[tuple[int, int]] = set()
        self._prefetch_inflight: set[tuple[int, int]] = set()
        self._idle_spins = 0            # consecutive no-progress stalls
        self._idle_pool_state = None    # last observed (pool used, grant)
        # ---- fleet / fault-injection seams (serve/router.py) ------------
        # on_step: called once per run-loop iteration OFF the engine lock —
        # the router wires it to Heartbeat.beat(replica), so a wedged or
        # paused loop stops beating and the supervisor notices.
        self.on_step = None
        # hard-kill seams: `hard_kill()` (async, from any thread) or
        # `fault_after_steps` (deterministic: raise once this many decode
        # steps have run — the chaos harness's seeded kill instants). Both
        # raise ReplicaKilled out of run(); the finally block still joins
        # every DMA stream, so a killed replica leaks no threads.
        self._killed = False
        self.fault_after_steps: int | None = None
        # stall seam: `pause()` blocks the run loop (heartbeats stop, the
        # loop thread stays alive) until `resume()` — the missed-heartbeat
        # path that is NOT a crash.
        self._pause_evt = threading.Event()
        self._pause_evt.set()

    # ---------------------------------------------- pool lease bookkeeping
    def pool_model(self) -> PoolConfig:
        """The engine's pool population as the static liveness model sees
        it (DESIGN.md §14): every lease a reserving consumer with its
        declared drain routes, co-tenants included as they stand."""
        specs = tuple(LeaseSpec(
            name=l.name, min_bytes=l.min_bytes, weight=l.weight,
            priority=l.priority, discipline="reserving",
            drains_via=tuple(getattr(l, "drains_via", ())))
            for l in self._pool.leases())
        return PoolConfig(capacity=self._pool.capacity, leases=specs,
                          policy=getattr(self._pool.policy, "name",
                                         "static"))

    def _waits_for_locked(self) -> dict:
        """The live waits-for graph, dumped when the no-progress detector
        fires: who holds what, who is blocked on what. Diagnostic only —
        the detector itself is demoted to a certifier-soundness check for
        certified configurations. Leads with the replica name: under a
        router N engines share one traceback consumer, and a wedge report
        that can't say *which* replica wedged is useless."""
        if self._pool is not None:
            leases = {
                l.name: {"grant": l.grant, "used": l.used,
                         "pressure": l.pressure, "overage": l.overage,
                         "refusals": l.refusals}
                for l in self._pool.leases()}
            pool = {"capacity": self._pool.capacity,
                    "used_bytes": self._pool.used_bytes}
        else:
            leases = {}
            pool = None
        with self._revoke_lock:
            revoked = self._revoked_pending
        return {
            "replica": self.name,
            "pool": pool,
            "leases": leases,
            "revoked_pending": revoked,
            "queued": list(self._queue),
            "swapped": list(self._swapped),
            "spill_inflight": sorted(self._spill_inflight),
            "prefetch_inflight": sorted(self._prefetch_inflight),
            "inflight": {r: sorted(self.reqs[r].inflight)
                         for r in self._live if self.reqs[r].inflight},
            "states": {r: self.reqs[r].state for r in self._live},
        }

    def _on_revoke(self, deficit: int) -> None:
        """Pool callback: another consumer's pressure shrank one of our
        grants below its charged bytes. Must stay cheap and lock-light —
        it can fire on any thread, including one already inside the
        engine lock — so it only records the pressure; the scheduler's
        next spill pass drains it through the disk stream (never a
        blocking inline write on the revoker's thread)."""
        with self._revoke_lock:
            self._revoked_pending += deficit

    def _charge_key_locked(self, key, lease, *, urgent: bool = True) -> bool:
        """Reserve one block's bytes on ``lease`` before submitting its
        host-bound transfer. True when the bytes may move (already charged,
        or the reservation fit); False defers the transfer."""
        if self._pool is None:
            return True
        if key in self._charged:
            return True
        n = self.kv.block_nbytes
        if not lease.try_charge(n, urgent=urgent):
            self.stats.lease_deferrals += 1
            return False
        self._charged[key] = (lease, n)
        return True

    def _release_key_locked(self, key) -> None:
        if self._pool is None:
            return
        entry = self._charged.pop(key, None)
        if entry is not None:
            entry[0].release(entry[1])

    def _transfer_key_locked(self, key, dst) -> None:
        """Move a charged key's reservation to ``dst`` (prefetch→kv when a
        staged block's request is admitted: the bytes are already host-
        resident, so the move is forced — dst drains any overage through
        its own spills)."""
        entry = self._charged.get(key)
        if entry is None or entry[0] is dst:
            return
        self._pool.transfer(entry[0], dst, entry[1])
        self._charged[key] = (dst, entry[1])

    # ------------------------------------------------------------- public
    def submit(self, prompt, max_new: int = 32, *,
               rid: int | None = None) -> int:
        """Enqueue a request; returns its id. Tokens emitted will be
        ``min(max_new, max_len - len(prompt) + 1)`` — the first token
        samples from the prefill logits, so a prompt that exactly fills the
        window still yields one token.

        ``rid`` pins the request id (fleet mode: the router allocates ids
        globally, because the sampling key schedule folds the rid — a
        request must keep its id across replicas for its tokens to be
        identical wherever it lands)."""
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1 (the first token always "
                             "samples from the prefill logits)")
        if len(prompt) > self.cfg.max_len:
            raise ValueError(f"prompt of {len(prompt)} tokens exceeds "
                             f"max_len={self.cfg.max_len}")
        with self._lock:        # online use submits while run() is draining
            if rid is None:
                rid = self._next_rid
            elif rid in self.reqs:
                raise ValueError(f"rid {rid} already present on replica "
                                 f"{self.name!r}")
            self._next_rid = max(self._next_rid, rid + 1)
            self.reqs[rid] = Request(rid, prompt, max_new,
                                     t_submit=time.monotonic())
            self._live.add(rid)
            self._queue.append(rid)
            self._wake.notify_all()     # a stalled run() picks it up now
        return rid

    def hard_kill(self) -> None:
        """Kill the replica from any thread: the run loop raises
        :class:`ReplicaKilled` at its next iteration (a stalled loop wakes
        within its 0.1 s wait tick). Device state is considered lost; the
        host/disk tiers stay intact for :meth:`drain_tickets`."""
        with self._lock:
            self._killed = True
            self._wake.notify_all()

    def pause(self) -> None:
        """Stall seam: block the run loop (and its heartbeats) without
        killing it — the silent-wedge failure mode a supervisor must
        distinguish from a crash. :meth:`resume` releases it."""
        self._pause_evt.clear()

    def resume(self) -> None:
        self._pause_evt.set()

    def close(self) -> None:
        """Release the engine-owned store's backing resources (the disk
        tier's temp directory and spilled blobs). Idempotent; a shared
        ``host`` store passed in by the caller is left untouched. A
        long-lived service should close the engine when retiring it."""
        if self._owns_host:
            self.host.close()
        if self._pool is not None:
            # retire our leases: their shares return to the pool (any
            # still-charged bytes are dropped with the store)
            self._kv_lease.close()
            self._pf_lease.close()
            self._charged.clear()

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def release(self, rid: int) -> None:
        """Drop a finished request's record. Finished requests otherwise
        stay in ``reqs`` so callers can read their tokens; a long-lived
        online engine should release them once consumed."""
        with self._lock:
            req = self.reqs.get(rid)
            if req is not None and req.state != DONE:
                raise ValueError(f"request {rid} is {req.state}, not done")
            self.reqs.pop(rid, None)

    # ------------------------------------- KV migration (DESIGN.md §16)
    def _warm_payload_locked(self, req: Request) -> "list[dict] | None":
        """Collect a SWAPPED request's complete block set from the host/
        disk tiers (``peek_offload``: no restaging, no traffic counted).
        ``None`` unless *every* block is present and quiescent — a warm
        ticket is all blocks or nothing, the export face of all-or-nothing
        admission."""
        if req.state != SWAPPED or req.inflight or req.pending_reload:
            return None
        # the disk tier stores raw bytes and restores extended dtypes
        # (bfloat16, float8_*) as anonymous void words — relabel them from
        # the cache's own leaves so the ticket carries true dtypes and the
        # destination's leaf-spec validation sees what it expects. A view,
        # never a cast: the bytes are already exact.
        dtypes = {k: np.dtype(leaf.dtype)
                  for k, leaf in self.kv.cache.items()}
        blocks = []
        for blk in range(self.kv.n_token_blocks(req.pos)):
            data = self.host.peek_offload((req.rid, blk))
            if data is None:
                return None
            fixed = {}
            for k, v in data.items():
                arr = np.asarray(v)
                want = dtypes.get(k)
                if (want is not None and arr.dtype != want
                        and arr.dtype.kind == "V"
                        and arr.dtype.itemsize == want.itemsize):
                    arr = arr.view(want)
                fixed[k] = arr
            blocks.append(fixed)
        return blocks

    def _ticket_locked(self, req: Request,
                       blocks: "list[dict] | None") -> MigrationTicket:
        return MigrationTicket(
            rid=req.rid, prompt=list(req.prompt), out=list(req.out),
            max_new=req.max_new, pos=req.pos, last=req.last,
            block_size=self.cfg.block_size,
            t_submit=req.t_submit, t_first=req.t_first, blocks=blocks)

    def drain_tickets(self) -> list[MigrationTicket]:
        """Checkpoint every live request at its last emitted token for
        migration off this replica — the post-kill drain. SWAPPED requests
        whose full block set survives on the host/disk tiers (owned by the
        host process, which outlives the dead worker) become *warm*
        tickets; everything else lost its device state with the worker and
        goes *cold* (the destination re-prefills ``prompt + out``).
        Read-only on the source: the caller retires it with ``close()``."""
        tickets = []
        with self._lock:
            for rid in sorted(self._live):
                req = self.reqs[rid]
                blocks = (self._warm_payload_locked(req)
                          if self.kv is not None else None)
                tickets.append(self._ticket_locked(req, blocks))
        return tickets

    def export_one_swapped(self) -> MigrationTicket | None:
        """Live rebalance: detach the *tail* of the swapped FIFO (the
        request that would wait longest for a local slot) as a warm
        ticket, releasing its local bytes, lease charges, and seq entries.
        ``None`` when no swapped request has a complete, quiescent block
        set (in-flight spills/prefetches defer the export — never race a
        stream for a block)."""
        with self._lock:
            if self.kv is None:
                return None
            for i in range(len(self._swapped) - 1, -1, -1):
                rid = self._swapped[i]
                req = self.reqs[rid]
                keys = [(rid, b)
                        for b in range(self.kv.n_token_blocks(req.pos))]
                if any(k in self._spill_inflight
                       or k in self._prefetch_inflight for k in keys):
                    continue
                blocks = self._warm_payload_locked(req)
                if blocks is None:
                    continue
                ticket = self._ticket_locked(req, blocks)
                self._swapped.pop(i)
                self._live.discard(rid)
                self.reqs.pop(rid)
                for k in keys:
                    self.host.pop_offload(k)
                    self._release_key_locked(k)
                    self._block_seq.pop(k, None)
                self.stats.migrations_out += 1
                self._wake.notify_all()   # run() re-checks its live set
                return ticket
        return None

    def load(self) -> tuple[int, int]:
        """Placement signals for a router: (live request count, resident +
        committed KV tokens). Cheap and exact under the engine lock."""
        with self._lock:
            return (len(self._live),
                    sum(max(self.reqs[r].pos, len(self.reqs[r].prompt))
                        for r in self._live))

    def import_migration(self, ticket: MigrationTicket) -> None:
        """Admit a warm ticket in SWAPPED state: validate every payload
        against this replica's :meth:`PagedKVCache.leaf_spec`, reserve the
        whole block set against the kv lease, then land the bytes in the
        host tier — **all or nothing**: a :class:`MigrationRefused` leaves
        no byte, charge, or request record behind, so the §12 pool
        invariants and the §14 liveness assumptions hold on the
        destination exactly as if the request had been swapped out
        locally. The request resumes through the ordinary swap-in path;
        the imported blocks are bit-identical to what ``restore_slot``
        would have reloaded on the source, so its continuation is
        token-exact."""
        if ticket.blocks is None:
            raise MigrationRefused(
                f"ticket {ticket.rid} is cold (no KV payload): resubmit "
                "prompt+out for re-prefill instead")
        if ticket.block_size != self.cfg.block_size:
            raise MigrationRefused(
                f"block_size mismatch: ticket has {ticket.block_size}, "
                f"replica {self.name!r} serves {self.cfg.block_size}")
        with self._lock:
            if ticket.rid in self.reqs:
                raise MigrationRefused(
                    f"rid {ticket.rid} already present on replica "
                    f"{self.name!r}")
            if self.kv is None:
                # a fresh replica has no cache yet; geometry (block bytes,
                # leaf spec) is needed before any payload can be validated
                bucket = self._bucket_for(1)
                self.kv = PagedKVCache(self.model, bucket, self.cfg.max_len,
                                       block_size=self.cfg.block_size)
                self._slots = [None] * bucket
            n_blocks = self.kv.n_token_blocks(ticket.pos)
            if len(ticket.blocks) != n_blocks:
                raise MigrationRefused(
                    f"ticket {ticket.rid} carries {len(ticket.blocks)} "
                    f"blocks for pos={ticket.pos} (want {n_blocks})")
            spec = self.kv.leaf_spec()
            for blk, data in enumerate(ticket.blocks):
                if set(data) != set(spec):
                    raise MigrationRefused(
                        f"ticket {ticket.rid} block {blk}: leaves "
                        f"{sorted(data)} != spec {sorted(spec)}")
                for leaf, (shape, dtype) in spec.items():
                    arr = data[leaf]
                    if tuple(arr.shape) != shape or str(arr.dtype) != dtype:
                        raise MigrationRefused(
                            f"ticket {ticket.rid} block {blk} leaf "
                            f"{leaf!r}: {arr.shape}/{arr.dtype} != "
                            f"{shape}/{dtype}")
            charged_now = []
            for blk in range(n_blocks):
                if self._charge_key_locked((ticket.rid, blk),
                                           self._kv_lease):
                    charged_now.append((ticket.rid, blk))
                else:
                    for key in charged_now:
                        self._release_key_locked(key)
                    raise MigrationRefused(
                        f"replica {self.name!r} cannot reserve "
                        f"{n_blocks} blocks for ticket {ticket.rid}: "
                        "kv lease refused the set")
            req = Request(ticket.rid, list(ticket.prompt), ticket.max_new,
                          out=list(ticket.out), state=SWAPPED,
                          pos=ticket.pos, last=ticket.last,
                          mirrored=set(range(n_blocks)),
                          t_submit=ticket.t_submit, t_first=ticket.t_first)
            for blk, data in enumerate(ticket.blocks):
                key = (ticket.rid, blk)
                self.host.put_offload(key, data)
                self._block_seq[key] = self._seq_counter
                self._seq_counter += 1
            self.reqs[ticket.rid] = req
            self._live.add(ticket.rid)
            self._swapped.append(ticket.rid)
            self._next_rid = max(self._next_rid, ticket.rid + 1)
            self.stats.migrations_in += 1
            self._wake.notify_all()

    def generate(self, prompts: list[list[int]], *, max_new: int = 32,
                 seed: int | None = None) -> list[list[int]]:
        """Submit ``prompts`` and run the queue to completion (the batch
        API the tests drive; online use is ``submit()`` + ``run()``)."""
        rids = [self.submit(p, max_new) for p in prompts]
        self.run(seed=seed)
        return [list(self.reqs[r].out) for r in rids]

    def run(self, *, seed: int | None = None) -> ServeStats:
        """Drain the queue: admit → prefill → decode, with offload/reload
        riding on DMA streams, until every submitted request is DONE.

        Returns once the live set is observed empty under the lock: a
        request submitted concurrently after that instant waits for the
        next ``run()`` — a long-lived online service keeps a run loop (or
        re-invokes ``run()`` after submitting)."""
        if seed is not None:
            self._seed = seed
        cfg = self.cfg
        pol = get_reload_policy(cfg.reload_policy, seed=self._seed)
        pol.prepare(self)
        def _on_batch(n: int) -> None:      # lock held (stream cond)
            self.stats.fused_dma_batches += 1

        fuse_kw = dict(fuse=cfg.fuse_dma, max_fuse=cfg.max_fuse_dma,
                       on_batch=_on_batch)
        self._d2h = _DmaStream(D2H, cfg.d2h_bw, cfg.dma_latency, pol,
                               self._service_d2h, self._lock, **fuse_kw)
        self._h2d = _DmaStream(H2D, cfg.h2d_bw, cfg.dma_latency, pol,
                               self._service_h2d, self._lock, **fuse_kw)
        streams = [self._d2h, self._h2d]
        if self._tiered:
            # the disk tier's own engine class: spills/loads never occupy
            # (or wait behind) the h2d/d2h DMA lanes
            self._disk = _DmaStream(DISK, cfg.disk_bw, cfg.dma_latency, pol,
                                    self._service_disk, self._lock,
                                    **fuse_kw)
            streams.append(self._disk)
        for stream in streams:
            stream.start()
        try:
            while True:
                if self.on_step is not None:
                    # off the lock: the heartbeat table is a leaf lock and
                    # the callback must never nest inside the engine lock
                    self.on_step(self)
                self._pause_evt.wait()
                with self._lock:
                    if self._killed or (
                            self.fault_after_steps is not None
                            and self.stats.decode_steps
                            >= self.fault_after_steps):
                        raise ReplicaKilled(
                            f"replica {self.name!r} hard-killed after "
                            f"{self.stats.decode_steps} decode steps")
                    for stream in streams:
                        if stream.error is not None:
                            raise stream.error
                    self._apply_events_locked()
                    admits = self._plan_admissions_locked()
                if admits:
                    self._prefill_admit(admits)
                with self._lock:
                    self._schedule_offload_locked()
                    self._schedule_spill_locked()
                    self._schedule_prefetch_locked()
                    self._schedule_preempt_locked()
                    active = [(s, r) for s, r in enumerate(self._slots)
                              if r is not None
                              and self.reqs[r].state == RUNNING]
                    if not self._live:     # atomic with submit()'s mutation
                        break
                if active:
                    self._decode_once(active)
                else:
                    self._stall_wait()
        finally:
            with self._lock:
                for stream in streams:
                    stream.shutdown()
                self._spill_inflight.clear()
                self._prefetch_inflight.clear()
            for stream in streams:
                stream.join()
        return self.stats

    # -------------------------------------------------- DMA service hooks
    # (run on stream threads after the simulated wire time; they only read
    # device blocks and post events — the main loop owns cache mutation)
    def _service_d2h(self, tr: _Transfer) -> None:
        with self._lock:
            req = self.reqs.get(tr.rid)
            if req is None:                           # released mid-flight
                self._release_key_locked((tr.rid, tr.blk))
                self._wake.notify_all()
                return
            if req.state == DONE or req.slot < 0:
                req.inflight.discard(tr.blk)
                self._release_key_locked((tr.rid, tr.blk))
                self._wake.notify_all()
                return
            snapshot = self.kv.cache                  # immutable leaf refs
            slot = req.slot
        # the actual copy runs OFF the engine lock so it overlaps under
        # decode like a real copy engine; the slot cannot be reassigned
        # while this block is in flight (swap-out completes only once
        # `inflight` drains), so only completion can invalidate it
        data = self.kv.read_block(slot, tr.blk, cache=snapshot)
        with self._lock:
            req.inflight.discard(tr.blk)
            if req.state != DONE and req.slot == slot:
                self.host.put_offload((tr.rid, tr.blk), data)
                # counted here, not as a HostStore delta: a runtime sharing
                # the store must not have its traffic attributed to serving
                self.stats.offload_bytes += tr.nbytes
                req.mirrored.add(tr.blk)
                if req.state == SWAPPING and not req.inflight:
                    self._events.append(("swap-done", tr.rid))
            else:
                # payload dropped: the reservation made at submit time has
                # nothing backing it any more
                self._release_key_locked((tr.rid, tr.blk))
            self._wake.notify_all()

    def _service_h2d(self, tr: _Transfer) -> None:
        data = self.host.get_offload((tr.rid, tr.blk))
        with self._lock:
            self.stats.reload_bytes += tr.nbytes
            req = self.reqs.get(tr.rid)
            if req is not None:
                req.inflight.discard(tr.blk)
                self._events.append(("reload", tr.rid, tr.blk, data))
            self._wake.notify_all()

    def _service_disk(self, tr: _Transfer) -> None:
        """Disk-stream service: ``spill`` moves a cold host block to the
        file tier, ``load`` stages a disk block back into host RAM and
        chains the h2d hop (the pipelined two-hop reload). Runs after the
        simulated disk wire time. Load file I/O happens off the engine
        lock (the store has its own lock) and overlaps under decode; the
        spill's small block write deliberately stays *under* the lock —
        admissions hold the same lock, so a swap-in can never claim a
        block mid-spill and drag the disk read onto the h2d lane via
        read-through. One block's write is cheap; the invariant is not."""
        key = (tr.rid, tr.blk)
        if tr.disk_op == "prefetch":
            # predictive staging for a request still waiting in the swapped
            # queue: bring the blob host-side so its eventual resume is a
            # single h2d hop. The request may have finished or been
            # released mid-flight (blob popped) — then there is nothing to
            # stage and the prefetch is a benign no-op. The tier check is
            # exact here: all disk ops serialize on this one stream, so a
            # block the reactive path already staged (and counted) is seen
            # host-resident and not double-counted.
            try:
                staged = self.host.tier_of(key) == "disk"
                if staged:
                    self.host.load(key)
            except KeyError:
                staged = False
            with self._lock:
                self._prefetch_inflight.discard(key)
                req = self.reqs.get(tr.rid)
                if staged and (req is None or req.state == DONE
                               or key not in self._block_seq):
                    # the request retired while the blob was being read:
                    # _finish_locked already popped every copy, so the
                    # freshly staged bytes are an orphan nothing would
                    # ever release — undo the resurrection
                    self.host.pop_offload(key)
                    staged = False
                if (self._pool is not None
                        and self._charged.get(key, (None,))[0]
                        is self._pf_lease
                        and self.host.tier_of(key) != "host"):
                    # the reservation has no host bytes behind it (blob
                    # vanished mid-flight, or the staging was undone):
                    # give the prefetch share back
                    self._release_key_locked(key)
                if staged:
                    self.stats.disk_load_bytes += tr.nbytes
                    self.stats.prefetch_bytes += tr.nbytes
                if req is not None and tr.blk in req.pending_reload:
                    # the request was admitted while this prefetch was in
                    # flight and its swap-in deferred to us: chain the h2d
                    # hop (or, if the blob vanished under a live request —
                    # which pop paths forbid, but stay safe — fall back to
                    # the reactive two-hop load)
                    if staged or self.host.tier_of(key) == "host":
                        self._h2d.submit(_Transfer(H2D, tr.rid, tr.blk,
                                                   tr.seq, tr.nbytes))
                    else:
                        self._submit_transfer_locked(self._disk, req,
                                                     tr.blk, disk_op="load")
                self._wake.notify_all()
            return
        if tr.disk_op == "spill":
            with self._lock:
                self._spill_inflight.discard(key)
                req = self.reqs.get(tr.rid)
                ok = (req is not None and req.state != DONE
                      and tr.blk not in req.pending_reload
                      and tr.blk not in req.inflight)
                if ok:
                    # under the engine lock: admissions also hold it, so a
                    # swap-in can never claim the block between this check
                    # and the spill (which would push the disk read onto
                    # the h2d lane via read-through). The write itself is
                    # one small block; the wire time was slept off-lock.
                    if self._pool is not None:
                        # mark this thread as the kv lease's revocation
                        # drain (assumption A2): the spill may only
                        # release — a charge against any undeclared lease
                        # in here would be a blocking edge the liveness
                        # model never saw, and the pool rejects it loudly
                        with self._pool.draining(self._kv_lease):
                            self.stats.disk_spill_bytes += \
                                self.host.spill(key)
                    else:
                        self.stats.disk_spill_bytes += self.host.spill(key)
                    # the host copy moved down a tier: its reservation is
                    # what the arbiter has been waiting for
                    self._release_key_locked(key)
                self._wake.notify_all()
            return
        # load: read-through staging is idempotent, so a racy spill/reload
        # interleaving can only change timing, never bytes
        self.host.load(key)
        with self._lock:
            self.stats.disk_load_bytes += tr.nbytes
            req = self.reqs.get(tr.rid)
            if req is not None and tr.blk in req.pending_reload:
                self._h2d.submit(_Transfer(H2D, tr.rid, tr.blk, tr.seq,
                                           tr.nbytes))
            elif req is not None:        # swap-in abandoned mid-flight
                req.inflight.discard(tr.blk)
            self._wake.notify_all()

    # ------------------------------------------------------ event applies
    def _apply_events_locked(self) -> None:
        for ev in self._events:
            if ev[0] == "reload":
                _, rid, blk, data = ev
                req = self.reqs.get(rid)
                if req is None or req.state != RELOADING:
                    continue
                req.reload_data[blk] = data
                req.pending_reload.discard(blk)
                if not req.pending_reload:
                    # one per-leaf scatter for the whole resume, not one
                    # full-cache copy per block
                    self.kv.restore_slot(
                        req.slot, [req.reload_data[b]
                                   for b in sorted(req.reload_data)])
                    req.reload_data.clear()
                    req.state = RUNNING
                    req.quantum = 0
                    # the tail block keeps growing after resume: its host
                    # copy is stale from now on and must re-offload (every
                    # cold block's copy stays valid — reuse_host_copy)
                    if req.pos % self.cfg.block_size:
                        tail = req.pos // self.cfg.block_size
                        req.mirrored.discard(tail)
                        self.host.pop_offload((rid, tail))
                        self._release_key_locked((rid, tail))
            elif ev[0] == "swap-done":
                req = self.reqs.get(ev[1])
                if req is None or req.state != SWAPPING:
                    continue
                self.kv.drop_slot(req.slot)
                self._slots[req.slot] = None
                req.slot = -1
                req.state = SWAPPED
                self._swapped.append(req.rid)
        self._events.clear()

    # ----------------------------------------------------- admission path
    def _bucket_for(self, n: int) -> int:
        for b in self.cfg.batch_buckets:
            if n <= b:
                return b
        return self.cfg.batch_buckets[-1]

    def _plan_admissions_locked(self) -> list[tuple[int, int]]:
        """Assign free slots: swapped requests first (schedule their
        reloads), then fresh requests (returned for batched prefill).
        Grows the cache to the next batch bucket when demand requires."""
        want = len(self._swapped) + len(self._queue)
        if want == 0:
            return []
        occupied = sum(r is not None for r in self._slots)
        desired = self._bucket_for(occupied + want)
        if self.kv is None:
            self.kv = PagedKVCache(
                self.model, desired, self.cfg.max_len,
                block_size=self.cfg.block_size)
            self._slots = [None] * desired
        elif desired > self.kv.bucket:
            self.kv.grow(desired)
            self._slots.extend([None] * (desired - len(self._slots)))
        free = [s for s, r in enumerate(self._slots) if r is None]

        # fresh requests admit before swapped resumes: a preemption's whole
        # point is to let waiters in, so the preempted request must not
        # reclaim its slot ahead of them (a production engine would add an
        # aging term here to bound swapped-out residence)
        admits: list[tuple[int, int]] = []
        while free and self._queue:
            rid = self._queue.pop(0)
            slot = free.pop(0)
            self._slots[slot] = rid
            self.reqs[rid].slot = slot
            admits.append((slot, rid))

        # swap-ins: host-resident blocks reload through the h2d stream;
        # disk-resident blocks take the pipelined two-hop chain (disk
        # stream load first, h2d hop chained on its completion). A block
        # whose prefetch is already queued/in service is NOT resubmitted —
        # the prefetch handler chains the h2d hop itself — so the disk
        # stream never sleeps a wire time staging the same blob twice.
        while free and self._swapped:
            rid = self._swapped[0]
            req = self.reqs[rid]
            blocks = range(self.kv.n_token_blocks(req.pos))
            if self._pool is not None:
                # reserve the resume's host-side staging before taking the
                # slot: disk-resident blocks land in host RAM on their way
                # up, and admitting a request whose staging cannot be
                # charged would burst past the arbitrated share. A refusal
                # defers the admission (FIFO preserved: later swapped
                # requests wait too) and the recorded pressure drives the
                # spill stream until the resume fits.
                charged_now = []
                ok = True
                for blk in blocks:
                    key = (rid, blk)
                    if (key in self._charged
                            or key in self._prefetch_inflight
                            or not self._tiered
                            or self.host.tier_of(key) != "disk"):
                        continue
                    if self._charge_key_locked(key, self._kv_lease):
                        charged_now.append(key)
                    else:
                        ok = False
                        break
                if not ok:
                    for key in charged_now:
                        self._release_key_locked(key)
                    break
                for blk in blocks:
                    # staged (or in-flight) prefetches now back a resuming
                    # request: their bytes outrank opportunistic staging,
                    # so the reservation migrates prefetch -> kv
                    self._transfer_key_locked((rid, blk), self._kv_lease)
            self._swapped.pop(0)
            slot = free.pop(0)
            self._slots[slot] = rid
            req.slot = slot
            req.state = RELOADING
            req.pending_reload = set(blocks)
            for blk in blocks:
                if (rid, blk) in self._prefetch_inflight:
                    req.inflight.add(blk)   # h2d chains on the prefetch
                elif (self._tiered
                        and self.host.tier_of((rid, blk)) == "disk"):
                    self._submit_transfer_locked(self._disk, req, blk,
                                                 disk_op="load")
                else:
                    self._submit_transfer_locked(self._h2d, req, blk)
        return admits

    def _submit_transfer_locked(self, stream: _DmaStream, req: Request,
                                blk: int, *, disk_op: str = "") -> None:
        key = (req.rid, blk)
        if key not in self._block_seq:
            self._block_seq[key] = self._seq_counter
            self._seq_counter += 1
        req.inflight.add(blk)
        stream.submit(_Transfer(stream.kind, req.rid, blk,
                                self._block_seq[key], self.kv.block_nbytes,
                                disk_op=disk_op))

    # ------------------------------------------------------------ prefill
    def _prefill_admit(self, admits: list[tuple[int, int]]) -> None:
        """One batched forward over the admitted prompts (padded to a
        (bucket, block-aligned-length) static shape), then scatter the K/V
        into the admitted slots and sample each request's first token."""
        cfg = self.cfg
        reqs = [self.reqs[rid] for _, rid in admits]
        max_p = max(len(r.prompt) for r in reqs)
        s_pad = min(-(-max_p // cfg.block_size) * cfg.block_size,
                    cfg.max_len)
        b_pad = self._bucket_for(len(reqs))
        toks = np.zeros((b_pad, s_pad), np.int32)
        lengths = np.ones((b_pad,), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt
            lengths[i] = len(r.prompt)
        t0 = time.perf_counter()
        logits, kv = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lengths))
        logits_np = np.asarray(logits, np.float32)
        self.stats.prefill_time += time.perf_counter() - t0
        with self._lock:
            rows = jax.tree.map(lambda a: a[:, :len(reqs)], kv)
            self.kv.scatter_prefill([slot for slot, _ in admits], rows)
            for i, (slot, rid) in enumerate(admits):
                req = self.reqs[rid]
                req.pos = len(req.prompt)
                req.state = RUNNING
                req.quantum = 0
                self.stats.prefill_tokens += req.pos
                self.stats.kv_bytes_written += int(
                    req.pos * self.kv.token_nbytes)
                self._emit_locked(req, logits_np[i])

    def _emit_locked(self, req: Request, row_logits: np.ndarray) -> None:
        tok = _sample_token(row_logits, seed=self._seed, rid=req.rid,
                            pos=req.pos, temperature=self.cfg.temperature,
                            vocab_size=self.model.cfg.vocab_size)
        req.out.append(tok)
        req.last = tok
        if req.t_first == 0.0:      # a migrated request keeps its original
            req.t_first = time.monotonic()   # first-token stamp (ticket)
        self.stats.tokens += 1
        if len(req.out) >= req.max_new or req.pos >= self.cfg.max_len:
            self._finish_locked(req)

    def _finish_locked(self, req: Request) -> None:
        req.state = DONE
        self._live.discard(req.rid)
        if req.slot >= 0:
            self._slots[req.slot] = None
            req.slot = -1
        for blk in req.mirrored:
            self.host.pop_offload((req.rid, blk))
            self._release_key_locked((req.rid, blk))
        req.mirrored.clear()
        req.pending_reload.clear()
        for blk in range(self.kv.n_token_blocks(req.pos)):
            self._block_seq.pop((req.rid, blk), None)
        # in-flight d2h mirrors see state == DONE and drop their payload
        # (and release their reservations); in-flight prefetches release
        # theirs on completion when no host bytes landed

    # ------------------------------------------------- offload scheduling
    def _schedule_offload_locked(self) -> None:
        """Mirror cold blocks of running rows to the host store (eager d2h
        that overlaps under decode; makes a later swap-out nearly free)."""
        cfg = self.cfg
        if not cfg.offload or self.kv is None:
            return
        for slot, rid in enumerate(self._slots):
            if rid is None:
                continue
            req = self.reqs[rid]
            if req.state != RUNNING:
                continue
            cold = max(req.pos - cfg.hot_window, 0) // cfg.block_size
            cap = int(cfg.offload_fraction
                      * self.kv.n_token_blocks(req.pos))
            for blk in range(min(cold, cap)):
                if blk not in req.mirrored and blk not in req.inflight:
                    # shared pool: reserve before the bytes move; a refusal
                    # defers this (and every later) mirror until the spill
                    # stream frees share — eager mirroring is optional
                    # work, never worth bursting the budget for
                    if not self._charge_key_locked((rid, blk),
                                                   self._kv_lease):
                        return
                    self._submit_transfer_locked(self._d2h, req, blk)

    def _schedule_spill_locked(self) -> None:
        """Second threshold of the hierarchy: once the host KV mirror
        passes ``host_kv_bytes``, push the least-recently-used mirrored
        blocks down to the disk tier. Runs on the dedicated disk stream
        (never the h2d/d2h DMA lanes); victim choice is LRU because at
        runtime the request future is unknown — the serving counterpart of
        the compiler's Belady-over-the-schedule spills."""
        if not self._tiered or self._disk is None or self.kv is None:
            return
        blk_n = self.kv.block_nbytes
        if self._pool is not None:
            # arbitrated budget: drain (a) bytes held past the current
            # grants — a revocation leaves `overage` and fires the
            # pressure callback — and (b) the recorded deficit of refused
            # reservations, so deferred transfers eventually fit. Spills
            # already in flight count as freed.
            with self._revoke_lock:
                if self._revoked_pending:
                    self.stats.revocations += 1
                    self._revoked_pending = 0
            budget = (self._kv_lease.overage + self._kv_lease.pressure
                      + self._pf_lease.overage + self._pf_lease.pressure
                      - len(self._spill_inflight) * blk_n)
        else:
            cap = self.cfg.host_kv_bytes
            if cap is None:
                return
            budget = (self.host.resident_bytes
                      - len(self._spill_inflight) * blk_n - cap)
        if budget <= 0:
            return
        for key in self.host.lru_keys():
            if budget <= 0:
                break
            if (key not in self._block_seq or key in self._spill_inflight
                    or key in self._prefetch_inflight):
                continue                    # not a serving block / queued
            rid, blk = key
            req = self.reqs.get(rid)
            if (req is None or req.state == RELOADING
                    or blk in req.inflight or blk in req.pending_reload):
                continue
            self._spill_inflight.add(key)
            self._disk.submit(_Transfer(DISK, rid, blk,
                                        self._block_seq[key],
                                        self.kv.block_nbytes,
                                        disk_op="spill"))
            budget -= self.kv.block_nbytes

    def _schedule_prefetch_locked(self) -> None:
        """NEO-style predictive reload: the swapped queue *is* the resume
        schedule, so stage the next-scheduled requests' disk-resident
        blocks back into host RAM while decode runs — by admission time
        only the h2d hop remains. Strictly headroom-bounded: a prefetch
        never pushes occupancy past ``host_kv_bytes`` (it could only thrash
        with the LRU spiller), and prefetch/spill never race on one block
        (each skips keys the other has in flight)."""
        cfg = self.cfg
        cap = cfg.host_kv_bytes
        if (not cfg.prefetch_swapped or not self._tiered
                or self._disk is None or self.kv is None):
            return
        if self._pool is None and cap is None:
            return
        if self._pool is None:
            # reserve headroom for everything already headed host-side:
            # our own in-flight prefetches, resuming requests' pending
            # two-hop reloads (their disk legs stage into the host arena
            # when they land), and in-flight d2h offload mirrors
            # (put_offload on arrival). Conservative for blocks already
            # staged or h2d-only — over-reserving only makes the
            # prefetcher more cautious, never an over-commit
            reserved = len(self._prefetch_inflight) + sum(
                len(self.reqs[r].pending_reload | self.reqs[r].inflight)
                for r in self._live)
            headroom = (cap - self.host.resident_bytes
                        - reserved * self.kv.block_nbytes)
        for rid in self._swapped:
            if self._pool is None and headroom < self.kv.block_nbytes:
                return
            req = self.reqs.get(rid)
            if req is None:
                continue
            for blk in range(self.kv.n_token_blocks(req.pos)):
                if self._pool is None and headroom < self.kv.block_nbytes:
                    return
                key = (rid, blk)
                if (key in self._prefetch_inflight
                        or key in self._spill_inflight
                        or self.host.tier_of(key) != "disk"):
                    continue
                if self._pool is not None:
                    if self._kv_lease.pressure > 0:
                        # mandatory work is waiting on the spill stream:
                        # staging now would hand the spiller fresh LRU
                        # victims and churn the disk stream in a loop
                        # (stage → spill-for-pressure → restage) without
                        # ever helping the blocked resume
                        return
                    # the prefetch lease IS the headroom: an opportunistic
                    # (non-urgent) reservation that never records
                    # pressure — a refusal just means no staging now
                    if not self._charge_key_locked(key, self._pf_lease,
                                                   urgent=False):
                        return
                self._prefetch_inflight.add(key)
                self._disk.submit(_Transfer(
                    DISK, rid, blk, self._block_seq.get(key, 0),
                    self.kv.block_nbytes, disk_op="prefetch"))
                if self._pool is None:
                    headroom -= self.kv.block_nbytes

    def _schedule_preempt_locked(self) -> None:
        """Swap out requests that exhausted their decode quantum while
        others wait — the continuous-batching fairness lever, and the
        source of genuine reload traffic."""
        cfg = self.cfg
        if not cfg.preempt_every or self.kv is None:
            return
        waiting = len(self._queue) + len(self._swapped)
        for slot, rid in enumerate(self._slots):
            if waiting <= 0:
                return
            if rid is None:
                continue
            req = self.reqs[rid]
            if req.state != RUNNING or req.quantum < cfg.preempt_every:
                continue
            if len(req.out) >= req.max_new - 1:     # about to finish anyway
                continue
            pending = [blk for blk in range(self.kv.n_token_blocks(req.pos))
                       if blk not in req.mirrored and blk not in req.inflight]
            if self._pool is not None:
                # a swap-out must mirror *every* unmirrored block — all or
                # nothing. Reserve the full set up front; if the share
                # cannot take it, skip preempting this request this round
                # (the recorded pressure spills other blocks; we retry on
                # the next pass) rather than strand it half-swapped
                charged_now = []
                ok = True
                for blk in pending:
                    key = (rid, blk)
                    if key in self._charged:
                        continue
                    if self._charge_key_locked(key, self._kv_lease):
                        charged_now.append(key)
                    else:
                        ok = False
                        break
                if not ok:
                    for key in charged_now:
                        self._release_key_locked(key)
                    continue
            req.state = SWAPPING
            self.stats.swaps += 1
            waiting -= 1
            for blk in pending:
                self._submit_transfer_locked(self._d2h, req, blk)
            if not req.inflight:                    # everything was mirrored
                self._events.append(("swap-done", rid))

    # -------------------------------------------------------------- decode
    def _decode_once(self, active: list[tuple[int, int]]) -> None:
        with self._lock:
            self._idle_spins = 0               # decode is forward progress
            bucket = self.kv.bucket
            cache = self.kv.cache
            toks = np.zeros((bucket, 1), np.int32)
            lens = np.zeros((bucket,), np.int32)
            mask = np.zeros((bucket,), bool)
            for slot, rid in active:
                req = self.reqs[rid]
                toks[slot, 0] = req.last
                lens[slot] = req.pos
                mask[slot] = True
        t0 = time.perf_counter()
        logits, new_cache = self._step(self.params, cache, jnp.asarray(toks),
                                       jnp.asarray(lens), jnp.asarray(mask))
        logits_np = np.asarray(logits, np.float32)
        self.stats.decode_time += time.perf_counter() - t0
        self.stats.decode_steps += 1
        with self._lock:
            self.kv.cache = new_cache
            for slot, rid in active:
                req = self.reqs[rid]
                req.pos += 1
                req.quantum += 1
                self.stats.kv_bytes_written += int(self.kv.token_nbytes)
                self.stats.decode_tokens += 1
                self._emit_locked(req, logits_np[slot])

    def _stall_wait(self) -> None:
        """Nothing resident to decode: wait for a DMA completion event."""
        t0 = time.perf_counter()
        with self._wake:
            busy = (self._events or self._d2h.pending or self._h2d.pending
                    or (self._disk is not None and self._disk.pending)
                    or self._spill_inflight or self._prefetch_inflight
                    or any(self.reqs[r].inflight for r in self._live))
            if not busy and not self._queue and not self._swapped:
                raise RuntimeError(
                    f"serving scheduler wedged on replica {self.name!r} — "
                    f"live waits-for graph: {self._waits_for_locked()}")
            if busy:
                self._idle_spins = 0
            elif self._pool is not None:
                # deferred admissions with nothing in flight: room must
                # come from our own spills or from a co-consumer draining
                # its share. Any movement of pool occupancy or our grant
                # is progress (the other consumer may just be slow — not
                # deadlocked), so the counter resets on it; only a pool
                # that is provably static gets the loud failure.
                state = (self._pool.used_bytes, self._kv_lease.grant)
                if state != self._idle_pool_state:
                    self._idle_pool_state = state
                    self._idle_spins = 0
                self._idle_spins += 1
                if self._idle_spins > 100:
                    waits = self._waits_for_locked()
                    if self._certified_live:
                        # DESIGN.md §14 assumption A4: this configuration
                        # was statically proven stall-free, so reaching
                        # here means the certifier is unsound or a
                        # blocking edge escaped the model — not an
                        # operational deadlock to shrug at
                        raise LivenessModelError(
                            "no-progress detector fired on replica "
                            f"{self.name!r} under a liveness-certified "
                            "pool configuration (statically unreachable): "
                            "the certifier is unsound or the runtime grew "
                            "a blocking edge outside the model — live "
                            f"waits-for graph: {waits}")
                    raise RuntimeError(
                        f"shared-pool deadlock on replica {self.name!r}: "
                        "swapped requests cannot reserve their resume "
                        "staging, no spillable bytes remain, and no other "
                        "consumer is releasing any — live waits-for "
                        f"graph: {waits}")
            self._wake.wait(timeout=0.1)
        self.stats.stall_time += time.perf_counter() - t0


# --------------------------------------------------------------------------
# the unbatched oracle
# --------------------------------------------------------------------------
def naive_generate(model, params, prompt, *, max_new: int = 32,
                   max_len: int = 512, rid: int = 0, seed: int = 0,
                   temperature: float = 0.0) -> list[int]:
    """Reference decode for ONE request, no batching/padding/offload: one
    prefill forward, then single-row decode steps, sampling with the same
    (seed, rid, position) key schedule as the engine. ``Engine.generate``
    must reproduce this for every batching and offload configuration."""
    prompt = [int(t) for t in prompt]
    p_len = len(prompt)
    vocab = model.cfg.vocab_size
    # jit wrappers cached on the model: jax.jit keys its trace cache on
    # wrapper identity, so a fresh wrapper per oracle call would recompile
    # decode_step for every request of every test
    fns = getattr(model, "_serve_oracle_fns", None)
    if fns is None:
        fns = (jax.jit(model.prefill), jax.jit(model.decode_step))
        model._serve_oracle_fns = fns
    prefill, step = fns
    logits, kv = prefill(params, jnp.asarray([prompt], jnp.int32),
                         jnp.asarray([p_len], jnp.int32))
    cache = model.init_cache(1, max_len)
    cache = {k: cache[k].at[:, :, :p_len].set(kv[k].astype(cache[k].dtype))
             for k in cache}
    out: list[int] = []
    pos = p_len
    row = np.asarray(logits[0], np.float32)
    while True:
        tok = _sample_token(row, seed=seed, rid=rid, pos=pos,
                            temperature=temperature, vocab_size=vocab)
        out.append(tok)
        if len(out) >= max_new or pos >= max_len:
            return out
        logits, cache = step(params, cache,
                             jnp.asarray([[tok]], jnp.int32),
                             jnp.asarray([pos], jnp.int32))
        row = np.asarray(logits[0], np.float32)
        pos += 1
