"""The tiered storage hierarchy (DESIGN.md §10/§11): DiskStore/TieredStore
semantics, disk-tier fault injection (truncated/missing blobs, full-disk
refusal — typed errors, promptly, never a hang), compile-time spill/load
chains, per-tier budget validation, and tier transparency — bounded-host
plans reproduce the unbounded oracle bit-for-bit on the threaded runtime
under every dispatch policy (a seeded mirror of the hypothesis property,
so it runs without the extra dep)."""
import os
import random as pyrandom
import threading
import time

import numpy as np
import pytest

from repro.core import (BuildConfig, MemgraphOOM, MemOp, OpKind,
                        build_memgraph)
from repro.core.dispatch import COMPUTE, DISK, POLICY_NAMES, engine_of
from repro.core.memgraph import RaceError
from repro.core.runtime import (DiskStore, HostStore, TieredStore,
                                TurnipRuntime, eval_taskgraph, make_store,
                                run_in_order)
from repro.core.simulate import HardwareModel, simulate
from repro.core.stores import DiskCorruptionError, DiskFullError

from helpers import (fig3_taskgraph, graph_inputs, int_inputs,
                     random_taskgraph)

UNITS = dict(size_fn=lambda v: 1)


# ----------------------------------------------------------------- stores
class TestDiskStore:
    def test_roundtrip_array_and_block(self, tmp_path):
        ds = DiskStore(tmp_path)
        a = np.arange(12, dtype=np.float32).reshape(3, 4)
        blk = {"k": np.ones((2, 3), np.float16), "v": np.zeros((2,), np.int8)}
        ds.put("a", a)
        ds.put(("r", 0), blk)
        assert "a" in ds and ("r", 0) in ds and "nope" not in ds
        np.testing.assert_array_equal(ds.get("a"), a)
        got = ds.get(("r", 0))
        np.testing.assert_array_equal(got["k"], blk["k"])
        assert ds.read_bytes == a.nbytes + blk["k"].nbytes + blk["v"].nbytes
        assert ds.resident_bytes == ds.read_bytes    # both values resident
        ds.drop("a")
        assert "a" not in ds and ds.resident_bytes < ds.read_bytes
        ds.close()

    def test_close_removes_private_dir(self):
        ds = DiskStore()
        ds.put("x", np.ones(4))
        root = ds._dir
        assert root is not None and root.exists()
        ds.close()
        assert not root.exists()


class TestTieredStore:
    def test_auto_lru_spill_and_read_through(self):
        ts = TieredStore({}, host_capacity=100)
        a, b, c = (np.full(10, i, np.float64) for i in range(3))  # 80 B each
        ts.put_offload("a", a)
        ts.put_offload("b", b)                    # over 100 B: spills "a"
        assert ts.tier_of("a") == "disk" and ts.tier_of("b") == "host"
        assert ts.resident_bytes == 80
        ts.put_offload("c", c)                    # spills LRU ("b")
        assert ts.tier_of("b") == "disk"
        np.testing.assert_array_equal(ts.get_offload("a"), a)  # read-through
        assert ts.disk.read_bytes == 80
        assert ts.tier_of("a") == "host"          # staged back (and touched)
        ts.close()

    def test_plan_driven_spill_load_drop(self):
        ts = TieredStore({}, auto_spill=False)
        v = np.arange(6, dtype=np.float32)
        ts.put_offload("k", v)
        ts.spill("k")
        assert ts.tier_of("k") == "disk" and ts.resident_bytes == 0
        ts.spill("k")                              # idempotent
        ts.load("k")
        assert ts.tier_of("k") == "host"
        ts.spill("k")                              # dedup: no second write
        assert ts.disk.write_bytes == v.nbytes
        np.testing.assert_array_equal(ts.peek_offload("k"), v)
        ts.spill("k", drop=True)                   # dead data: all tiers
        assert ts.tier_of("k") is None and ts.peek_offload("k") is None
        ts.close()

    def test_pop_drops_disk_copy_too(self):
        ts = TieredStore({})
        ts.put_offload("k", np.ones(8))
        ts.spill("k")
        ts.pop_offload("k")
        assert ts.tier_of("k") is None and ts.disk.resident_bytes == 0
        ts.close()

    def test_peak_counter(self):
        hs = HostStore({})
        hs.put_offload("a", np.ones(16))
        hs.pop_offload("a")
        assert hs.peak_resident_bytes == 128 and hs.resident_bytes == 0

    def test_overwrite_invalidates_stale_disk_twin(self):
        """Regression (data corruption): overwriting a host-resident key
        left the old disk blob alive, and the next spill dedup-skipped the
        write ('immutable disk copy already exists') — a later
        read-through returned the OLD bytes."""
        ts = TieredStore({}, auto_spill=False)
        old, new = np.arange(8.0), np.arange(8.0) * 10
        ts.put_offload("k", old)
        ts.spill("k")
        ts.load("k")                      # host copy back; disk twin alive
        ts.put_offload("k", new)          # overwrite supersedes the twin
        assert "k" not in ts.disk         # twin invalidated immediately
        ts.spill("k")                     # must really write, not dedup
        assert ts.tier_of("k") == "disk"
        np.testing.assert_array_equal(ts.get_offload("k"), new)
        ts.close()

    def test_overwrite_of_disk_only_key_invalidates_twin(self):
        """Same bug, other tier: the overwritten key's bytes lived only on
        disk — prev is None in put_offload, so nothing ever dropped the
        blob and the dedup spill kept resurrecting the old bytes."""
        ts = TieredStore({}, auto_spill=False)
        ts.put_offload("k", np.zeros(4))
        ts.spill("k")                     # host copy gone, blob holds zeros
        ts.put_offload("k", np.ones(4))
        ts.spill("k")
        np.testing.assert_array_equal(ts.get_offload("k"), np.ones(4))
        ts.close()

    def test_read_through_respects_host_budget(self):
        """Regression: load() admitted bytes without the eviction path, so
        a burst of read-throughs pushed resident_bytes past host_capacity
        with auto_spill on and no eviction ever ran."""
        ts = TieredStore({}, host_capacity=200)
        vals = {k: np.full(16, i, np.float64) for i, k in
                enumerate("abcde")}              # 128 B each, cap = 1 key
        for k, v in vals.items():
            ts.put_offload(k, v)                 # LRU-spills predecessors
        for k, v in vals.items():                # read-through sweep
            np.testing.assert_array_equal(ts.get_offload(k), v)
            assert ts.resident_bytes <= 200, \
                f"read-through of {k!r} burst the host budget"
        ts.close()


# ------------------------------------------------- disk-tier faults (§11)
class TestDiskFaults:
    """Truncated/missing spill files and full-disk refusal raise typed
    errors promptly — no executor or stream may hang on rotten bytes."""

    def test_rotted_log_raises_typed(self, tmp_path):
        ds = DiskStore(tmp_path)
        ds.put("k", np.arange(8, dtype=np.float32))
        # wipe the log out from under the store (rotted storage): the
        # record frame no longer matches the index entry
        assert ds._log_path is not None
        os.truncate(ds._log_path, 0)
        with pytest.raises(DiskCorruptionError, match="torn or corrupt"):
            ds.get("k")
        ds.close()

    def test_truncated_record_raises_typed(self, tmp_path):
        ds = DiskStore(tmp_path)
        ds.put("k", np.arange(64, dtype=np.float64))
        path = ds._log_path
        path.write_bytes(path.read_bytes()[:13])      # torn mid-write
        with pytest.raises(DiskCorruptionError):
            ds.get("k")
        # an unknown key is caller error, not corruption
        with pytest.raises(KeyError):
            ds.get("never-put")
        ds.close()

    def test_full_disk_refusal_prompt_and_typed(self):
        ds = DiskStore(capacity=100)
        ds.put("a", np.zeros(10, np.float64))          # 80 B
        with pytest.raises(DiskFullError, match="disk tier full"):
            ds.put("b", np.zeros(10, np.float64))
        # refusal left the tier unchanged; freeing space readmits
        assert ds.resident_bytes == 80 and "b" not in ds
        ds.drop("a")
        ds.put("b", np.zeros(10, np.float64))
        # overwriting charges only the delta, not put-size twice
        ds.put("b", np.zeros(12, np.float64))
        assert ds.resident_bytes == 96
        ds.close()

    def test_drop_get_race_is_keyerror_not_corruption(self):
        """Regression: DiskStore.get resolved the path under the lock but
        read the file outside it; a concurrent drop unlinking mid-read
        surfaced as DiskCorruptionError for a healthy, legitimately-freed
        blob. The dropped-key case must be a KeyError."""
        ds = DiskStore()
        reading = threading.Event()
        dropped = threading.Event()

        class _PausedRead(DiskStore):
            pass

        orig = DiskStore._read_blob

        def paused(self, entry):
            reading.set()                      # reader is past the lock
            assert dropped.wait(5)             # drop lands mid-read
            return orig(self, entry)

        ds._read_blob = paused.__get__(ds)     # instance-level seam
        ds.put("k", np.arange(16.0))
        result: list = []

        def reader():
            try:
                result.append(ds.get("k"))
            except BaseException as e:
                result.append(e)

        t = threading.Thread(target=reader)
        t.start()
        assert reading.wait(5)
        ds.drop("k")                           # unlink while the read runs
        ds.put("k", np.arange(4.0))            # and re-put: fresh path —
        dropped.set()                          # the old read is stale, not rot
        t.join(5)
        assert result, "reader never finished"
        assert isinstance(result[0], KeyError), \
            f"drop/get race misreported as {result[0]!r}"
        # a genuinely rotten record is still corruption, not KeyError
        ds._read_blob = orig.__get__(ds)
        ds.put("r", np.arange(4.0))
        off, _, _ = ds._files["r"]
        with open(ds._log_path, "r+b") as f:
            f.seek(off)
            f.write(b"rot")                    # stomp the record frame
        with pytest.raises(DiskCorruptionError):
            ds.get("r")
        ds.close()

    def test_drop_get_hammer_never_reports_corruption(self):
        """Unseamed probabilistic mirror of the race: concurrent get/drop/
        put cycles may see values or KeyError, never corruption."""
        ds = DiskStore()
        errs: list = []
        stop = threading.Event()

        def reader():
            while not stop.is_set():
                try:
                    ds.get("k", count=False)
                except KeyError:
                    pass
                except BaseException as e:     # pragma: no cover
                    errs.append(e)
                    return

        threads = [threading.Thread(target=reader) for _ in range(3)]
        for t in threads:
            t.start()
        v = np.arange(64.0)
        for _ in range(200):
            ds.put("k", v)
            ds.drop("k")
        stop.set()
        for t in threads:
            t.join(10)
        ds.close()
        assert not errs, f"drop/get race escalated: {errs[0]!r}"

    def test_tiered_auto_spill_surfaces_refusal(self):
        ts = TieredStore({}, host_capacity=100, disk_capacity=100)
        ts.put_offload("a", np.zeros(10))
        ts.put_offload("b", np.full(10, 2.0))          # spills "a": disk 80
        with pytest.raises(DiskFullError):
            ts.put_offload("c", np.zeros(10))          # next spill overflows
        # refusal rolled the hierarchy back: the spill victim's only copy
        # went back to the host tier, the refused admission was undone,
        # and the host budget still holds
        assert ts.tier_of("b") == "host"
        np.testing.assert_array_equal(ts.peek_offload("b"), np.full(10, 2.0))
        assert ts.tier_of("c") is None
        assert ts.resident_bytes <= 100
        ts.close()

    def test_refused_overwrite_keeps_old_disk_twin(self):
        """A refused put_offload must leave the hierarchy at its pre-put
        state *including* the overwritten key's disk twin: invalidating
        the twin before the admission stands would destroy the last copy
        on refusal."""
        ts = TieredStore({}, host_capacity=80, disk_capacity=80)
        ts.put_offload("k", np.zeros(10))              # 80 B
        ts.spill("k")                                  # old bytes disk-only
        ts.put_offload("other", np.ones(10))           # host holds 80/80
        with pytest.raises(DiskFullError):
            ts.put_offload("k", np.full(10, 2.0))      # eviction can't fit
        # the refusal lost nothing: k's OLD bytes are still readable
        np.testing.assert_array_equal(ts.get_offload("k"), np.zeros(10))
        ts.close()

    def test_plan_driven_spill_refusal_keeps_host_copy(self):
        ts = TieredStore({}, auto_spill=False, disk=DiskStore(capacity=0))
        ts.put_offload("k", np.arange(4.0))
        with pytest.raises(DiskFullError):
            ts.spill("k")
        assert ts.tier_of("k") == "host"               # nothing changed
        np.testing.assert_array_equal(ts.get_offload("k"), np.arange(4.0))
        ts.close()

    def test_runtime_surfaces_load_fault_and_joins_all_streams(self):
        """A rotten blob hit by a LOAD on the disk engine must surface as
        DiskCorruptionError from run() — promptly, with every stream
        (compute, DMA, *and* disk) deterministically joined on the error
        path. A silently dead disk thread would wedge the consumers."""

        class _RottenDisk(DiskStore):
            def get(self, key, *, count: bool = True):
                raise DiskCorruptionError(f"injected rot for {key!r}")

        tg = fig3_taskgraph()
        res = build_memgraph(tg, BuildConfig(capacity=3, host_capacity=1,
                                             **UNITS))
        assert res.n_loads > 0
        store = TieredStore(int_inputs(tg), auto_spill=False,
                            disk=_RottenDisk())
        before = set(threading.enumerate())
        t0 = time.monotonic()
        try:
            with pytest.raises(DiskCorruptionError, match="injected rot"):
                TurnipRuntime(tg, res, mode="nondet", policy="random",
                              seed=0,
                              store_factory=lambda inputs: store
                              ).run(int_inputs(tg))
        finally:
            store.close()
        assert time.monotonic() - t0 < 30            # prompt, not a hang
        leaked = {t for t in set(threading.enumerate()) - before
                  if t.name.startswith("turnip-")}
        assert not leaked, f"streams leaked on error path: {leaked}"

    def test_runtime_surfaces_spill_fault_promptly(self):
        """Same discipline for the write side: a full disk met by a SPILL
        vertex raises DiskFullError out of run(), threads joined."""
        tg = fig3_taskgraph()
        res = build_memgraph(tg, BuildConfig(capacity=3, host_capacity=1,
                                             **UNITS))
        assert res.n_spills > 0
        store = TieredStore(int_inputs(tg), auto_spill=False,
                            disk=DiskStore(capacity=0))
        before = set(threading.enumerate())
        try:
            with pytest.raises(DiskFullError):
                TurnipRuntime(tg, res, mode="nondet", policy="random",
                              seed=0,
                              store_factory=lambda inputs: store
                              ).run(int_inputs(tg))
        finally:
            store.close()
        leaked = {t for t in set(threading.enumerate()) - before
                  if t.name.startswith("turnip-")}
        assert not leaked, f"streams leaked on error path: {leaked}"


# ----------------------------------------------------- log compaction
class TestCompaction:
    """Append-only spill.log compaction (DESIGN.md §10): when dead bytes
    dominate, the live records are streamed into a fresh log and
    atomically swapped in. Compaction is an optimization — every failure
    mode must leave the store fully functional on the old log."""

    def test_overwrite_churn_triggers_and_shrinks_log(self):
        ds = DiskStore(compact_min_bytes=1)
        keep = np.arange(256, dtype=np.float64)          # 2048 B payload
        ds.put("keep", keep)
        for i in range(8):
            ds.put("churn", np.full(256, float(i)))
        assert ds.n_compactions >= 1
        assert ds.compacted_reclaimed_bytes > 0
        # the on-disk log matches the index's view and holds far less
        # than the total bytes ever appended
        assert ds._log_path is not None
        assert os.stat(ds._log_path).st_size == ds._end
        assert ds._end < 9 * (ds._HDR.size + keep.nbytes)
        # write_bytes counts spill traffic only — compaction's internal
        # rewrite must not inflate it
        assert ds.write_bytes == 9 * keep.nbytes
        np.testing.assert_array_equal(ds.get("keep"), keep)
        np.testing.assert_array_equal(ds.get("churn"), np.full(256, 7.0))
        ds.close()
        assert not ds._retired_fds                       # no fd leak

    def test_drop_triggers_compaction(self):
        ds = DiskStore(compact_min_bytes=1)
        big = np.arange(256, dtype=np.float64)
        ds.put("a", big)
        ds.put("b", 2 * big)
        ds.drop("a")                 # dead == live → fraction 0.5 crossed
        assert ds.n_compactions == 1
        assert "a" not in ds
        assert ds._end == ds._HDR.size + big.nbytes
        assert ds.dead_bytes == 0
        np.testing.assert_array_equal(ds.get("b"), 2 * big)
        ds.close()

    def test_no_compaction_below_min_bytes_or_when_disabled(self):
        for ds in (DiskStore(),                          # default 1 MiB floor
                   DiskStore(compact_dead_fraction=None,
                             compact_min_bytes=1)):      # knob off
            for i in range(8):
                ds.put("churn", np.full(64, float(i)))
            assert ds.n_compactions == 0
            assert ds.dead_bytes > 0
            np.testing.assert_array_equal(ds.get("churn"), np.full(64, 7.0))
            ds.close()

    def test_crash_at_publish_leaves_old_log_intact(self):
        """Kill the compaction at its commit point: the atomic-rename
        seam raises. The store must carry on against the old log — the
        triggering put succeeds, every key reads back byte-exact, and
        the half-built tmp file is cleaned up."""
        ds = DiskStore(compact_min_bytes=1)

        def boom(tmp, path):
            raise OSError("injected crash at publish")

        ds._publish_compaction = boom                    # instance seam
        keep = np.arange(256, dtype=np.float64)
        ds.put("keep", keep)
        for i in range(8):
            ds.put("churn", np.full(256, float(i)))      # crossings swallowed
        assert ds.n_compactions == 0
        assert ds.dead_bytes > 0                         # nothing reclaimed
        assert ds._log_path is not None
        assert not ds._log_path.with_name("spill.log.compact").exists()
        np.testing.assert_array_equal(ds.get("keep"), keep)
        np.testing.assert_array_equal(ds.get("churn"), np.full(256, 7.0))
        del ds._publish_compaction                       # heal the seam
        ds.put("churn", np.full(256, 9.0))               # re-trigger
        assert ds.n_compactions >= 1
        np.testing.assert_array_equal(ds.get("keep"), keep)
        np.testing.assert_array_equal(ds.get("churn"), np.full(256, 9.0))
        ds.close()

    def test_crash_during_rewrite_leaves_old_log_intact(self, monkeypatch):
        """Kill the compaction mid-rewrite (fsync of the tmp log fails —
        strictly before the commit point). Old log untouched, tmp
        cleaned, store functional; once I/O heals the next trigger
        compacts successfully."""
        real_fsync = os.fsync
        monkeypatch.setattr(os, "fsync",
                            lambda fd: (_ for _ in ()).throw(
                                OSError("injected crash during rewrite")))
        ds = DiskStore(compact_min_bytes=1)
        keep = np.arange(256, dtype=np.float64)
        ds.put("keep", keep)
        for i in range(8):
            ds.put("churn", np.full(256, float(i)))
        assert ds.n_compactions == 0
        assert ds._log_path is not None
        assert not ds._log_path.with_name("spill.log.compact").exists()
        np.testing.assert_array_equal(ds.get("keep"), keep)
        monkeypatch.setattr(os, "fsync", real_fsync)
        ds.put("churn", np.full(256, 9.0))
        assert ds.n_compactions >= 1
        np.testing.assert_array_equal(ds.get("keep"), keep)
        np.testing.assert_array_equal(ds.get("churn"), np.full(256, 9.0))
        ds.close()

    def test_reader_paused_across_compaction_retries(self):
        """A get() that resolved its index entry, then lost the CPU while
        a compaction rewrote the log, reads at a stale offset of the NEW
        log. The generation counter must send it back for a retry — the
        caller sees the correct bytes, never a spurious error."""
        ds = DiskStore(compact_min_bytes=1, compact_dead_fraction=None)
        junk = np.zeros(512)
        a = np.arange(64.0)
        ds.put("junk", junk)         # "k" lands at a nonzero offset...
        ds.put("k", a)
        ds.drop("junk")              # ...that compaction will move to 0
        reading = threading.Event()
        resume = threading.Event()
        orig = DiskStore._read_blob
        calls: list = []

        def seam(self, entry):
            calls.append(entry)
            if len(calls) == 1:      # pause only the first, stale read
                reading.set()
                assert resume.wait(5)
            return orig(self, entry)

        ds._read_blob = seam.__get__(ds)
        result: list = []

        def reader():
            try:
                result.append(ds.get("k"))
            except BaseException as e:
                result.append(e)

        t = threading.Thread(target=reader)
        t.start()
        assert reading.wait(5)       # reader holds a pre-compaction entry
        ds.compact_dead_fraction = 0.01
        ds.put("x", np.ones(4))
        ds.put("x", np.ones(4))      # overwrite trigger: rewrites the log
        assert ds.n_compactions == 1
        resume.set()
        t.join(5)
        assert result, "reader never finished"
        assert not isinstance(result[0], BaseException), \
            f"stale-offset read after compaction escalated: {result[0]!r}"
        np.testing.assert_array_equal(result[0], a)
        assert len(calls) >= 2, "generation bump did not force a retry"
        ds.close()


# ------------------------------------------------------- compiled plans
def tiered_build(cap=3, host_cap=2, **kw):
    tg = fig3_taskgraph()
    kw = {**UNITS, **kw}
    res = build_memgraph(tg, BuildConfig(capacity=cap, host_capacity=host_cap,
                                         **kw))
    return tg, res


class TestCompiledTiering:
    def test_plan_spills_and_validates_budget(self):
        tg, res = tiered_build(cap=3, host_cap=1)
        assert res.n_spills > 0 and res.n_loads > 0
        assert res.peak_host <= 1
        res.memgraph.validate(check_races=True, host_capacity=1)
        prof = res.memgraph.host_tier_profile()
        assert prof["peak_units"] <= 1
        # two-hop reloads are annotated with their tier
        tiers = {v.tier for v in res.memgraph.vertices.values()
                 if v.op == MemOp.RELOAD}
        assert "disk" in tiers

    def test_budget_validation_catches_violation(self):
        tg, res = tiered_build(cap=3, host_cap=2)
        with pytest.raises(RaceError, match="host-tier budget"):
            res.memgraph.validate(host_capacity=0)

    def test_store_selection(self):
        tg, res = tiered_build(cap=3, host_cap=1)
        assert isinstance(make_store(res.memgraph, {}), TieredStore)
        tg2, res2 = tiered_build(cap=3, host_cap=None)
        store = make_store(res2.memgraph, {})
        assert isinstance(store, HostStore)
        assert not isinstance(store, TieredStore)

    def test_disk_vertices_on_disk_engine_only(self):
        tg, res = tiered_build(cap=3, host_cap=1)
        sim = simulate(res.memgraph, HardwareModel(transfer_jitter=0.5),
                       mode="nondet", policy="transfer-first",
                       record_timeline=True)
        disk_names = {v.name for v in res.memgraph.vertices.values()
                      if v.op in (MemOp.SPILL, MemOp.LOAD)}
        assert disk_names
        for (_a, _b, _dev, eng, name) in sim.timeline:
            assert (eng == DISK) == (name in disk_names)

    def test_host_oom_when_tensor_exceeds_tier(self):
        # 3 device slots (forces offload), but a single tensor outsizes
        # the whole host tier: nothing can ever be staged
        with pytest.raises(MemgraphOOM, match="host tier"):
            tiered_build(cap=9, host_cap=2, size_fn=lambda v: 3)


# ------------------------------------------- tier transparency (seeded)
class TestTierTransparency:
    """Seeded mirror of test_property_memgraph's hypothesis property: any
    (device, host, disk) configuration must match the dataflow oracle."""

    def test_random_graphs_all_policies(self):
        n_exercised = 0
        for seed in range(10):
            tg = random_taskgraph(pyrandom.Random(seed))
            try:
                res = build_memgraph(tg, BuildConfig(
                    capacity=3, host_capacity=1 + seed % 3, **UNITS))
            except MemgraphOOM:
                continue
            if res.n_loads == 0:
                continue
            res.memgraph.validate(check_races=True,
                                  host_capacity=1 + seed % 3)
            inputs = graph_inputs(tg, seed)
            ref = eval_taskgraph(tg, inputs)
            # adversarial sequential orders
            for i in range(2):
                r = pyrandom.Random(seed * 7 + i)
                order = res.memgraph.topo_order(key=lambda m: r.random())
                out = run_in_order(tg, res, inputs, order)
                for k in ref:
                    np.testing.assert_array_equal(out[k], ref[k])
            # threaded runtime, every policy, both modes
            for policy in POLICY_NAMES:
                for mode in ("nondet", "fixed"):
                    rr = TurnipRuntime(tg, res, mode=mode, policy=policy,
                                       seed=seed).run(inputs)
                    for k in ref:
                        np.testing.assert_array_equal(rr.outputs[k], ref[k])
            n_exercised += 1
        assert n_exercised >= 3    # the sweep must hit real disk plans

    def test_working_set_exceeding_host_tier_completes(self):
        """The acceptance scenario: device working set ≫ host tier, all
        traffic flows through disk, results oracle-equal under
        random/fixed/critical-path with real disk files moving."""
        tg = fig3_taskgraph()
        inputs = int_inputs(tg)
        ref = eval_taskgraph(tg, inputs)
        res = build_memgraph(tg, BuildConfig(capacity=3, host_capacity=1,
                                             **UNITS))
        assert res.n_spills > 0
        for policy in ("random", "fixed", "critical-path"):
            rr = TurnipRuntime(tg, res, mode="nondet", policy=policy,
                               seed=2).run(inputs)
            for k in ref:
                np.testing.assert_array_equal(rr.outputs[k], ref[k])
            assert rr.disk_spill_bytes > 0 and rr.disk_load_bytes > 0
            assert rr.transfer_time[DISK] >= 0.0

    def test_latency_injected_disk_still_correct(self):
        """Slow disk hops (the two-hop nondeterminism source) change
        timing, never results — and disk latency rides the disk engine."""
        tg = fig3_taskgraph()
        inputs = int_inputs(tg)
        ref = eval_taskgraph(tg, inputs)
        res = build_memgraph(tg, BuildConfig(capacity=3, host_capacity=1,
                                             **UNITS))

        def latency(v):
            return 0.004 if engine_of(v) == DISK else 0.0005

        rr = TurnipRuntime(tg, res, mode="nondet", policy="critical-path",
                           seed=5, latency=latency).run(inputs)
        for k in ref:
            np.testing.assert_array_equal(rr.outputs[k], ref[k])
        # timeline: disk ops only ever occupy the disk engine
        disk_rows = [t for t in rr.timeline if t[3] == DISK]
        assert disk_rows
        for (_a, _b, _dev, eng, name) in rr.timeline:
            is_disk = name.startswith(("spill:", "load:", "drop:"))
            assert (eng == DISK) == is_disk
