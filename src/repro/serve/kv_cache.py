"""Block-paged KV cache — the serving-side MEMGRAPH memory discipline.

The device cache is the model's dense decode cache (``LM.init_cache``):
every leaf is laid out ``[L, B, S_max, ...]`` with batch *slots* on axis 1
and the token axis (2) divided into fixed ``block_size``-token blocks. Like
the runtime's static extents (paper §4), a ``(slot, block)`` pair names a
fixed byte range for the whole serving run — no allocation happens per
token, and every transfer moves a whole extent.

This class is pure device-side geometry + extent I/O; the owning engine
moves the payloads through a :class:`~repro.core.stores.HostStore` (or,
when ``host_kv_bytes`` bounds the mirror, a
:class:`~repro.core.stores.TieredStore` whose cold blocks continue down to
a file-backed disk tier) on its DMA and disk streams. Blocks are the
offload unit (NEO / SpecOffload direction, PAPERS.md):

* :meth:`read_block`   — device→host snapshot of one block (a d2h payload);
* :meth:`write_block`  — host→device restore of one block (an h2d payload);
* :meth:`drop_slot`    — zero a slot's extents when its request is swapped
  out, so a missed reload computes on zeros instead of silently reusing
  stale bytes (the serving analogue of ``SlotTable`` read-validation);
* :meth:`scatter_prefill` — write a batched prefill's ``[L, b, S, ...]``
  K/V into freshly admitted slots in one update;
* :meth:`grow` — widen the slot axis to the next batch bucket (the only
  "allocation", and it happens at admission boundaries, never per token).

Host copies of *cold* blocks stay valid for the lifetime of a request —
once a block's token range is fully behind the decode position it is never
rewritten — so a request preempted twice re-offloads only the tail block
that kept growing: the serving analogue of ``build.py``'s
``reuse_host_copy`` (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

__all__ = ["PagedKVCache"]


class PagedKVCache:
    """Device-side paged view over a dense decode cache pytree.

    All methods that mutate ``self.cache`` replace leaves functionally and
    must be called with the owning engine's lock held; ``read_block`` only
    reads (jax arrays are immutable, so a snapshot taken under the lock
    stays consistent on a DMA thread)."""

    def __init__(self, model, bucket: int, max_len: int, *,
                 block_size: int = 32) -> None:
        if max_len % block_size:
            raise ValueError("max_len must be a multiple of block_size")
        self.model = model
        self.bucket = bucket
        self.max_len = max_len
        self.block_size = block_size
        self.cache: dict[str, Any] = model.init_cache(bucket, max_len)
        for name, leaf in self.cache.items():
            if leaf.ndim < 3 or leaf.shape[1] != bucket \
                    or leaf.shape[2] != max_len:
                raise ValueError(
                    f"cache leaf {name!r} of shape {leaf.shape} is not "
                    "[L, B, S, ...] token-paged — PagedKVCache supports "
                    "the attention families (dense/moe) only")
        self.n_blocks = max_len // block_size
        # bytes of one (slot, block) extent, summed over leaves (k, v, and
        # int8 scales when present)
        self.block_nbytes = sum(
            leaf.shape[0] * int(np.prod(leaf.shape[3:], dtype=np.int64))
            * block_size * leaf.dtype.itemsize
            for leaf in self.cache.values())

    # ------------------------------------------------------------ geometry
    def n_token_blocks(self, pos: int) -> int:
        """Blocks covering cache positions [0, pos)."""
        return -(-pos // self.block_size)

    def token_range(self, blk: int) -> tuple[int, int]:
        return blk * self.block_size, (blk + 1) * self.block_size

    def leaf_spec(self) -> dict[str, tuple[tuple[int, ...], str]]:
        """Per-leaf (shape, dtype) of ONE block's payload —
        ``[L, block_size, ...]`` — the wire-format contract an inter-replica
        migration codec (serve/router.py) validates before any byte lands
        on the destination. Two replicas serving the same model/config have
        identical specs; a mismatch means the ticket is not importable."""
        return {k: ((leaf.shape[0], self.block_size)
                    + tuple(leaf.shape[3:]), str(leaf.dtype))
                for k, leaf in self.cache.items()}

    @property
    def token_nbytes(self) -> float:
        """Per-token KV bytes (offload-fraction denominator)."""
        return self.block_nbytes / self.block_size

    # ------------------------------------------------------------ extents
    def read_block(self, slot: int, blk: int,
                   cache: dict[str, Any] | None = None
                   ) -> dict[str, np.ndarray]:
        """Copy one block out. Pass a ``cache`` snapshot (leaf refs taken
        under the engine lock) to do the copy off the lock — jax arrays are
        immutable, so the snapshot stays consistent on a DMA thread."""
        lo, hi = self.token_range(blk)
        leaves = self.cache if cache is None else cache
        return {k: np.asarray(leaf[:, slot, lo:hi])
                for k, leaf in leaves.items()}

    def write_block(self, slot: int, blk: int,
                    data: dict[str, np.ndarray]) -> None:
        lo, hi = self.token_range(blk)
        self.cache = {k: leaf.at[:, slot, lo:hi].set(jnp.asarray(data[k]))
                      for k, leaf in self.cache.items()}

    def restore_slot(self, slot: int,
                     blocks: list[dict[str, np.ndarray]]) -> None:
        """Apply a resumed request's reloaded blocks 0..n-1 in ONE per-leaf
        scatter — block-wise application would copy every cache leaf once
        per block."""
        span = len(blocks) * self.block_size
        self.cache = {
            k: leaf.at[:, slot, :span].set(
                jnp.concatenate([jnp.asarray(b[k]) for b in blocks],
                                axis=1).astype(leaf.dtype))
            for k, leaf in self.cache.items()}

    def drop_slot(self, slot: int) -> None:
        self.cache = {k: leaf.at[:, slot].set(jnp.zeros((), leaf.dtype))
                      for k, leaf in self.cache.items()}

    def scatter_prefill(self, slots: list[int], kv: dict[str, Any]) -> None:
        """Write prefill K/V (leaves [L, len(slots), S, ...]) into rows."""
        idx = jnp.asarray(slots)
        S = next(iter(kv.values())).shape[2]
        self.cache = {k: leaf.at[:, idx, :S].set(kv[k].astype(leaf.dtype))
                      for k, leaf in self.cache.items()}

    def grow(self, new_bucket: int) -> None:
        pad = new_bucket - self.bucket
        if pad <= 0:
            return
        self.cache = {
            k: jnp.concatenate(
                [leaf,
                 jnp.zeros(leaf.shape[:1] + (pad,) + leaf.shape[2:],
                           leaf.dtype)], axis=1)
            for k, leaf in self.cache.items()}
        self.bucket = new_bucket
