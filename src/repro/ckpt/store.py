"""Sharded, digest-verified checkpointing with elastic restore.

Layout: ``<dir>/step_<N>/`` containing one ``shard_<i>.npz`` per writer plus
``MANIFEST.json`` (leaf paths, shapes, dtypes, per-file sha256, step,
mesh-shape metadata). Writes are atomic (tmp dir + rename) so a failure
mid-write never corrupts the latest checkpoint — the restart driver always
loads the newest *complete* manifest (fault tolerance deliverable).

Elastic: arrays are stored unsharded by logical leaf (host gathers before
save); restore re-shards onto whatever mesh the new job brings, so scaling
from 256→512 chips (or CPU smoke) needs no conversion step.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str | os.PathLike, step: int, tree: Any,
                    *, meta: dict | None = None,
                    max_keep: int = 3) -> pathlib.Path:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = pathlib.Path(tempfile.mkdtemp(dir=d, prefix=".tmp_"))
    leaves = _leaf_paths(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, (_k, leaf) in enumerate(leaves)}
    shard_path = tmp / "shard_0.npz"
    np.savez(shard_path, **arrays)
    digest = hashlib.sha256(shard_path.read_bytes()).hexdigest()
    manifest = {
        "step": int(step),
        "meta": meta or {},
        "leaves": [{"key": k, "idx": f"a{i}",
                    "shape": list(np.shape(l)),
                    "dtype": str(np.asarray(l).dtype)}
                   for i, (k, l) in enumerate(leaves)],
        "files": {"shard_0.npz": digest},
    }
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    final = d / f"step_{step:010d}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)   # atomic publish
    # retention
    steps = sorted(p for p in d.iterdir() if p.name.startswith("step_"))
    for old in steps[:-max_keep]:
        shutil.rmtree(old)
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    best = None
    for p in sorted(d.iterdir()):
        if p.name.startswith("step_") and (p / "MANIFEST.json").exists():
            best = int(p.name.split("_")[1])
    return best


def restore_checkpoint(directory: str | os.PathLike, tree_like: Any,
                       *, step: int | None = None,
                       shardings: Any | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; verify digests; place
    leaves on ``shardings`` if given (elastic re-shard)."""
    d = pathlib.Path(directory)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {d}")
    cdir = d / f"step_{step:010d}"
    manifest = json.loads((cdir / "MANIFEST.json").read_text())
    for fname, want in manifest["files"].items():
        got = hashlib.sha256((cdir / fname).read_bytes()).hexdigest()
        if got != want:
            raise IOError(f"checkpoint corruption in {cdir / fname}: "
                          f"sha256 {got} != {want}")
    data = np.load(cdir / "shard_0.npz")
    by_key = {l["key"]: data[l["idx"]] for l in manifest["leaves"]}
    flat = _leaf_paths(tree_like)
    leaves = []
    for key, like in flat:
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        want_shape = tuple(np.shape(like))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"leaf {key!r}: ckpt {arr.shape} != "
                             f"expected {want_shape}")
        leaves.append(arr)
    tdef = jax.tree_util.tree_structure(tree_like)
    restored = jax.tree_util.tree_unflatten(tdef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored, manifest["step"]
