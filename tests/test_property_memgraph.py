"""Property-based tests (hypothesis): for *random* TASKGRAPHs, memory
budgets, and execution orders, the compiled MEMGRAPH is acyclic, race-free,
within budget, and produces outputs identical to direct dataflow evaluation
— the paper's §7 correctness claims as machine-checked invariants."""
import random as pyrandom

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (BuildConfig, MemgraphOOM, OpKind, TaskGraph,
                        build_memgraph)
from repro.core.runtime import eval_taskgraph, run_in_order

# the shared TASKGRAPH strategy (helpers.py): one distribution across the
# property tests, the seeded dispatch/tiering sweeps, and the differential
# fuzz harness
from helpers import taskgraphs


@st.composite
def budgets(draw):
    return draw(st.integers(3, 12))


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(tg=taskgraphs(), cap=budgets(),
       policy=st.sampled_from(["belady", "lru", "random"]),
       reuse=st.booleans(), seed=st.integers(0, 2**16))
def test_any_order_matches_oracle(tg, cap, policy, reuse, seed):
    cfg = BuildConfig(capacity=cap, size_fn=lambda v: 1,
                      victim_policy=policy, reuse_host_copy=reuse,
                      rng_seed=seed)
    try:
        res = build_memgraph(tg, cfg)
    except MemgraphOOM:
        return  # infeasible budget for this graph's working set: OK
    mg = res.memgraph
    mg.validate(check_races=True)                       # acyclic + race-free
    assert max(res.peak_used.values()) <= cap            # never over budget

    rng = np.random.default_rng(seed)
    inputs = {t: rng.integers(-3, 4, v.out.shape).astype(np.float64)
              for t, v in tg.vertices.items() if v.kind == OpKind.INPUT}
    ref = eval_taskgraph(tg, inputs)

    # simulation order + three adversarial random topological orders
    orders = [None]
    for i in range(3):
        r = pyrandom.Random(seed + i)
        orders.append(mg.topo_order(key=lambda m: r.random()))
    for order in orders:
        out = run_in_order(tg, res, inputs, order)
        assert set(out) == set(ref)
        for k in ref:
            np.testing.assert_array_equal(out[k], ref[k], err_msg=f"out {k}")


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(tg=taskgraphs(), seed=st.integers(0, 2**16))
def test_bytewise_variable_sizes(tg, seed):
    """Same invariants with byte-granular arenas (nbytes size_fn)."""
    cap = 6 * 4 * 4 * 8          # six tensors' worth of bytes per device
    try:
        res = build_memgraph(tg, BuildConfig(capacity=cap))
    except MemgraphOOM:
        return
    res.memgraph.validate(check_races=True)
    rng = np.random.default_rng(seed)
    inputs = {t: rng.integers(-3, 4, v.out.shape).astype(np.float64)
              for t, v in tg.vertices.items() if v.kind == OpKind.INPUT}
    ref = eval_taskgraph(tg, inputs)
    out = run_in_order(tg, res, inputs)
    for k in ref:
        np.testing.assert_array_equal(out[k], ref[k])


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(tg=taskgraphs(), cap=budgets(), host_cap=st.integers(1, 6),
       reuse=st.booleans(), seed=st.integers(0, 2**16))
def test_tiered_host_matches_unbounded_oracle(tg, cap, host_cap, reuse, seed):
    """Tier transparency: ANY host-capacity/disk configuration reproduces
    the unbounded-host oracle bit-for-bit under arbitrary execution orders,
    and the host-tier budget holds along the schedule. Tier choice changes
    timing only — never results."""
    cfg = BuildConfig(capacity=cap, size_fn=lambda v: 1,
                      reuse_host_copy=reuse, rng_seed=seed,
                      host_capacity=host_cap)
    try:
        res = build_memgraph(tg, cfg)
    except MemgraphOOM:
        return  # infeasible device or host budget: OK
    mg = res.memgraph
    # acyclic + race-free + within BOTH budgets
    mg.validate(check_races=True, host_capacity=host_cap)
    assert max(res.peak_used.values()) <= cap
    assert res.peak_host <= host_cap

    rng = np.random.default_rng(seed)
    inputs = {t: rng.integers(-3, 4, v.out.shape).astype(np.float64)
              for t, v in tg.vertices.items() if v.kind == OpKind.INPUT}
    ref = eval_taskgraph(tg, inputs)

    orders = [None]
    for i in range(2):
        r = pyrandom.Random(seed + i)
        orders.append(mg.topo_order(key=lambda m: r.random()))
    for order in orders:
        out = run_in_order(tg, res, inputs, order)
        assert set(out) == set(ref)
        for k in ref:
            np.testing.assert_array_equal(out[k], ref[k], err_msg=f"out {k}")


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(tg=taskgraphs(), cap=budgets(), host_cap=st.one_of(
    st.none(), st.integers(1, 6)), seed=st.integers(0, 2**16))
def test_forward_seq_edges(tg, cap, host_cap, seed):
    """Every dependency edge points forward in simulation order — the §7
    acyclicity argument, checked directly (disk-tier chains included)."""
    try:
        res = build_memgraph(tg, BuildConfig(
            capacity=cap, size_fn=lambda v: 1, rng_seed=seed,
            host_capacity=host_cap))
    except MemgraphOOM:
        return
    mg = res.memgraph
    for m, v in mg.vertices.items():
        for u in mg.preds[m]:
            assert mg.vertices[u].seq < v.seq, f"backward edge {u}->{m}"
