"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(42)

pytestmark = pytest.mark.slow


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == "bfloat16" \
        else dict(rtol=2e-4, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Skv,Hq,Hkv,Dh,causal", [
        (2, 128, 128, 4, 2, 64, True),       # GQA
        (1, 200, 200, 4, 4, 128, True),      # non-multiple padding
        (2, 64, 256, 8, 2, 64, False),       # cross-ish, bidir
        (1, 256, 64, 2, 1, 64, True),        # MQA, short kv
    ])
    def test_vs_ref(self, B, Sq, Skv, Hq, Hkv, Dh, causal):
        from repro.kernels.flash_attention.ops import flash_attention
        from repro.kernels.flash_attention.ref import attention_ref
        q = jnp.asarray(rng.normal(size=(B, Sq, Hq, Dh)), "float32")
        k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, Dh)), "float32")
        v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, Dh)), "float32")
        o = flash_attention(q, k, v, causal=causal, interpret=True,
                            block_q=64, block_kv=64)
        r = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3),
                          causal=causal).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   **_tol("float32"))

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_dtypes(self, dtype):
        from repro.kernels.flash_attention.ops import flash_attention
        from repro.kernels.flash_attention.ref import attention_ref
        q = jnp.asarray(rng.normal(size=(1, 128, 4, 64)), dtype)
        k = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), dtype)
        v = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), dtype)
        o = flash_attention(q, k, v, interpret=True)
        r = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(r, np.float32), **_tol(dtype))


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(7, 128), (3, 33, 256), (1, 512)])
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_vs_ref(self, shape, dtype):
        from repro.kernels.rmsnorm.ops import rmsnorm
        from repro.kernels.rmsnorm.ref import rmsnorm_ref
        x = jnp.asarray(rng.normal(size=shape), dtype)
        g = jnp.asarray(rng.normal(size=shape[-1:]), dtype)
        o = rmsnorm(x, g, interpret=True, block_rows=16)
        r = rmsnorm_ref(x, g)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32), **_tol(dtype))


class TestSSDScan:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (2, 100, 3, 32, 16, 32), (1, 64, 2, 64, 64, 16), (2, 33, 1, 16, 8, 64),
    ])
    def test_vs_ref(self, B, S, H, P, N, chunk):
        from repro.kernels.ssd_scan.ops import ssd_scan
        from repro.kernels.ssd_scan.ref import ssd_scan_ref
        xh = jnp.asarray(rng.normal(size=(B, S, H, P)), "float32")
        dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))), "float32")
        A = jnp.asarray(-np.abs(rng.normal(size=(H,))), "float32")
        Bm = jnp.asarray(rng.normal(size=(B, S, N)), "float32")
        Cm = jnp.asarray(rng.normal(size=(B, S, N)), "float32")
        y = ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk, interpret=True)
        r = ssd_scan_ref(xh, dt, A, Bm, Cm, chunk=37)   # different chunking
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=5e-4, atol=5e-4)


class TestWKV6:
    @pytest.mark.parametrize("B,S,H,P,chunk", [
        (2, 100, 3, 32, 25), (1, 31, 2, 64, 8), (2, 64, 1, 16, 64),
    ])
    def test_vs_ref(self, B, S, H, P, chunk):
        from repro.kernels.rwkv6.ops import wkv6
        from repro.kernels.rwkv6.ref import wkv6_ref
        r = jnp.asarray(rng.normal(size=(B, S, H, P)), "float32")
        k = jnp.asarray(rng.normal(size=(B, S, H, P)), "float32")
        v = jnp.asarray(rng.normal(size=(B, S, H, P)), "float32")
        lw = jnp.clip(jnp.asarray(
            -np.exp(rng.normal(size=(B, S, H, P))), "float32"), -20, 0)
        u = jnp.asarray(rng.normal(size=(H, P)), "float32")
        y = wkv6(r, k, v, lw, u, chunk=chunk, interpret=True)
        yr = wkv6_ref(r, k, v, lw, u, chunk=19)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-3, atol=1e-3)


class TestMoEGMM:
    @pytest.mark.parametrize("E,C,D,F", [(4, 100, 96, 130), (2, 64, 64, 64),
                                         (8, 16, 48, 32)])
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_vs_ref(self, E, C, D, F, dtype):
        from repro.kernels.moe_gmm.ops import moe_gmm
        from repro.kernels.moe_gmm.ref import moe_gmm_ref
        x = jnp.asarray(rng.normal(size=(E, C, D)), dtype)
        w = jnp.asarray(rng.normal(size=(E, D, F)), dtype)
        o = moe_gmm(x, w, block_c=64, block_f=64, block_d=32, interpret=True)
        r = moe_gmm_ref(x, w)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32), **_tol(dtype))
