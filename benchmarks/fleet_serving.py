"""Fleet-serving benchmark: a 3-replica router under a bursty trace, with
one replica hard-killed mid-run (DESIGN.md §16).

Three questions, matching the fleet transplant of the paper's claims:

1. **Does the fleet degrade gracefully?** Aggregate decode throughput with
   one of three replicas killed mid-burst must stay ≥ 2/3 of the steady
   3-replica rate — losing a replica costs its capacity share, never a
   stall of the whole fleet. Enforced as a hard assertion: a regression
   fails the figure (and the bench-smoke CI gate).
2. **Is failover invisible in the bytes?** The chaos run's outputs must be
   token-identical to the steady run's — placement, migration, and death
   change timing only (the TURNIP property lifted to the fleet).
3. **When does warm migration beat cold re-prefill?** The simulator's
   crossover sweep (:func:`repro.core.simulate.migration_crossover`)
   prices both recovery paths per request size; the rows land in the
   BENCH_10.json artifact as the router's eviction-choice table.

CSV contract: ``name,us_per_call,derived`` via :func:`benchmarks.common.emit`.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                     # noqa: E402
import numpy as np                                             # noqa: E402

from repro.configs.base import ArchConfig                      # noqa: E402
from repro.core.simulate import migration_crossover            # noqa: E402
from repro.launch.mesh import FleetTopology                    # noqa: E402
from repro.models import build_model                           # noqa: E402
from repro.serve import (Router, RouterStats, ServeConfig,     # noqa: E402
                         ServeStats)

from .common import emit                                       # noqa: E402

ARCH = ArchConfig(name="fleet-demo", family="dense", n_layers=4,
                  d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                  vocab_size=512, dtype="float32")
MAX_LEN = 128
BLOCK = 16
N_REPLICAS = 3
SEED = 7


def _workload(rng: np.random.Generator, n: int):
    return [list(rng.integers(1, ARCH.vocab_size, rng.integers(24, 49)))
            for _ in range(n)]


def _fleet_cfg() -> ServeConfig:
    # preemption + a short hot window keep requests swapping, so a kill
    # finds warm (migratable) state, not just live device state
    return ServeConfig(max_len=MAX_LEN, batch_buckets=(1, 2),
                       block_size=BLOCK, offload=True, hot_window=BLOCK,
                       preempt_every=2, seed=SEED)


def _run_fleet(model, params, prompts, max_new: int, *,
               kill_step: "int | None" = None):
    """One routed burst. Warm every replica's jit caches first, reset the
    counters, then time the burst; ``kill_step`` arms a deterministic
    mid-decode fault on replica 0 (heartbeats never time out here — the
    60 s budget absorbs jit compiles on a shared CPU)."""
    topo = FleetTopology(n_replicas=N_REPLICAS, heartbeat_timeout_s=60.0,
                         host_bytes_per_replica=64 << 20)
    with Router(model, params, _fleet_cfg(), topology=topo,
                placement="least-loaded") as router:
        warm = [router.submit(p, max_new=2) for p in prompts]
        router.wait(warm, timeout=600)
        with router._lock:
            router._records.clear()   # warm-up must not pollute TTFT
        router.stats = RouterStats()
        for rep in router.replicas:
            rep.engine.stats = ServeStats()
        if kill_step is not None:
            router.replicas[0].engine.fault_after_steps = kill_step

        t0 = time.perf_counter()
        # bursty multi-tenant trace: a front burst, a beat, a second wave
        split = max(1, (2 * len(prompts)) // 3)
        rids = [router.submit(p, max_new=max_new) for p in prompts[:split]]
        time.sleep(0.05)
        rids += [router.submit(p, max_new=max_new) for p in prompts[split:]]
        router.wait(rids, timeout=600)
        wall = time.perf_counter() - t0
        outs = [router.result(r) for r in rids]
        summary = router.summary()
    tokens = sum(len(o) for o in outs)
    return outs, summary, wall, tokens / max(wall, 1e-9)


def run(quick: bool = True) -> list[dict]:
    rng = np.random.default_rng(0)
    model = build_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    n_req, max_new = (9, 12) if quick else (18, 24)
    prompts = _workload(rng, n_req)

    # ---- 1. steady state: 3 replicas, no faults
    ref_out, ref_sum, _, ref_rate = _run_fleet(model, params, prompts,
                                               max_new)
    p99 = max(ref_sum["ttft_p99"].values() or [0.0])
    emit("fleet/steady/aggregate", 1e6 / max(ref_rate, 1e-9),
         f"tok_s={ref_rate:.1f};replicas={N_REPLICAS};"
         f"ttft_p99_ms={p99 * 1e3:.1f}")

    # ---- 2. chaos: replica 0 dies mid-decode; survivors absorb its load
    out, s, _, rate = _run_fleet(model, params, prompts, max_new,
                                 kill_step=4)
    ratio = rate / max(ref_rate, 1e-9)
    floor = (N_REPLICAS - 1) / N_REPLICAS
    exact = out == ref_out
    emit("fleet/chaos/aggregate", 1e6 / max(rate, 1e-9),
         f"tok_s={rate:.1f};of_steady_x{ratio:.2f};"
         f"migrations={s['migrations']};"
         f"migrated_KB={s['migrated_bytes'] / 1024:.1f};"
         f"reprefills={s['reprefills']};"
         f"drain_ms={s['drain_time'] * 1e3:.1f};exact={exact}")
    assert s["replicas_killed"] == 1, s
    assert exact, "chaos run diverged from the steady run's tokens"
    assert ratio >= floor, (
        f"post-kill aggregate throughput {rate:.1f} tok/s is "
        f"{ratio:.2f}x steady state — below the {floor:.2f}x floor")

    # ---- 3. warm-migration vs cold-re-prefill crossover (simulated).
    # Two pricing points: the demo arch (flops/token ≈ 2 * n_params, so
    # tiny — re-prefill is nearly free) and a 7B-class fp16 model where
    # re-creating KV state costs real compute and migration pays off.
    head_dim = ARCH.d_model // ARCH.n_heads
    demo_block = (ARCH.n_layers * 2 * BLOCK * ARCH.n_kv_heads
                  * head_dim * 4)
    n_params = ARCH.n_layers * (4 * ARCH.d_model**2
                                + 2 * ARCH.d_model * ARCH.d_ff) \
        + ARCH.vocab_size * ARCH.d_model
    scales = {
        "demo": dict(block_nbytes=demo_block,
                     flops_per_token=2.0 * n_params),
        "7b": dict(block_nbytes=32 * 2 * BLOCK * 32 * 128 * 2,
                   flops_per_token=2.0 * 7e9),
    }
    rows: list[dict] = []
    for scale, kw in scales.items():
        for disk_frac in (0.0, 0.5):
            sweep = migration_crossover(block_size=BLOCK,
                                        disk_frac=disk_frac, **kw)
            for r in sweep:
                r["scale"] = scale
                r["disk_frac"] = disk_frac
            rows += sweep
            wins = [r["n_blocks"] for r in sweep
                    if r["winner"] == "migrate"]
            emit(f"fleet/crossover/{scale}/disk_frac{disk_frac:g}", 0.0,
                 f"migrate_wins_from_{min(wins)}_blocks" if wins
                 else "reprefill_always_wins")
    rows.append({"kind": "chaos_summary", "steady_tok_s": ref_rate,
                 "chaos_tok_s": rate, "of_steady": ratio,
                 "migrations": s["migrations"],
                 "migrated_bytes": s["migrated_bytes"],
                 "reprefills": s["reprefills"],
                 "drain_time_s": s["drain_time"],
                 "replicas_killed": s["replicas_killed"],
                 "exact": exact})
    return rows


if __name__ == "__main__":
    run(quick=os.environ.get("QUICK", "1") != "0")
