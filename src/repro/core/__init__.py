"""TURNIP core: TASKGRAPH → MEMGRAPH compilation and nondeterministic
execution (the paper's primary contribution)."""
from .taskgraph import OpKind, TaskGraph, TaskVertex, TensorSpec
from .memgraph import DepKind, Loc, MemGraph, MemOp, MemVertex, RaceError
from .analyze import (Certificate, PlanCertificationError, PlanHazard,
                      certify)
from .liveness import (LeaseSpec, LivenessCertificate, LivenessModelError,
                       PoolConfig, ProgressCertificationError, StreamConfig,
                       certify_progress, default_pool_config)
from .build import BuildConfig, BuildResult, MemgraphOOM, build_memgraph
from .compile import CompiledPlan, PlanCompileError, lower
from .dispatch import DispatchPolicy, POLICY_NAMES, get_policy
from .stores import DiskStore, HostStore, TieredStore
from .pool import (ARBITRATION_POLICY_NAMES, ArbitrationPolicy, HostPool,
                   Lease, LeaseRefusal, get_arbitration_policy)

__all__ = [
    "OpKind", "TaskGraph", "TaskVertex", "TensorSpec",
    "DepKind", "Loc", "MemGraph", "MemOp", "MemVertex", "RaceError",
    "Certificate", "PlanCertificationError", "PlanHazard", "certify",
    "LeaseSpec", "LivenessCertificate", "LivenessModelError", "PoolConfig",
    "ProgressCertificationError", "StreamConfig", "certify_progress",
    "default_pool_config",
    "BuildConfig", "BuildResult", "MemgraphOOM", "build_memgraph",
    "CompiledPlan", "PlanCompileError", "lower",
    "DispatchPolicy", "POLICY_NAMES", "get_policy",
    "DiskStore", "HostStore", "TieredStore",
    "ARBITRATION_POLICY_NAMES", "ArbitrationPolicy", "HostPool",
    "Lease", "LeaseRefusal", "get_arbitration_policy",
]
