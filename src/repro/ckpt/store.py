"""Sharded, digest-verified checkpointing with elastic restore.

Layout: ``<dir>/step_<N>/`` containing ``shard_<i>.npz`` files plus
``MANIFEST.json`` (leaf paths, shapes, dtypes, per-leaf shard file,
per-file sha256, step, mesh-shape metadata). Leaves are packed greedily
into shards by a byte threshold (``shard_bytes``), so a large tree splits
across many files — parallel-writer friendly, and a corruption blast
radius of one shard. Writes are atomic (tmp dir + rename) so a failure
mid-write never corrupts the latest checkpoint; restore verifies every
needed shard's digest and, when no explicit step is requested, **falls
back to the newest complete checkpoint** if the latest one is corrupt or
truncated (fault-tolerance deliverable).

Elastic: arrays are stored unsharded by logical leaf (host gathers before
save); restore re-shards onto whatever mesh the new job brings, so scaling
from 256→512 chips (or CPU smoke) needs no conversion step.
"""
from __future__ import annotations

import concurrent.futures
import hashlib
import json
import os
import pathlib
import shutil
import sys
import tempfile
import threading
from typing import Any

import jax
import numpy as np

from ..core import lockcheck

__all__ = ["save_checkpoint", "save_checkpoint_async", "PendingCheckpoint",
           "restore_checkpoint", "latest_step", "complete_steps"]

DEFAULT_SHARD_BYTES = 64 * 2**20

# Serializes the publish + retention critical section across concurrent
# savers (an async checkpoint thread racing the supervisor's restart
# path): both mutate the same published step tree, and two overlapping
# prunes can race ``rmtree`` on the same directory. A SanitizedLock leaf,
# so checkpoint writes join the suite-wide lock-order audit.
_publish_lock = lockcheck.make_lock("CkptStore")

# The checkpoint disk-tier stream (DESIGN.md §15 / ROADMAP item 5 tail):
# one dedicated writer thread, mirroring the runtime's `disk` engine
# class. Blocking saves pipeline shard writes through it (leaf gather of
# shard i+1 overlaps the write of shard i); `save_checkpoint_async` runs
# the *whole* save on it so the training step loop never blocks on disk.
# Single-worker on purpose: shard writes of one checkpoint stay ordered,
# and concurrent saves serialize instead of thrashing one spindle.
_stream_lock = threading.Lock()
_stream: concurrent.futures.ThreadPoolExecutor | None = None


def _disk_stream() -> concurrent.futures.ThreadPoolExecutor:
    global _stream
    with _stream_lock:
        if _stream is None:
            _stream = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="ckpt-disk")
        return _stream


def _write_shard(path: pathlib.Path, arrays: dict[str, np.ndarray]) -> None:
    """Write one shard file. A seam for fault-injection tests (a crash
    mid-shard-write must leave no partial checkpoint behind)."""
    np.savez(path, **arrays)


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str | os.PathLike, step: int, tree: Any,
                    *, meta: dict | None = None,
                    max_keep: int = 3,
                    shard_bytes: int = DEFAULT_SHARD_BYTES) -> pathlib.Path:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = pathlib.Path(tempfile.mkdtemp(dir=d, prefix=".tmp_"))
    try:
        return _save_into(d, tmp, step, tree, meta, max_keep, shard_bytes,
                          pipelined=True)
    except BaseException:
        # a crash mid-shard-write must not leak the partial tmp dir: the
        # published tree holds only complete, digest-covered checkpoints
        shutil.rmtree(tmp, ignore_errors=True)
        raise


class PendingCheckpoint:
    """Handle to a checkpoint save running on the disk-tier stream."""

    def __init__(self, future: concurrent.futures.Future) -> None:
        self._future = future

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: float | None = None) -> pathlib.Path:
        """Block until the save publishes; returns the checkpoint dir.
        Re-raises any save failure (the tmp dir is already cleaned)."""
        return self._future.result(timeout)


def save_checkpoint_async(directory: str | os.PathLike, step: int, tree: Any,
                          *, meta: dict | None = None,
                          max_keep: int = 3,
                          shard_bytes: int = DEFAULT_SHARD_BYTES,
                          ) -> PendingCheckpoint:
    """Non-blocking :func:`save_checkpoint`: the whole save (leaf gather,
    shard writes, digests, atomic publish) runs on the disk-tier stream so
    the training step loop overlaps checkpointing instead of stalling on
    it. Sound because jax/numpy leaves are immutable snapshots — a step
    that replaces the tree cannot mutate the one being written; the
    publish + retention critical section still serializes against
    concurrent blocking saves under ``_publish_lock``.

    The save runs inline on the stream worker (not re-submitted shard by
    shard): the stream is single-worker, so a save that queued its own
    shard writes behind itself would deadlock."""
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = pathlib.Path(tempfile.mkdtemp(dir=d, prefix=".tmp_"))

    def _job() -> pathlib.Path:
        try:
            return _save_into(d, tmp, step, tree, meta, max_keep,
                              shard_bytes, pipelined=False)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    return PendingCheckpoint(_disk_stream().submit(_job))


def _save_into(d: pathlib.Path, tmp: pathlib.Path, step: int, tree: Any,
               meta: dict | None, max_keep: int, shard_bytes: int, *,
               pipelined: bool) -> pathlib.Path:
    leaves = _leaf_paths(tree)

    # ``pipelined``: shard writes ride the disk-tier stream as each shard
    # closes, so the device→host gather of shard i+1 overlaps the write
    # of shard i. The async path passes False — it already *is* the
    # stream worker, and the stream is single-worker.
    futures: list[concurrent.futures.Future] = []

    def _flush(group: list[tuple[str, str, np.ndarray]], si: int) -> None:
        path = tmp / f"shard_{si}.npz"
        arrays = {idx: arr for idx, _key, arr in group}
        if pipelined:
            # late-bind _write_shard so test fault injection (monkeypatch
            # of the module global) reaches stream-side writes too
            futures.append(_disk_stream().submit(
                lambda: _write_shard(path, arrays)))
        else:
            _write_shard(path, arrays)

    # greedy size-threshold packing: a shard closes once adding the next
    # leaf would push it past shard_bytes (oversized single leaves get a
    # shard of their own)
    shards: list[list[tuple[str, str, np.ndarray]]] = []
    cur: list[tuple[str, str, np.ndarray]] = []
    cur_bytes = 0
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        if cur and cur_bytes + arr.nbytes > shard_bytes:
            shards.append(cur)
            _flush(cur, len(shards) - 1)
            cur, cur_bytes = [], 0
        cur.append((f"a{i}", key, arr))
        cur_bytes += arr.nbytes
    if cur:
        shards.append(cur)
        _flush(cur, len(shards) - 1)

    # drain the stream before digesting: every write must land first, and
    # on failure the rest are cancelled (best effort — one may already be
    # running) then waited out, so no late write races the caller's
    # tmp-dir cleanup
    errors: list[BaseException] = []
    for f in futures:
        if errors and f.cancel():
            continue
        try:
            f.result()
        except concurrent.futures.CancelledError:
            pass
        except BaseException as e:
            errors.append(e)
    if errors:
        raise errors[0]

    files: dict[str, str] = {}
    manifest_leaves: list[dict] = []     # shard packing preserves leaf order
    for si, group in enumerate(shards):
        fname = f"shard_{si}.npz"
        path = tmp / fname
        files[fname] = hashlib.sha256(path.read_bytes()).hexdigest()
        for idx, key, arr in group:
            # reuse the already-materialized array: a second np.asarray
            # per leaf would repeat the whole device→host gather
            manifest_leaves.append({"key": key, "idx": idx, "file": fname,
                                    "shape": list(arr.shape),
                                    "dtype": str(arr.dtype)})

    manifest = {
        "step": int(step),
        "meta": meta or {},
        "leaves": manifest_leaves,
        "files": files,
    }
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    final = d / f"step_{step:010d}"
    with _publish_lock:
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)   # atomic publish
        # retention
        steps = sorted(p for p in d.iterdir() if p.name.startswith("step_"))
        for old in steps[:-max_keep]:
            shutil.rmtree(old)
    return final


def complete_steps(directory: str | os.PathLike) -> list[int]:
    """Steps with a parseable manifest whose every shard exists and passes
    its digest, ascending."""
    d = pathlib.Path(directory)
    if not d.exists():
        return []
    out = []
    for p in sorted(d.iterdir()):
        if not p.name.startswith("step_"):
            continue
        try:
            _verify(p)
        except Exception:
            continue
        out.append(int(p.name.split("_")[1]))
    return out


def latest_step(directory: str | os.PathLike) -> int | None:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    best = None
    for p in sorted(d.iterdir()):
        if p.name.startswith("step_") and (p / "MANIFEST.json").exists():
            best = int(p.name.split("_")[1])
    return best


def _verify(cdir: pathlib.Path) -> dict:
    """Parse a checkpoint's manifest and verify every shard digest."""
    manifest = json.loads((cdir / "MANIFEST.json").read_text())
    for fname, want in manifest["files"].items():
        shard = cdir / fname
        if not shard.exists():
            raise IOError(f"checkpoint corruption: missing shard {shard}")
        got = hashlib.sha256(shard.read_bytes()).hexdigest()
        if got != want:
            raise IOError(f"checkpoint corruption in {shard}: "
                          f"sha256 {got} != {want}")
    return manifest


def _load(cdir: pathlib.Path, tree_like: Any, shardings: Any | None,
          manifest: dict | None = None) -> tuple[Any, int]:
    if manifest is None:           # fallback path verified (+parsed) already
        manifest = _verify(cdir)
    # group leaves by shard so each file is opened once
    by_file: dict[str, list[dict]] = {}
    for leaf in manifest["leaves"]:
        # pre-sharding manifests (one monolithic shard) carry no file field
        by_file.setdefault(leaf.get("file", "shard_0.npz"), []).append(leaf)
    by_key: dict[str, np.ndarray] = {}
    for fname, leaves in by_file.items():
        with np.load(cdir / fname) as data:
            for leaf in leaves:
                by_key[leaf["key"]] = data[leaf["idx"]]
    flat = _leaf_paths(tree_like)
    out = []
    for key, like in flat:
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        want_shape = tuple(np.shape(like))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"leaf {key!r}: ckpt {arr.shape} != "
                             f"expected {want_shape}")
        out.append(arr)
    tdef = jax.tree_util.tree_structure(tree_like)
    restored = jax.tree_util.tree_unflatten(tdef, out)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored, manifest["step"]


def restore_checkpoint(directory: str | os.PathLike, tree_like: Any,
                       *, step: int | None = None,
                       shardings: Any | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; verify digests; place
    leaves on ``shardings`` if given (elastic re-shard).

    With an explicit ``step``, corruption raises. With ``step=None`` the
    newest checkpoint is tried first and, if its shards/manifest fail
    verification (a crash mid-write, bit rot), restore falls back to the
    next-newest *complete* step — the restart driver never wedges on a bad
    latest checkpoint. Shape/structure mismatches against ``tree_like``
    never fall back: they mean the caller asked for the wrong tree."""
    d = pathlib.Path(directory)
    if step is not None:
        return _load(d / f"step_{step:010d}", tree_like, shardings)
    candidates = sorted((p for p in d.iterdir()
                         if p.name.startswith("step_")),
                        reverse=True) if d.exists() else []
    if not candidates:
        raise FileNotFoundError(f"no checkpoint under {d}")
    errors: list[str] = []
    for cdir in candidates:
        try:
            manifest = _verify(cdir)
        except Exception as e:          # truncated/corrupt: try the next
            errors.append(f"{cdir.name}: {e}")
            print(f"ckpt: skipping {cdir.name} ({e}); falling back",
                  file=sys.stderr)
            continue
        # shape/structure errors below must surface, never fall back
        return _load(cdir, tree_like, shardings, manifest)
    raise IOError("checkpoint corruption: no intact checkpoint under "
                  f"{d}; tried {'; '.join(errors)}")
