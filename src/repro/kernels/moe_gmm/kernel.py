"""Grouped expert matmul (MegaBlocks-style) Pallas TPU kernel.

Computes ``out[e] = x[e] @ w[e]`` for E experts with MXU-aligned tiles:
grid (E, C/bc, F/bf, D/bd), contraction innermost with an f32 VMEM
accumulator. This is the dense-grouped form matching the capacity-dispatch
MoE layer (buffers [E, C, D]); on TPU one kernel instance per expert tile
avoids E separate XLA dots and keeps the weight tile resident in VMEM across
the C dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def moe_gmm_kernel(x, w, *, block_c: int = 128, block_f: int = 128,
                   block_d: int = 512, interpret: bool = False):
    """x: [E, C, D]; w: [E, D, F] → [E, C, F]."""
    E, C, D = x.shape
    _, _, F = w.shape
    bc = min(block_c, C)
    bf = min(block_f, F)
    bd = min(block_d, D)
    assert C % bc == 0 and F % bf == 0 and D % bd == 0
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        _gmm_kernel,
        grid=(E, C // bc, F // bf, D // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
