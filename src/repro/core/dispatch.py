"""Dispatch policies — the scheduling vocabulary shared by the threaded
runtime and the discrete-event simulator (paper §5/§8).

TURNIP's runtime is free to launch *any* ready vertex ("at runtime, TURNIP
chooses the best order in response to real-time events"). *Which* ready
vertex it launches when an engine frees up is a policy decision, factored
out here so the threaded :class:`~repro.core.runtime.TurnipRuntime` and the
:func:`~repro.core.simulate.simulate` ablation rank candidates identically:

* ``random``         — uniform-random per-vertex priority (seeded); the
  stress-test policy: order-independence must hold for every draw;
* ``fixed``          — priority = compile-time simulation order (``seq``).
  Note this is *still* event-driven (a vertex launches only when ready);
  the head-of-line "fixed execution" ablation is the runtime's
  ``mode='fixed'``, not a priority policy;
* ``critical-path``  — longest cost-weighted path to a sink, computed from
  ``MemVertex.flops``/``nbytes``; vertices on the critical path launch
  first (classic list scheduling / HEFT upward rank);
* ``transfer-first`` — transfer-engine vertices (offload/reload/transfer/
  input) outrank compute, tie-broken by critical path: start DMAs as early
  as possible so they overlap under compute (the paper's "transfers never
  block computation" precondition).

This module also owns the *engine-class* model: each device has a compute
engine plus three DMA channels (host→device, device→host, device→device)
that run concurrently — the same structure as CUDA streams +
``cudaMemcpyAsync`` or TPU DMA engines. ``engine_of`` maps a vertex to the
engine class that executes it.
"""
from __future__ import annotations

import random
from typing import Callable, Iterable

from .memgraph import MemGraph, MemOp, MemVertex

__all__ = [
    "COMPUTE", "H2D", "D2H", "D2D", "DISK", "NIC", "ENGINE_KINDS",
    "TRANSFER_KINDS",
    "ENGINE_OF", "engine_of", "engine_key", "DispatchPolicy", "RandomPolicy",
    "FixedPolicy", "CriticalPathPolicy", "TransferFirstPolicy",
    "POLICY_NAMES", "get_policy",
]

# -- engine classes ---------------------------------------------------------
# `disk` is the I/O engine of the third storage tier (host RAM → disk): SPILL
# and LOAD vertices run there, so a two-hop reload's disk leg never occupies
# — or waits behind — the h2d/d2h DMA lanes.
# `nic` is the inter-replica link (ROADMAP item 1/2, arXiv 2502.15712's
# NIC-as-pipeline-resource): XFER vertices run there, so a KV migration's
# wire leg never competes with the local DMA or disk lanes. The plan
# builder never emits XFER — only simulator-built pricing graphs (see
# `simulate.price_migration`) and the serving router's cost model use it.
COMPUTE, H2D, D2H, D2D, DISK, NIC = \
    "compute", "h2d", "d2h", "d2d", "disk", "nic"
ENGINE_KINDS = (COMPUTE, H2D, D2H, D2D, DISK, NIC)
TRANSFER_KINDS = (H2D, D2H, D2D, DISK, NIC)

ENGINE_OF = {
    MemOp.INPUT: H2D,        # weights/activations stream in from host store
    MemOp.RELOAD: H2D,
    MemOp.OFFLOAD: D2H,
    MemOp.TRANSFER: D2D,
    MemOp.SPILL: DISK,       # host -> disk (second hop of a tiered eviction)
    MemOp.LOAD: DISK,        # disk -> host (first hop of a two-hop reload)
    MemOp.XFER: NIC,         # host -> remote host (inter-replica migration)
    MemOp.COMPUTE: COMPUTE,
    MemOp.ALLOC0: COMPUTE,
    MemOp.ADD_INTO: COMPUTE,
    MemOp.JOIN: COMPUTE,
}


def engine_of(v: MemVertex) -> str:
    """The engine class (compute or DMA direction) that executes ``v``."""
    return ENGINE_OF[v.op]


def engine_key(v: MemVertex) -> tuple[int, str]:
    """The (device, engine class) pair ``v`` is dispatched on — the unit
    of stream assignment shared by the simulator's engine model, the
    threaded runtime's ready heaps, and the compiled backend's
    fused-DMA adjacency rule (core/compile.py)."""
    return (v.device, ENGINE_OF[v.op])


# -- cost model for priority computation ------------------------------------
# Deliberately coarse (P100-ish constants): priorities only need the right
# *relative* ordering, and a policy must never affect results — only timing.
_FLOPS = 8e12
_HBM_BW = 500e9
_DMA_BW = 12e9
_DISK_BW = 2.4e9          # NVMe-class: ~5x slower than the PCIe DMA lanes
_NIC_BW = 3.1e9           # 25 GbE-class inter-replica link
_KERNEL_OVERHEAD = 5e-6
_DMA_LATENCY = 10e-6
_DISK_LATENCY = 100e-6
_NIC_LATENCY = 50e-6


def vertex_cost(v: MemVertex) -> float:
    """Estimated execution seconds of ``v`` — the critical-path edge weight.

    Disk legs cost ~5x a DMA of the same size, so the cost-aware policies
    (critical-path / transfer-first) naturally rank a two-hop disk reload
    chain earlier than a one-hop host reload of equal size: the slowest
    tier is issued earliest."""
    if v.op == MemOp.JOIN:
        return 0.0
    if engine_of(v) == COMPUTE:
        return _KERNEL_OVERHEAD + max(v.flops / _FLOPS,
                                      3.0 * v.nbytes / _HBM_BW)
    if engine_of(v) == DISK:
        if v.nbytes == 0:       # a dedup/drop spill moves no bytes
            return 0.0
        return _DISK_LATENCY + v.nbytes / _DISK_BW
    if engine_of(v) == NIC:
        return _NIC_LATENCY + v.nbytes / _NIC_BW
    return _DMA_LATENCY + v.nbytes / _DMA_BW


def critical_path_lengths(
        mg: MemGraph,
        cost_fn: Callable[[MemVertex], float] = vertex_cost,
) -> dict[int, float]:
    """Longest cost-weighted path from each vertex to any sink (inclusive of
    the vertex's own cost) — the "upward rank" of list scheduling."""
    cp: dict[int, float] = {}
    for m in reversed(mg.topo_order()):
        tail = max((cp[s] for s in mg.succs[m]), default=0.0)
        cp[m] = cost_fn(mg.vertices[m]) + tail
    return cp


# -- policies ---------------------------------------------------------------
class DispatchPolicy:
    """Ranks ready vertices: lower :meth:`priority` launches first.

    ``prepare(mg)`` is called once per run before any ``priority`` query;
    priorities are static per (graph, policy) pair so both the threaded
    runtime's ready heaps and the simulator's event queue can use them as
    plain sort keys.
    """

    name = "base"

    def prepare(self, mg: MemGraph) -> None:
        self.mg = mg

    def priority(self, mid: int) -> float:
        raise NotImplementedError

    def order(self, mids: Iterable[int]) -> list[int]:
        """Convenience: rank ``mids`` best-first (stable on mid)."""
        return sorted(mids, key=lambda m: (self.priority(m), m))


class RandomPolicy(DispatchPolicy):
    """Uniform-random priority per vertex, deterministic given the seed and
    independent of arrival order (each vertex hashes its own stream).
    ``seed=None`` draws a fresh seed, so repeated unseeded runs stress
    *different* schedules — the paper's any-order-must-work stance."""

    name = "random"

    def __init__(self, seed: int | None = None) -> None:
        self.seed = random.randrange(2**31) if seed is None else seed

    def priority(self, mid: int) -> float:
        # salt differs from HardwareModel._jit's (seed << 20) ^ mid so a
        # simulation's dispatch draws and jitter draws are independent
        # streams even when both derive from the same seed.
        return random.Random((self.seed * 1000003 + 0x5BD1E995) ^ mid).random()


class FixedPolicy(DispatchPolicy):
    """Priority = compile-time simulation order (``MemVertex.seq``)."""

    name = "fixed"

    def priority(self, mid: int) -> float:
        return float(self.mg.vertices[mid].seq)


class CriticalPathPolicy(DispatchPolicy):
    """Longest-path-to-sink first; ties broken by ``seq``.

    ``cost_fn`` supplies per-vertex durations — pass the hardware model's
    (e.g. ``HardwareModel.duration``) so priorities reflect the machine
    being simulated; the default is the coarse built-in estimate.
    """

    name = "critical-path"

    def __init__(self, cost_fn: Callable[[MemVertex], float] | None = None
                 ) -> None:
        self.cost_fn = cost_fn or vertex_cost

    def prepare(self, mg: MemGraph) -> None:
        self.mg = mg
        self._cp = critical_path_lengths(mg, self.cost_fn)
        self._n = max(len(mg), 1)

    def priority(self, mid: int) -> float:
        # negative: larger critical path = earlier launch. The tiny seq
        # epsilon makes ties deterministic without masking the path length.
        return -self._cp[mid] + self.mg.vertices[mid].seq / (1e12 * self._n)


class TransferFirstPolicy(CriticalPathPolicy):
    """Vertices that perform — or directly feed — a DMA outrank the rest;
    critical path breaks ties within each bucket.

    Ready heaps are per engine class, so transfers never compete with
    compute for the same stream; what a policy *can* control is how soon a
    DMA's producer runs. Boosting compute vertices with a transfer
    successor starts offloads/reloads as early as possible: on real copy
    engines a transfer issued "too early" costs nothing (it runs on its own
    channel), while one issued late stalls its consumer (paper §2's
    unpredictable-transfer pathology).
    """

    name = "transfer-first"

    _BUCKET = 1e9   # >> any critical-path length in seconds

    def prepare(self, mg: MemGraph) -> None:
        super().prepare(mg)
        self._feeds_dma = {
            m: (engine_of(mg.vertices[m]) in TRANSFER_KINDS
                or any(engine_of(mg.vertices[s]) in TRANSFER_KINDS
                       for s in mg.succs[m]))
            for m in mg.vertices}

    def priority(self, mid: int) -> float:
        base = super().priority(mid)
        if self._feeds_dma[mid]:
            return base - self._BUCKET
        return base


POLICY_NAMES = ("random", "fixed", "critical-path", "transfer-first")


def get_policy(policy: str | DispatchPolicy | None, *,
               seed: int | None = None,
               cost_fn: Callable[[MemVertex], float] | None = None,
               ) -> DispatchPolicy:
    """Resolve a policy name (or pass through an instance). ``None`` means
    ``random`` — the paper's default stance that any order must work.
    ``cost_fn`` overrides the duration estimate of the cost-aware policies
    (ignored by ``random``/``fixed`` and by pre-built instances)."""
    if isinstance(policy, DispatchPolicy):
        return policy
    if policy is None or policy == "random":
        return RandomPolicy(seed)
    if policy == "fixed":
        return FixedPolicy()
    if policy == "critical-path":
        return CriticalPathPolicy(cost_fn)
    if policy == "transfer-first":
        return TransferFirstPolicy(cost_fn)
    raise ValueError(f"unknown dispatch policy {policy!r}; "
                     f"expected one of {POLICY_NAMES}")
