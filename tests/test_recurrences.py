"""Chunked recurrences (WKV6 / SSD) vs naive per-token oracles — including
hypothesis sweeps over shapes/chunk sizes (exactness is what licenses the
training-memory optimization)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.rwkv import wkv6_chunked
from repro.models.ssm import _ssd_chunked


def wkv6_naive(r, k, v, lw, u, s0=None):
    B, S, H, P = r.shape
    S_ = np.zeros((B, H, P, P), np.float64) if s0 is None \
        else np.asarray(s0, np.float64)
    w = np.exp(np.asarray(lw, np.float64))
    r, k, v = [np.asarray(t, np.float64) for t in (r, k, v)]
    u = np.asarray(u, np.float64)
    ys = []
    for t in range(S):
        kv = np.einsum("bhp,bhq->bhpq", k[:, t], v[:, t])
        ys.append(np.einsum("bhp,bhpq->bhq", r[:, t],
                            S_ + u[None, :, :, None] * kv))
        S_ = w[:, t][..., None] * S_ + kv
    return np.stack(ys, 1), S_


def ssd_naive(xh, dt, A, Bm, Cm, h0=None):
    B_, S_, H_, P_ = xh.shape
    N_ = Bm.shape[-1]
    h = np.zeros((B_, H_, P_, N_), np.float64) if h0 is None \
        else np.asarray(h0, np.float64)
    xh, dt, Bm, Cm = [np.asarray(t, np.float64) for t in (xh, dt, Bm, Cm)]
    A = np.asarray(A, np.float64)
    ys = []
    for t in range(S_):
        da = np.exp(dt[:, t] * A[None])
        h = h * da[:, :, None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], xh[:, t])
        ys.append(np.einsum("bn,bhpn->bhp", Cm[:, t], h))
    return np.stack(ys, 1), h


@settings(max_examples=10, deadline=None)
@given(S=st.integers(1, 70), chunk=st.integers(1, 80),
       seed=st.integers(0, 100))
def test_wkv6_chunked_exact(S, chunk, seed):
    rng = np.random.default_rng(seed)
    B, H, P = 2, 2, 8
    r, k, v = [jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
               for _ in range(3)]
    lw = jnp.clip(jnp.asarray(
        -np.exp(rng.normal(size=(B, S, H, P))).astype(np.float32)), -20, 0)
    u = jnp.asarray(rng.normal(size=(H, P)).astype(np.float32))
    s0 = jnp.asarray(rng.normal(size=(B, H, P, P)).astype(np.float32))
    y, sT = wkv6_chunked(r, k, v, lw, u, chunk=chunk, s0=s0)
    yr, sr = wkv6_naive(r, k, v, lw, u, s0=s0)
    np.testing.assert_allclose(np.asarray(y), yr, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sT), sr, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(1, 60), chunk=st.integers(1, 70),
       seed=st.integers(0, 100))
def test_ssd_chunked_exact(S, chunk, seed):
    rng = np.random.default_rng(seed)
    B, H, P, N = 2, 2, 4, 5
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))).astype(np.float32))
    A = jnp.asarray(-np.abs(rng.normal(size=(H,))).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    h0 = jnp.asarray(rng.normal(size=(B, H, P, N)).astype(np.float32))
    y, hT = _ssd_chunked(xh, dt, A, Bm, Cm, chunk=chunk, h0=h0)
    yr, hr = ssd_naive(xh, dt, A, Bm, Cm, h0=h0)
    np.testing.assert_allclose(np.asarray(y), yr, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hT), hr, rtol=2e-3, atol=2e-3)


def test_wkv6_grads_finite():
    rng = np.random.default_rng(0)
    B, S, H, P = 1, 40, 2, 8
    r, k, v = [jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
               for _ in range(3)]
    lw = jnp.clip(jnp.asarray(
        -np.exp(rng.normal(size=(B, S, H, P))).astype(np.float32)), -20, 0)
    u = jnp.asarray(rng.normal(size=(H, P)).astype(np.float32))
    g = jax.grad(lambda rr: wkv6_chunked(rr, k, v, lw, u, chunk=16)[0].sum())(r)
    assert bool(jnp.isfinite(g).all())
