"""Quickstart: the TURNIP pipeline end to end in one page.

1. Build a TASKGRAPH (the paper's Fig. 3 running example).
2. Compile it to a MEMGRAPH under a 3-slot-per-device budget — offload and
   reload vertices appear, with the safe-overwrite memory dependencies.
3. Execute it with the nondeterministic event-driven runtime and check that
   the result equals direct dataflow evaluation.
4. Compare fixed-order vs nondeterministic dispatch in the discrete-event
   simulator (the paper's §8 ablation).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import BuildConfig, TaskGraph, build_memgraph
from repro.core.runtime import TurnipRuntime, eval_taskgraph
from repro.core.simulate import HardwareModel, simulate


def main() -> None:
    # -- 1. TASKGRAPH (paper Fig. 3: sliced matmul on three devices) -------
    tg = TaskGraph()
    A = tg.add_input(0, (64, 64), name="A")
    B = tg.add_input(0, (64, 64), name="B")
    C = tg.add_input(1, (64, 64), name="C")
    D = tg.add_input(1, (64, 64), name="D")
    v1 = tg.add_compute(0, (A, B), (64, 64), op="matmul", name="1")
    v2 = tg.add_compute(0, (A, B), (64, 64), op="matmul_t", name="2")
    v5 = tg.add_compute(1, (C, D), (64, 64), op="matmul", name="5")
    v6 = tg.add_compute(1, (C, D), (64, 64), op="matmul_t", name="6")
    t25 = tg.add_transfer(1, v2)
    t61 = tg.add_transfer(0, v6)
    v3 = tg.add_compute(0, (v1, t61), (64, 64), op="add", name="3")
    v7 = tg.add_compute(1, (v5, t25), (64, 64), op="add", name="7")
    tg.add_transfer(2, v7)
    v4 = tg.add_compute(0, (v3, t61), (64, 64), op="mul", name="4")
    tg.add_compute(0, (v4, v3), (64, 64), op="mul", name="8")

    # -- 2. compile under pressure: 3 tensor slots per device ---------------
    res = build_memgraph(tg, BuildConfig(capacity=3, size_fn=lambda v: 1))
    res.memgraph.validate(check_races=True)
    print("MEMGRAPH:", res.memgraph.stats())
    print(f"offloads={res.n_offloads} reloads={res.n_reloads} "
          f"peak={res.peak_used}")

    # -- 3. execute: any dependency-respecting order is correct -------------
    rng = np.random.default_rng(0)
    inputs = {t: rng.integers(-3, 4, (64, 64)).astype(np.float64)
              for t in (A, B, C, D)}
    ref = eval_taskgraph(tg, inputs)
    for policy in ("random", "critical-path", "transfer-first"):
        rr = TurnipRuntime(tg, res, mode="nondet", policy=policy,
                           seed=42).run(inputs)
        ok = all(np.array_equal(rr.outputs[k], ref[k]) for k in ref)
        print(f"nondet ({policy:>14s} dispatch) matches dataflow "
              f"oracle: {ok}")

    # -- 4. the paper's ablation in the simulator ---------------------------
    hw = HardwareModel(transfer_jitter=0.8, seed=7)
    nd = simulate(res.memgraph, hw, mode="nondet")
    fx = simulate(res.memgraph, hw, mode="fixed")
    print(f"simulated makespan: nondet={nd.makespan*1e6:.0f}us "
          f"fixed={fx.makespan*1e6:.0f}us "
          f"(fixed/nondet = {fx.makespan/nd.makespan:.2f}x)")


if __name__ == "__main__":
    main()
