import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax-importing import: jax locks the device count on
# first backend init. Only the dry-run gets 512 placeholder devices.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, build the pjit'd step function
(train_step for ``train`` shapes, serve prefill/decode for the others),
``.lower().compile()`` it against the production mesh, and record:

* ``compiled.memory_analysis()``  — proves the plan fits per-device HBM;
* ``compiled.cost_analysis()``   — HLO FLOPs / bytes for §Roofline;
* collective bytes parsed from the optimized HLO (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute), with while-loop trip
  counts folded in.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape train_4k [--multipod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import (SHAPES, ARCHS, ASSIGNED, applicable_shapes, get_arch,
                       input_specs)
from ..models import build_model
from ..sharding.rules import (batch_sharding, cache_sharding, param_sharding,
                              scalar_sharding)
from ..train.optim import AdamW
from ..train.step import make_train_step
from .mesh import make_production_mesh
from .hlo_analysis import collective_bytes_from_hlo, hlo_cost_with_trips


def _sds_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
               remat: str = "full", block_kv: int = 1024,
               kv_cache_dtype: str = "bf16", extra_tag: str = "",
               dump_hlo: str | None = None,
               mesh_shape: tuple[int, ...] | None = None) -> dict:
    """Lower + compile one (arch × shape) cell; return the artifact record."""
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    kw = {}
    if cfg.family in ("dense", "moe"):
        kw["kv_cache_dtype"] = kv_cache_dtype
    model = build_model(cfg, remat=remat if shape.kind == "train" else None,
                        block_kv=block_kv, **kw)
    key = jax.random.PRNGKey(0)

    specs = input_specs(cfg, shape)
    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            opt = AdamW()
            state_shapes = jax.eval_shape(
                lambda k: {"params": model.init(k),
                           "opt": opt.init(jax.eval_shape(model.init, k)),
                           "step": jnp.zeros((), jnp.int32)}, key)
            state_sh = {
                "params": param_sharding(state_shapes["params"], mesh),
                "opt": {"m": param_sharding(state_shapes["opt"]["m"], mesh),
                        "v": param_sharding(state_shapes["opt"]["v"], mesh),
                        "count": scalar_sharding(mesh)},
                "step": scalar_sharding(mesh),
            }
            batch_sh = batch_sharding(specs, mesh)
            step_fn = make_train_step(model, opt)
            metric_sh = {"loss": scalar_sharding(mesh),
                         "grad_norm": scalar_sharding(mesh)}
            jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, metric_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, specs)
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(model.init, key)
            params_sh = param_sharding(params_shapes, mesh)
            batch_sh = batch_sharding(specs, mesh)

            if cfg.family == "encdec":
                def prefill(params, batch):
                    return model.apply(params, batch["tokens"],
                                       encoder_embeds=batch["encoder_embeds"])
            elif cfg.frontend == "vit":
                def prefill(params, batch):
                    return model.apply(params, batch["tokens"],
                                       vision_embeds=batch["vision_embeds"])
            else:
                def prefill(params, batch):
                    return model.apply(params, batch["tokens"])
            jitted = jax.jit(prefill, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_shapes, specs)
        else:  # decode
            params_shapes = jax.eval_shape(model.init, key)
            params_sh = param_sharding(params_shapes, mesh)
            B, S = shape.global_batch, shape.seq_len
            if cfg.family == "encdec":
                cache_shapes = jax.eval_shape(
                    lambda: model.init_cache(B, S, S))
            else:
                cache_shapes = jax.eval_shape(
                    lambda: model.init_cache(B, S))
            cache_sh = cache_sharding(cache_shapes, mesh)
            tok_sh = batch_sharding(
                {"token": specs["token"]}, mesh)["token"]

            def serve_step(params, cache, token, cache_len):
                return model.decode_step(params, cache, token, cache_len)
            from jax.sharding import NamedSharding, PartitionSpec as P
            logits_sh = NamedSharding(mesh, P(None, "model"))
            jitted = jax.jit(
                serve_step,
                in_shardings=(params_sh, cache_sh, tok_sh,
                              scalar_sharding(mesh)),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, cache_shapes,
                                   specs["token"], specs["cache_len"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    if dump_hlo:
        import gzip
        with gzip.open(dump_hlo, "wt") as fh:
            fh.write(hlo)
    hc = hlo_cost_with_trips(hlo)   # XLA cost_analysis counts scan bodies
    coll = hc["collectives"]         # once; this folds loop trip counts
    n_dev = mesh.devices.size
    record = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_dev),
        "kind": shape.kind,
        "tag": extra_tag,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
            "host_temp_bytes": mem.host_temp_size_in_bytes,
        },
        "cost": {
            "flops": hc["flops"],
            "bytes_accessed": hc["bytes_accessed"],
            "xla_raw_flops": cost.get("flops", 0.0),
            "xla_raw_bytes": cost.get("bytes accessed", 0.0),
        },
        "collectives": coll,
        "model": {
            "params": get_arch(arch_name).param_count,
            "active_params": get_arch(arch_name).active_param_count,
        },
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--block-kv", type=int, default=1024)
    ap.add_argument("--kv-cache-dtype", default="bf16")
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="override (data,model), e.g. 32x8")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ASSIGNED:
            for s in applicable_shapes(get_arch(a)):
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        cells.append((args.arch, args.shape))

    pod = "multi" if args.multipod else "single"
    failures = 0
    for a, s in cells:
        fn = out_dir / f"{a}__{s}__{pod}{args.tag}.json"
        if args.skip_existing and fn.exists():
            print(f"SKIP {a:24s} {s:12s} {pod}: exists", flush=True)
            continue
        try:
            ms = (tuple(int(x) for x in args.mesh_shape.split("x"))
                  if args.mesh_shape else None)
            rec = lower_cell(a, s, multi_pod=args.multipod,
                             remat=args.remat, block_kv=args.block_kv,
                             kv_cache_dtype=args.kv_cache_dtype,
                             extra_tag=args.tag, mesh_shape=ms,
                             dump_hlo=(str(fn) + ".hlo.gz"
                                       if args.dump_hlo else None))
            fn.write_text(json.dumps(rec, indent=1))
            m = rec["memory"]["peak_bytes_per_device"] / 2**30
            print(f"OK   {a:24s} {s:12s} {pod}: peak {m:.2f} GiB/dev, "
                  f"flops {rec['cost']['flops']:.3e}, "
                  f"coll {rec['collectives']['total_bytes']:.3e} B "
                  f"(compile {rec['compile_s']}s)", flush=True)
        except Exception as e:
            failures += 1
            fn.with_suffix(".err").write_text(traceback.format_exc())
            print(f"FAIL {a:24s} {s:12s} {pod}: {type(e).__name__}: {e}",
                  flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
