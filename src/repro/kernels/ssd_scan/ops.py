"""jit'd wrapper: pads S to a chunk multiple, dispatches the kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xh, dt, A, Bm, Cm, *, chunk: int = 128,
             interpret: bool = False):
    B, S, H, P = xh.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan_kernel(xh, dt, A, Bm, Cm, chunk=c, interpret=interpret)
    return y[:, :S] if pad else y
