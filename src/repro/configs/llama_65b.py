"""llama-65b: the paper's large evaluation model (§8). [arXiv:2302.13971]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-65b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=64,
    d_ff=22016, vocab_size=32000,
    norm="rmsnorm", mlp="swiglu", rope_theta=1e4,
    source="arXiv:2302.13971 (paper §8)",
)
