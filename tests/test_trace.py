"""Tracer tests: prefill logits + LoRA grads vs jnp references; memory-
constrained execution of the traced graphs through the full TURNIP stack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import BuildConfig, MemgraphOOM, build_memgraph
from repro.core.runtime import TurnipRuntime, eval_taskgraph, run_in_order
from repro.core.trace import TraceConfig, trace_lora_train, trace_prefill

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=48)
TC = TraceConfig(n_devices=2, head_group=1, q_block=8, mlp_slices=2,
                 lora_rank=4, lora_alpha=8.0)


def _weights_by_name(tr, inputs):
    from repro.core import OpKind
    return {v.name: inputs[t] for t, v in tr.tg.vertices.items()
            if v.kind == OpKind.INPUT}


def _ref_prefill(tr, inputs, S=16, L=2, H=4, dh=8, G=2, J=2, Cs=2):
    W = _weights_by_name(tr, inputs)
    x = jnp.asarray(W["x"])

    def rms(x, g):
        return x / jnp.sqrt(jnp.mean(x ** 2, -1, keepdims=True) + 1e-6) * g

    for l in range(L):
        cc = lambda nm, ax: jnp.concatenate(
            [jnp.asarray(W[f"L{l}.{nm}{g}.{j}"])
             for g in range(G) for j in range(J)], axis=ax)
        n1 = rms(x, jnp.asarray(W[f"L{l}.g1"]))
        q = (n1 @ cc("wq", 1)).reshape(S, H, dh).transpose(1, 0, 2)
        k = (n1 @ cc("wk", 1)).reshape(S, H, dh).transpose(1, 0, 2)
        v = (n1 @ cc("wv", 1)).reshape(S, H, dh).transpose(1, 0, 2)
        sc = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(dh)
        sc = jnp.where(jnp.tril(jnp.ones((S, S), bool))[None], sc, -1e30)
        p = jax.nn.softmax(sc, -1)
        o = jnp.einsum("hqk,hkd->hqd", p, v).transpose(1, 0, 2).reshape(S, -1)
        h1 = x + o @ cc("wo", 0)
        n2 = rms(h1, jnp.asarray(W[f"L{l}.g2"]))
        cm = lambda nm, ax: jnp.concatenate(
            [jnp.asarray(W[f"L{l}.{nm}{g}.{c}"])
             for g in range(G) for c in range(Cs)], axis=ax)
        u = n2 @ cm("wi", 1)
        x = h1 + jax.nn.gelu(u, approximate=True) @ cm("wo2", 0)
    xf = rms(x, jnp.asarray(W["gf"]))
    return xf[-1:] @ jnp.asarray(W["unembed"])


def test_prefill_logits_match_reference():
    tr = trace_prefill(TINY, seq_len=16, trace=TC)
    inputs = tr.make_inputs(seed=5, scale=0.3)
    outs = eval_taskgraph(tr.tg, inputs)
    logits = outs[tr.meta["logits"]]
    ref = np.asarray(_ref_prefill(tr, inputs))
    np.testing.assert_allclose(logits, ref, rtol=2e-4, atol=2e-5)


def test_prefill_through_turnip_under_pressure():
    """Full stack: trace → BUILDMEMGRAPH at tight budget → threaded nondet
    runtime → same logits."""
    tr = trace_prefill(TINY, seq_len=16, trace=TC)
    inputs = tr.make_inputs(seed=5, scale=0.3)
    ref = eval_taskgraph(tr.tg, inputs)
    sizes = sorted(v.out.nbytes for v in tr.tg.vertices.values())
    cap = 24 * sizes[-1]          # room for ~24 of the largest tensors
    res = build_memgraph(tr.tg, BuildConfig(capacity=cap))
    res.memgraph.validate(check_races=False)
    rr = TurnipRuntime(tr.tg, res, mode="nondet", seed=2).run(inputs)
    # fp32 streaming reductions commute only approximately (paper §8:
    # "asynchronous partial summations"); exact order-invariance is proven
    # by the integer-valued property tests.
    np.testing.assert_allclose(rr.outputs[tr.meta["logits"]],
                               ref[tr.meta["logits"]], rtol=5e-3, atol=1e-4)


def test_lora_grads_match_jax_autodiff():
    """The paper's training workload: hand-rolled distributed backward ==
    jax.grad of an identical reference network."""
    tr = trace_lora_train(TINY, seq_len=16, trace=TC)
    inputs = tr.make_inputs(seed=3, scale=0.3)
    outs = eval_taskgraph(tr.tg, inputs)

    S, H, dh, G, J, Cs = 16, 4, 8, 2, 2, 2
    s_lora = TC.lora_alpha / TC.lora_rank
    W = _weights_by_name(tr, inputs)

    def rms(x, g):
        return x / jnp.sqrt(jnp.mean(x ** 2, -1, keepdims=True) + 1e-6) * g

    def fwd(adapters, x):
        for l in range(2):
            cc = lambda nm, ax: jnp.concatenate(
                [jnp.asarray(W[f"L{l}.{nm}{g}.{j}"])
                 for g in range(G) for j in range(J)], axis=ax)
            cm = lambda nm, ax: jnp.concatenate(
                [jnp.asarray(W[f"L{l}.{nm}{g}.{c}"])
                 for g in range(G) for c in range(Cs)], axis=ax)
            A = adapters[l]
            n1 = rms(x, jnp.asarray(W[f"L{l}.g1"]))
            q = n1 @ cc("wq", 1) + s_lora * (n1 @ A["Aq"].T) @ cc("Bq", 0).T
            k = n1 @ cc("wk", 1) + s_lora * (n1 @ A["Ak"].T) @ cc("Bk", 0).T
            v = n1 @ cc("wv", 1) + s_lora * (n1 @ A["Av"].T) @ cc("Bv", 0).T
            q3 = q.reshape(S, H, dh).transpose(1, 0, 2)
            k3 = k.reshape(S, H, dh).transpose(1, 0, 2)
            v3 = v.reshape(S, H, dh).transpose(1, 0, 2)
            sc = jnp.einsum("hqd,hkd->hqk", q3, k3) / jnp.sqrt(dh)
            sc = jnp.where(jnp.tril(jnp.ones((S, S), bool))[None], sc, -1e30)
            p = jax.nn.softmax(sc, -1)
            o = jnp.einsum("hqk,hkd->hqd", p, v3).transpose(1, 0, 2)
            h1 = x + o.reshape(S, -1) @ cc("wo", 0)
            n2 = rms(h1, jnp.asarray(W[f"L{l}.g2"]))
            u = n2 @ cm("wi", 1) + s_lora * (n2 @ A["Am"].T) @ cm("Bm", 0).T
            x = h1 + jax.nn.gelu(u, approximate=True) @ cm("wo2", 0)
        return x.sum()

    adapters = [{"Aq": jnp.asarray(W[f"L{l}.Aq"]),
                 "Ak": jnp.asarray(W[f"L{l}.Ak"]),
                 "Av": jnp.asarray(W[f"L{l}.Av"]),
                 "Am": jnp.asarray(W[f"L{l}.Am"])} for l in range(2)]
    gref = jax.grad(fwd)(adapters, jnp.asarray(W["x"]))
    for l in range(2):
        for nm in ("q", "k", "v"):
            got = outs[tr.grad_tids[f"A{nm}{l}"]]
            np.testing.assert_allclose(
                got, np.asarray(gref[l][f"A{nm}"]), rtol=5e-3, atol=5e-4)
        np.testing.assert_allclose(
            outs[tr.grad_tids[f"Am{l}"]], np.asarray(gref[l]["Am"]),
            rtol=5e-3, atol=5e-4)


def test_lora_order_invariance_under_pressure():
    import random
    tr = trace_lora_train(TINY, seq_len=16, trace=TC)
    inputs = tr.make_inputs(seed=7, scale=0.2)
    ref = eval_taskgraph(tr.tg, inputs)
    sizes = sorted(v.out.nbytes for v in tr.tg.vertices.values())
    res = build_memgraph(tr.tg, BuildConfig(capacity=30 * sizes[-1]))
    for trial in range(2):
        r = random.Random(trial)
        order = res.memgraph.topo_order(key=lambda m: r.random())
        out = run_in_order(tr.tg, res, inputs, order)
        for name, tid in tr.grad_tids.items():
            # fp32 streaming-reduction order differs between plans/orders
            np.testing.assert_allclose(out[tid], ref[tid], rtol=5e-3,
                                       atol=1e-4, err_msg=name)
