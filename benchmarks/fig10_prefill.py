"""Paper Fig. 10/12: LLaMA first-token (prefill) latency vs sequence length
under constrained GPU RAM — TURNIP (nondet) vs the fixed-execution ablation
vs a synchronous layerwise baseline (ZeRO/FlexGen-style), with OOM detection.

Times come from the discrete-event simulator under the paper's P100-server
hardware profile (CPU container: no accelerator wall-clock; DESIGN.md §8).
"""
from __future__ import annotations

import time

from repro.configs import get_arch
from repro.core import BuildConfig, MemgraphOOM, build_memgraph
from repro.core.simulate import simulate
from repro.core.trace import TraceConfig, trace_prefill

from .common import P100_SERVER, emit


def run(budget_gb_list=(16.0, 6.0, 3.0), seqs=(1024, 2048, 4096),
        arch="llama-7b", n_layers=8, quick=False) -> list[dict]:
    """``n_layers`` truncates the stack for CPU-feasible graph sizes; the
    simulator's per-layer structure is unchanged (derived column reports the
    full-depth extrapolation)."""
    cfg = get_arch(arch)
    srv = P100_SERVER
    rows = []
    if quick:
        budget_gb_list, seqs = budget_gb_list[:2], seqs[:2]
    for S in seqs:
        tr = trace_prefill(cfg, seq_len=S, n_layers=n_layers,
                           trace=TraceConfig(
                               n_devices=srv["n_devices"], head_group=8,
                               q_block=max(512, S // 4), mlp_slices=2,
                               dtype="float16"))
        for budget in budget_gb_list:
            # scale the budget with the truncated depth so memory pressure
            # matches the full-depth model's weights:activations ratio
            cap = int(budget * 2**30 * tr.meta["n_layers"] / cfg.n_layers)
            t0 = time.time()
            try:
                res = build_memgraph(tr.tg, BuildConfig(capacity=cap))
            except MemgraphOOM:
                rows.append(dict(seq=S, budget=budget, mode="turnip",
                                 status="OOM", ms=None))
                emit(f"fig10/{arch}/S{S}/mem{budget:g}GB/turnip", 0.0, "OOM")
                continue
            build_s = time.time() - t0
            scale = cfg.n_layers / tr.meta["n_layers"]
            for mode, label in (("nondet", "turnip"),
                                ("fixed", "turnip-fixed")):
                sim = simulate(res.memgraph, srv["hw"], mode=mode)
                full = sim.makespan * scale
                rows.append(dict(seq=S, budget=budget, mode=label,
                                 status="ok", ms=full * 1e3,
                                 offloads=res.n_offloads,
                                 reloads=res.n_reloads, build_s=build_s))
                emit(f"fig10/{arch}/S{S}/mem{budget:g}GB/{label}",
                     full * 1e6,
                     f"stall={sim.total_stall*scale*1e3:.1f}ms;"
                     f"rel={res.n_reloads}")
    return rows


if __name__ == "__main__":
    run()
