"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract). Paper artifacts:

* fig10  — LLaMA prefill latency vs sequence length, constrained RAM
* fig11  — LoRA training time per batch
* ablation — fixed-execution slowdown (§8) + victim (§C) + dispatch policies
* threaded — nondet-vs-fixed on real threads (condition-variable runtime)
* memgraph_build — compiler throughput/dependency statistics
* serving — continuous-batching decode with KV offload + reload policies
* fleet_serving — 3-replica router under a bursty trace with one replica
  killed mid-run: graceful-degradation floor, token-exact failover, and
  the warm-migration vs cold-re-prefill crossover table (DESIGN.md §16)
* tiered_offload — bounded host tier + disk spill: throughput vs host-tier
  fraction, nondet-vs-fixed under two-hop reload latency (DESIGN.md §10)
* shared_pool — runtime + serving on one arbitrated HostPool: byte-identical
  to isolated pools, bounded combined occupancy, priced revocation stalls
  (DESIGN.md §12)
* certifier — plan-certification cost vs plan size on tiered-offload plans
  (DESIGN.md §13), plus liveness-certification cost vs plan size and pool
  arbitration policy (DESIGN.md §14)
* compiled_runtime — per-vertex dispatch overhead compiled vs interpreted
  on a ≥500-vertex tiered-offload plan, seam-handoff pricing on a mixed
  plan, fused-DMA ablation (DESIGN.md §15)
* roofline — three-term model per dry-run cell (skipped when no artifacts)

Figures run **isolated**: one broken benchmark emits a ``FAILED`` CSV row
and a traceback, the rest still run, and the process exits nonzero with a
failure summary — CI sees a single figure regression without it hiding the
others.

Besides the CSV stream, the harness writes ``BENCH_10.json`` next to the
working directory: one entry per figure with its machine-readable rows
(benchmarks that return row dicts), its pass/fail status, and the error
text on failure — the artifact CI jobs archive and diff across commits.

``QUICK=0`` env var runs the full sweeps; default is the quick profile so
``python -m benchmarks.run`` completes in a few minutes on one CPU core.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

BENCH_JSON = "BENCH_10.json"


def _roofline() -> None:
    art = "experiments/dryrun_v4"
    if os.path.isdir(art) and any(f.endswith(".json")
                                  for f in os.listdir(art)):
        from . import roofline
        roofline.run(art)
    else:
        print("roofline,0.0,skipped(no dryrun artifacts)")


def main() -> int:
    quick = os.environ.get("QUICK", "1") != "0"
    from . import (certifier, compiled_runtime, fig10_prefill, fig11_lora,
                   fleet_serving, stall_ablation, threaded_runtime,
                   memgraph_build, serving, shared_pool, tiered_offload)
    figures = [
        ("fig10_prefill", lambda: fig10_prefill.run(quick=quick)),
        ("fig11_lora", lambda: fig11_lora.run(quick=quick)),
        ("stall_ablation", lambda: stall_ablation.run(quick=quick)),
        ("threaded_runtime", lambda: threaded_runtime.run(quick=quick)),
        ("memgraph_build", lambda: memgraph_build.run(quick=quick)),
        ("serving", lambda: serving.run(quick=quick)),
        ("fleet_serving", lambda: fleet_serving.run(quick=quick)),
        ("tiered_offload", lambda: tiered_offload.run(quick=quick)),
        ("shared_pool", lambda: shared_pool.run(quick=quick)),
        ("certifier", lambda: certifier.run(quick=quick)),
        ("compiled_runtime", lambda: compiled_runtime.run(quick=quick)),
        ("roofline", _roofline),
    ]
    print("name,us_per_call,derived")
    failures: list[str] = []
    report: dict[str, dict] = {}
    for name, fn in figures:
        try:
            rows = fn()
        except KeyboardInterrupt:
            raise
        except BaseException as e:
            traceback.print_exc(file=sys.stderr)
            # keep the CSV contract: exception text may contain commas
            # and newlines, which would corrupt the 3-field row
            msg = " ".join(str(e).split()).replace(",", ";")[:160]
            print(f"{name},0.0,FAILED({type(e).__name__}: {msg})")
            failures.append(name)
            report[name] = {"ok": False,
                            "error": f"{type(e).__name__}: {msg}",
                            "rows": []}
        else:
            # benchmarks that return machine-readable rows land in the
            # JSON artifact verbatim; CSV-only figures record pass/fail
            report[name] = {"ok": True,
                            "rows": rows if isinstance(rows, list) else []}
    report_doc = {
        "quick": quick,
        "n_figures": len(figures),
        "n_failed": len(failures),
        "ok": not failures,
        "figures": report,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(report_doc, f, indent=2, default=str)
        f.write("\n")
    print(f"# wrote {BENCH_JSON}: {len(figures) - len(failures)}/"
          f"{len(figures)} figures ok", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {len(failures)}/{len(figures)} figure(s) broke: "
              + ", ".join(failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
