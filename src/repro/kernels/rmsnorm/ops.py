"""jit'd wrapper: accepts [..., D], flattens to rows, pads to block size."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import rmsnorm_kernel


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, g, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    shape = x.shape
    D = shape[-1]
    x2 = x.reshape(-1, D)
    N = x2.shape[0]
    br = min(block_rows, N)
    pad = (-N) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    o = rmsnorm_kernel(x2, g, eps=eps, block_rows=br, interpret=interpret)
    if pad:
        o = o[:N]
    return o.reshape(shape)
