"""Flash attention Pallas TPU kernel (causal, GQA).

TPU-native tiling: the grid is (batch, q_head, q_blocks, kv_blocks) with the
kv dimension innermost — TPU grids execute sequentially over the last axis,
so the online-softmax running state (m, l, acc) lives in VMEM scratch and
carries across kv blocks while the ``pallas_call`` pipeline double-buffers
the next K/V tiles from HBM (the intra-kernel mirror of TURNIP's
transfer/compute overlap — DESIGN.md §2). Block shapes default to MXU-
aligned (128, 128) tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, block_q: int, block_kv: int,
                 seq_kv: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0) + q_offset
    kv_pos = ik * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = kv_pos < seq_kv
    if causal:
        mask = mask & (kv_pos <= q_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # [bq, 128]
    m_cur = jnp.max(s, axis=1, keepdims=True)             # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    corr = jnp.exp(m_prev[:, :1] - m_new[:, :1])          # [bq, 1]
    p = jnp.exp(s - m_new[:, :1])                         # [bq, bk]
    l_new = l_scr[...] * corr + jnp.broadcast_to(
        jnp.sum(p, axis=1, keepdims=True), l_scr.shape)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _fin():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           q_offset: int = 0, block_q: int = 128,
                           block_kv: int = 128, interpret: bool = False,
                           true_skv: int | None = None):
    """q: [B, Hq, Sq, Dh]; k/v: [B, Hkv, Skv, Dh]; returns [B, Hq, Sq, Dh].
    ``true_skv``: unpadded KV length (padding tail is masked out)."""
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Skv, block_kv)

    from jax.experimental.pallas import tpu as pltpu
    grid = (B, Hq, nq, nk)
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_kv=block_kv,
                          seq_kv=true_skv if true_skv is not None else Skv,
                          q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, Dh),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, Dh),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # running sum
            pltpu.VMEM((block_q, Dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
