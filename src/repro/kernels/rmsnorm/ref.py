"""Pure-jnp oracle for the fused RMSNorm kernel."""
import jax
import jax.numpy as jnp


def rmsnorm_ref(x, g, *, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * g.astype(jnp.float32)).astype(x.dtype)
