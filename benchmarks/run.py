"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract). Paper artifacts:

* fig10  — LLaMA prefill latency vs sequence length, constrained RAM
* fig11  — LoRA training time per batch
* ablation — fixed-execution slowdown (§8) + victim (§C) + dispatch policies
* threaded — nondet-vs-fixed on real threads (condition-variable runtime)
* memgraph_build — compiler throughput/dependency statistics
* serving — continuous-batching decode with KV offload + reload policies
* roofline — three-term model per dry-run cell (skipped when no artifacts)

``QUICK=0`` env var runs the full sweeps; default is the quick profile so
``python -m benchmarks.run`` completes in a few minutes on one CPU core.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    quick = os.environ.get("QUICK", "1") != "0"
    from . import (fig10_prefill, fig11_lora, stall_ablation,
                   threaded_runtime, memgraph_build, serving)
    print("name,us_per_call,derived")
    fig10_prefill.run(quick=quick)
    fig11_lora.run(quick=quick)
    stall_ablation.run(quick=quick)
    threaded_runtime.run(quick=quick)
    memgraph_build.run(quick=quick)
    serving.run(quick=quick)
    # roofline (requires dry-run artifacts)
    art = "experiments/dryrun_v4"
    if os.path.isdir(art) and any(f.endswith(".json")
                                  for f in os.listdir(art)):
        from . import roofline
        roofline.run(art)
    else:
        print("roofline,0.0,skipped(no dryrun artifacts)")


if __name__ == "__main__":
    main()
