"""jit'd wrapper: pads S to a chunk multiple (decay padding = 0 log-decay,
which leaves the state untouched for padded steps... actually padded k rows
contribute 0 via zero k/v; lw padding of 0 keeps exp terms bounded)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import wkv6_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, lw, u, *, chunk: int = 32, interpret: bool = False):
    B, S, H, P = r.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, lw = z(r), z(k), z(v), z(lw)
    y = wkv6_kernel(r, k, v, lw, u, chunk=c, interpret=interpret)
    return y[:, :S] if pad else y
