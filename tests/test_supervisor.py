"""Direct coverage for the fault-tolerance substrate (ft/supervisor.py):
missed-heartbeat detection latency, restart-storm backoff, and speculative
re-dispatch dedup — the three mechanisms the serving fleet's router reuses
(DESIGN.md §16) and the training driver already depended on.
"""
import threading
import time

import numpy as np
import pytest

from repro.ft.supervisor import (Heartbeat, SpeculativeLedger, Supervisor,
                                 speculative_redispatch)


# -------------------------------------------------------------- heartbeat
class TestHeartbeatDetection:
    def test_detection_latency_bounds(self):
        """A silent worker is reported dead no earlier than ``timeout_s``
        after its last beat and immediately after — the detection latency
        is the timeout, not a multiple of it."""
        hb = Heartbeat(timeout_s=2.0)
        hb.beat("w", now=100.0)
        assert hb.dead_workers(now=101.9) == []
        assert hb.dead_workers(now=102.0) == []      # boundary: not yet
        assert hb.dead_workers(now=102.01) == ["w"]  # one epsilon past

    def test_beat_resets_the_clock(self):
        hb = Heartbeat(timeout_s=1.0)
        hb.beat("w", now=0.0)
        hb.beat("w", now=5.0)
        assert hb.dead_workers(now=5.5) == []
        assert hb.dead_workers(now=6.5) == ["w"]

    def test_forget_retires_a_drained_worker(self):
        """A drained replica must stop reporting dead on every later poll
        — otherwise the fleet monitor re-drains a corpse forever."""
        hb = Heartbeat(timeout_s=1.0)
        hb.beat("a", now=0.0)
        hb.beat("b", now=0.0)
        assert sorted(hb.dead_workers(now=10.0)) == ["a", "b"]
        hb.forget("a")
        assert hb.dead_workers(now=10.0) == ["b"]
        hb.forget("a")                      # idempotent
        assert hb.dead_workers(now=10.0) == ["b"]

    def test_concurrent_beats_and_polls(self):
        """Beats from worker threads racing the supervisor's poll: the
        table stays consistent and a live beater is never reported."""
        hb = Heartbeat(timeout_s=0.5)
        stop = threading.Event()

        def beater():
            while not stop.is_set():
                hb.beat("live")

        t = threading.Thread(target=beater)
        t.start()
        try:
            hb.beat("dead", now=time.monotonic() - 10.0)
            for _ in range(50):
                assert hb.dead_workers() == ["dead"]
        finally:
            stop.set()
            t.join(timeout=5)
        assert not t.is_alive()


# ---------------------------------------------------------------- backoff
class TestRestartBackoff:
    @staticmethod
    def _crashy(n_crashes, at=3):
        crashes = {"left": n_crashes}

        def step_fn(state, batch):
            if state["x"] == at and crashes["left"]:
                crashes["left"] -= 1
                raise RuntimeError("injected")
            return {"x": state["x"] + 1}, {}

        return step_fn

    def test_storm_sleeps_exponentially(self, tmp_path, monkeypatch):
        """Three consecutive crashes at the same step: the k-th restart
        sleeps backoff_s * 2**(k-1), capped — one fault never burns the
        restart budget in milliseconds."""
        slept = []
        monkeypatch.setattr(time, "sleep", lambda s: slept.append(s))
        sup = Supervisor(ckpt_dir=str(tmp_path), save_every=2,
                         backoff_s=0.1, max_backoff_s=0.25)
        state, report = sup.run({"x": np.zeros((), np.float32)},
                                self._crashy(3, at=3),
                                lambda s: None, 8)
        assert report.final_step == 8 and float(state["x"]) == 8
        assert report.restarts == 3
        assert slept == [0.1, 0.2, 0.25]         # doubled, then capped
        assert sum(h.startswith("backoff@") for h in report.history) == 3

    def test_zero_backoff_is_the_prior_behaviour(self, tmp_path,
                                                 monkeypatch):
        slept = []
        monkeypatch.setattr(time, "sleep", lambda s: slept.append(s))
        sup = Supervisor(ckpt_dir=str(tmp_path), save_every=2)
        _, report = sup.run({"x": np.zeros((), np.float32)},
                            self._crashy(2, at=3), lambda s: None, 6)
        assert report.restarts == 2
        assert slept == []
        assert not any(h.startswith("backoff@") for h in report.history)

    def test_budget_still_enforced_under_backoff(self, tmp_path,
                                                 monkeypatch):
        """Backoff damps the storm but never hides it: a persistent crash
        still exhausts max_restarts and re-raises."""
        monkeypatch.setattr(time, "sleep", lambda s: None)
        sup = Supervisor(ckpt_dir=str(tmp_path), save_every=1,
                         max_restarts=2, backoff_s=0.01)
        with pytest.raises(RuntimeError, match="injected"):
            sup.run({"x": np.zeros((), np.float32)},
                    self._crashy(99, at=2), lambda s: None, 6)


# ---------------------------------------------- speculative re-dispatch
class TestSpeculativeLedger:
    def test_at_most_one_clone_per_straggler(self):
        led = SpeculativeLedger()
        assert led.try_clone(7)
        assert not led.try_clone(7)      # already in flight
        assert led.cloned == 1

    def test_winner_applies_loser_drops(self):
        """The dedup that makes speculation safe: whichever completion
        lands second must be dropped, never applied twice."""
        led = SpeculativeLedger()
        assert led.try_clone(7)
        assert led.complete(7)           # first completion wins
        assert not led.complete(7)       # the straggler's late finish
        assert led.wasted == 1
        # a retired vertex is never re-cloned, even if the policy keeps
        # flagging it as slow on later wakeups
        assert not led.try_clone(7)

    def test_policy_flags_only_true_stragglers(self):
        durations = {1: 0.9, 2: 3.1, 3: 0.2}
        medians = {"matmul": 1.0, "copy": 0.1}
        ops = {1: "matmul", 2: "matmul", 3: "copy"}
        assert speculative_redispatch(durations, medians, ops,
                                      factor=3.0) == [2]

    def test_race_never_double_executes(self):
        """N threads race the same straggler through the ledger: exactly
        one clone dispatch and exactly one applied completion, on any
        interleaving."""
        led = SpeculativeLedger()
        clones, applies = [], []
        barrier = threading.Barrier(8)

        def racer(i):
            barrier.wait()
            if led.try_clone(42):
                clones.append(i)
            if led.complete(42):
                applies.append(i)

        threads = [threading.Thread(target=racer, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10)
        assert len(clones) == 1
        assert len(applies) == 1
        assert led.wasted == 7
