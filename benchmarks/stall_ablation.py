"""Paper §8 ablation: fixed-execution slowdown vs transfer-latency jitter
(the paper reports up to 3×). Sweeps jitter σ and memory budgets on the
tiled prefill workload; also the §C victim-policy ablation and the
dispatch-policy sweep (which ready vertex an engine launches first)."""
from __future__ import annotations

import dataclasses

from repro.configs import get_arch
from repro.core import BuildConfig, build_memgraph
from repro.core.dispatch import POLICY_NAMES
from repro.core.simulate import HardwareModel, simulate
from repro.core.trace import TraceConfig, trace_prefill

from .common import P100_SERVER, emit


def run(quick=False) -> list[dict]:
    cfg = get_arch("llama-7b")
    srv = P100_SERVER
    tr = trace_prefill(cfg, seq_len=2048, n_layers=4,
                       trace=TraceConfig(n_devices=srv["n_devices"],
                                         head_group=8, q_block=512,
                                         mlp_slices=2, dtype="float16"))
    rows = []
    jitters = (0.0, 0.6) if quick else (0.0, 0.3, 0.6, 1.0)
    budgets = (4.0,) if quick else (16.0, 4.0, 2.0)
    for budget in budgets:
        cap = int(budget * 2**30 * 4 / cfg.n_layers)
        res = build_memgraph(tr.tg, BuildConfig(capacity=cap))
        for j in jitters:
            hw = dataclasses.replace(srv["hw"], transfer_jitter=j)
            nd = simulate(res.memgraph, hw, mode="nondet")
            fx = simulate(res.memgraph, hw, mode="fixed")
            ratio = fx.makespan / nd.makespan
            rows.append(dict(budget=budget, jitter=j, ratio=ratio,
                             nondet_ms=nd.makespan * 1e3))
            emit(f"ablation/fixed_vs_nondet/mem{budget:g}GB/jit{j:g}",
                 nd.makespan * 1e6, f"fixed/nondet={ratio:.2f}x")
    # dispatch policies (shared vocabulary with the threaded runtime): same
    # nondet event loop, different ready-queue ranking, under heavy jitter.
    # `res` still holds the tightest-budget build from the sweep above.
    hw = dataclasses.replace(srv["hw"], transfer_jitter=0.6)
    base = simulate(res.memgraph, hw, mode="fixed").makespan
    for policy in POLICY_NAMES:
        sim = simulate(res.memgraph, hw, mode="nondet", policy=policy)
        rows.append(dict(dispatch=policy, ms=sim.makespan * 1e3,
                         fixed_ratio=base / sim.makespan))
        emit(f"ablation/dispatch/{policy}", sim.makespan * 1e6,
             f"fixed/nondet={base / sim.makespan:.2f}x")

    # §C victim policies
    # binding but feasible: the unembed tile alone is ~250 MB on dev 0
    cap = int(2.5 * 2**30 * 4 / cfg.n_layers)
    for policy in ("belady", "lru", "random"):
        try:
            res = build_memgraph(tr.tg, BuildConfig(capacity=cap,
                                                    victim_policy=policy))
        except Exception as e:
            emit(f"ablation/victim/{policy}", 0.0, f"OOM:{e}")
            continue
        sim = simulate(res.memgraph, srv["hw"], mode="nondet")
        rows.append(dict(policy=policy, reloads=res.n_reloads,
                         ms=sim.makespan * 1e3))
        emit(f"ablation/victim/{policy}", sim.makespan * 1e6,
             f"reloads={res.n_reloads}")
    return rows


if __name__ == "__main__":
    run()
