"""Dispatch policies (paper §5/§8): every policy is a *timing* choice only —
outputs must match direct dataflow evaluation on offload-heavy graphs in the
threaded runtime and the simulator — and the event-driven scheduler must
never issue a vertex before its dependencies complete."""
import random as pyrandom

import numpy as np
import pytest

from repro.core import (BuildConfig, MemgraphOOM, TaskGraph,
                        build_memgraph, get_policy)
from repro.core.dispatch import (COMPUTE, POLICY_NAMES, TRANSFER_KINDS,
                                 CriticalPathPolicy, TransferFirstPolicy,
                                 critical_path_lengths, engine_of)
from repro.core.runtime import TurnipRuntime, eval_taskgraph
from repro.core.simulate import HardwareModel, simulate

# the random-graph generator and inputs are the shared ones in helpers.py
# (one distribution across the dispatch sweep, the tiering tests, and the
# differential fuzz harness)
from helpers import (fig3_taskgraph, graph_inputs, int_inputs,
                     random_taskgraph)


def offload_heavy_build(tg: TaskGraph, cap: int = 3):
    """Tight per-device budget → the compiler must offload aggressively."""
    try:
        res = build_memgraph(tg, BuildConfig(capacity=cap,
                                             size_fn=lambda v: 1))
    except MemgraphOOM:
        return None
    return res


@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_policy_order_independence_random_graphs(policy):
    """Property: on random offload-heavy graphs, every dispatch policy (and
    both issue modes) produces outputs identical to the dataflow oracle."""
    n_checked = 0
    for seed in range(8):
        tg = random_taskgraph(pyrandom.Random(seed))
        res = offload_heavy_build(tg)
        if res is None:
            continue
        assert res.n_offloads + res.n_reloads > 0, "graph not offload-heavy"
        inputs = graph_inputs(tg, seed)
        ref = eval_taskgraph(tg, inputs)
        for mode in ("nondet", "fixed"):
            rr = TurnipRuntime(tg, res, mode=mode, policy=policy,
                               seed=seed).run(inputs)
            for k in ref:
                np.testing.assert_array_equal(rr.outputs[k], ref[k])
        n_checked += 1
    assert n_checked >= 4   # the sweep must actually exercise builds


@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_policy_matches_oracle_under_latency(policy):
    """Injected transfer latency creates real compute/transfer races; the
    outputs still cannot change."""
    tg = fig3_taskgraph()
    inputs = int_inputs(tg)
    ref = eval_taskgraph(tg, inputs)
    res = build_memgraph(tg, BuildConfig(capacity=3, size_fn=lambda v: 1))

    def latency(v):
        return 0.002 if engine_of(v) in TRANSFER_KINDS else 0.0005

    rr = TurnipRuntime(tg, res, mode="nondet", policy=policy, seed=3,
                       latency=latency).run(inputs)
    for k in ref:
        np.testing.assert_array_equal(rr.outputs[k], ref[k])


def test_no_vertex_starts_before_deps_complete():
    """Regression: the event-driven scheduler must never hand a vertex to a
    stream before every dependency has finished executing, even when random
    latencies shuffle completion order."""
    rng = pyrandom.Random(7)
    tg = random_taskgraph(rng)
    res = offload_heavy_build(tg, cap=4)
    assert res is not None
    inputs = graph_inputs(tg, 7)

    def latency(v):
        return pyrandom.Random(v.mid).uniform(0.0, 0.003)

    rr = TurnipRuntime(tg, res, mode="nondet", policy="random", seed=11,
                       latency=latency).run(inputs)
    mg = res.memgraph
    assert set(rr.spans) == set(mg.vertices)
    for m, (start, _end) in rr.spans.items():
        for p in mg.preds[m]:
            assert rr.spans[p][1] <= start, \
                f"vertex {m} started before dependency {p} completed"


def test_simulator_accepts_policies():
    """Simulated makespan is finite, deterministic, and complete for every
    policy — the shared scheduling vocabulary."""
    tg = fig3_taskgraph()
    res = build_memgraph(tg, BuildConfig(capacity=3, size_fn=lambda v: 1))
    hw = HardwareModel(transfer_jitter=0.5, seed=2)
    for policy in POLICY_NAMES:
        a = simulate(res.memgraph, hw, policy=policy)
        b = simulate(res.memgraph, hw, policy=policy)
        assert a.n_vertices == len(res.memgraph)
        assert a.makespan == b.makespan > 0


def test_critical_path_priorities_decrease_downstream():
    """cp(pred) >= cp(succ) + cost(succ) ≥ cp(succ): upstream vertices carry
    longer paths, so they rank at least as urgent as their successors."""
    tg = fig3_taskgraph()
    res = build_memgraph(tg, BuildConfig(capacity=3, size_fn=lambda v: 1))
    mg = res.memgraph
    cp = critical_path_lengths(mg)
    for m in mg.vertices:
        for s in mg.succs[m]:
            assert cp[m] >= cp[s]
    pol = CriticalPathPolicy()
    pol.prepare(mg)
    ranked = pol.order(list(mg.vertices))
    assert cp[ranked[0]] == max(cp.values())


def test_transfer_first_ranks_dma_work_ahead():
    """DMA vertices and their direct producers outrank compute that feeds no
    transfer — the ordering that actually changes compute-queue ranking
    (transfers themselves never compete with compute for a stream)."""
    tg = fig3_taskgraph()
    res = build_memgraph(tg, BuildConfig(capacity=3, size_fn=lambda v: 1))
    mg = res.memgraph
    pol = TransferFirstPolicy()
    pol.prepare(mg)
    boosted = [m for m, v in mg.vertices.items()
               if engine_of(v) in TRANSFER_KINDS
               or any(engine_of(mg.vertices[s]) in TRANSFER_KINDS
                      for s in mg.succs[m])]
    plain = [m for m in mg.vertices if m not in set(boosted)]
    assert boosted and plain
    assert max(pol.priority(m) for m in boosted) < \
        min(pol.priority(m) for m in plain)


def test_get_policy_rejects_unknown():
    with pytest.raises(ValueError):
        get_policy("steepest-descent")
    assert get_policy(None).name == "random"
    assert get_policy(get_policy("fixed")).name == "fixed"
