"""Fault tolerance: heartbeat supervision, straggler mitigation, restart.

Scope note (DESIGN.md §5): on a real fleet, per-step collectives are XLA's
job; what the *framework* owns is (a) detecting dead/slow hosts, (b)
checkpoint/restart with elastic re-mesh, and (c) straggler mitigation for
host-side work — which TURNIP's nondeterministic dispatch makes natural:
a vertex assigned to a slow worker can simply be re-dispatched elsewhere,
because any dependency-respecting executor is valid (paper §5).

Components:

* :class:`Heartbeat` — worker liveness with configurable timeout.
* :class:`Supervisor` — drives a train loop: run step → on failure, restore
  the latest complete checkpoint (ckpt.store guarantees atomicity) and
  continue, optionally on a different worker count (the data pipeline is
  topology-independent, so the stream is unaffected).
* :func:`speculative_redispatch` — TURNIP-side straggler mitigation: when a
  vertex's runtime exceeds ``factor``× the median for its op type, a clone
  is dispatched on another free stream; first completion wins (results are
  idempotent writes to the planned extent).
* :class:`SpeculativeLedger` — the dedup around that rule: at most one
  clone per straggler, first completion retires the vertex, losers are
  counted as waste and never double-applied.

The serving fleet reuses the same machinery (DESIGN.md §16): the router
beats each replica's heartbeat from the replica's own run loop and drains
replicas the supervisor declares dead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from ..core import lockcheck

__all__ = ["Heartbeat", "Supervisor", "SpeculativeLedger",
           "speculative_redispatch"]


class Heartbeat:
    """Worker liveness. Beats arrive from worker threads while the
    supervisor polls from the driver, so the table is lock-protected —
    a :class:`~repro.core.lockcheck.SanitizedLock` leaf, so the training
    side participates in the suite-wide acquisition-order audit."""

    def __init__(self, timeout_s: float = 30.0) -> None:
        self.timeout_s = timeout_s
        self.last_beat: dict[str, float] = {}
        self._lock = lockcheck.make_lock("Heartbeat")

    def beat(self, worker: str, now: float | None = None) -> None:
        stamp = time.monotonic() if now is None else now
        with self._lock:
            self.last_beat[worker] = stamp

    def dead_workers(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        with self._lock:
            return [w for w, t in self.last_beat.items()
                    if now - t > self.timeout_s]

    def forget(self, worker: str) -> None:
        """Drop a worker from the table: a drained/retired replica must
        not keep reporting dead on every later poll."""
        with self._lock:
            self.last_beat.pop(worker, None)


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int
    restarts: int
    final_step: int
    history: list[str]


class Supervisor:
    """Run-to-completion driver with checkpoint/restart.

    ``step_fn(state, batch) -> (state, metrics)`` may raise — any exception
    triggers restore-from-latest + resume. ``save_every`` controls the
    checkpoint cadence; the data stream is addressed purely by step index.
    """

    def __init__(self, *, ckpt_dir: str, save_every: int = 10,
                 max_restarts: int = 5,
                 backoff_s: float = 0.0, max_backoff_s: float = 30.0,
                 heartbeat: Heartbeat | None = None) -> None:
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_restarts = max_restarts
        # restart-storm damping: the k-th consecutive restart sleeps
        # backoff_s * 2**(k-1), capped at max_backoff_s (0 = no backoff —
        # the prior behaviour). A crash loop with a persistent cause
        # (bad host, poisoned batch) otherwise burns its restart budget in
        # milliseconds and turns one fault into max_restarts of churn.
        self.backoff_s = backoff_s
        self.max_backoff_s = max_backoff_s
        self.heartbeat = heartbeat if heartbeat is not None else Heartbeat()
        # guards the live progress record (step/restarts/history): a
        # monitor thread reads status() while run() mutates. Documented
        # order: Supervisor -> Heartbeat (run() beats under its own
        # lock); the sanitizer audits it with the rest of the fleet.
        self._lock = lockcheck.make_lock("Supervisor")
        self._step = 0
        self._restarts = 0
        self._history: list[str] = []

    def status(self) -> tuple[int, int, list[str]]:
        """(current step, restarts so far, history copy) — safe to call
        from a monitor thread while ``run`` is live."""
        with self._lock:
            return self._step, self._restarts, list(self._history)

    def _note(self, step: int, entry: str | None = None,
              restarted: bool = False) -> None:
        with self._lock:
            self._step = step
            if restarted:
                self._restarts += 1
            if entry is not None:
                self._history.append(entry)
            self.heartbeat.beat("driver")

    def run(self, state: Any, step_fn: Callable, batch_fn: Callable,
            n_steps: int, *, start_step: int = 0) -> tuple[Any, SupervisorReport]:
        from ..ckpt.store import latest_step, restore_checkpoint, \
            save_checkpoint
        restarts = 0
        step = start_step
        steps_run = 0
        with self._lock:
            self._step, self._restarts = step, 0
            self._history = []
        history = self._history
        while step < n_steps:
            try:
                state, metrics = step_fn(state, batch_fn(step))
                steps_run += 1
                step += 1
                if step % self.save_every == 0 or step == n_steps:
                    save_checkpoint(self.ckpt_dir, step, state)
                    self._note(step, f"ckpt@{step}")
                else:
                    self._note(step)
            except Exception as e:   # noqa: BLE001 — any failure → restart
                restarts += 1
                self._note(step, f"fail@{step}:{type(e).__name__}",
                           restarted=True)
                if restarts > self.max_restarts:
                    raise
                last = latest_step(self.ckpt_dir)
                if last is None:
                    raise
                if self.backoff_s > 0:
                    delay = min(self.backoff_s * 2 ** (restarts - 1),
                                self.max_backoff_s)
                    self._note(step, f"backoff@{step}:{delay:.4g}s")
                    time.sleep(delay)
                state, step = restore_checkpoint(self.ckpt_dir, state)
                self._note(step, f"restored@{step}")
        return state, SupervisorReport(steps_run, restarts, step,
                                       list(history))


class SpeculativeLedger:
    """Dedup around :func:`speculative_redispatch`: at most one clone per
    straggling vertex, and once either copy completes the vertex is
    retired — the losing completion is counted as waste and must be
    dropped, never applied twice. Results are idempotent writes to planned
    extents, so correctness never *depends* on this class; what it buys is
    bounded speculation (no clone storms when the policy keeps flagging
    the same straggler every wakeup) and an audit trail."""

    def __init__(self) -> None:
        # leaf lock: completions arrive from worker threads while the
        # driver's wakeup loop asks try_clone
        self._lock = lockcheck.make_lock("SpeculativeLedger")
        self._inflight: set[int] = set()
        self._done: set[int] = set()
        self.cloned = 0
        self.wasted = 0          # completions that lost the race

    def try_clone(self, mid: int) -> bool:
        """True exactly once per straggling vertex until it completes —
        the caller dispatches the clone iff this returns True."""
        with self._lock:
            if mid in self._inflight or mid in self._done:
                return False
            self._inflight.add(mid)
            self.cloned += 1
            return True

    def complete(self, mid: int) -> bool:
        """Record a completion (original or clone). True for the winner
        (apply the result); False for the loser (drop it)."""
        with self._lock:
            if mid in self._done:
                self.wasted += 1
                return False
            self._done.add(mid)
            self._inflight.discard(mid)
            return True


def speculative_redispatch(durations: dict[int, float], op_medians:
                           dict[str, float], vertex_ops: dict[int, str],
                           *, factor: float = 3.0) -> list[int]:
    """Straggler rule: vertices running ≥ factor× the median duration of
    their op class are candidates for speculative re-dispatch. Pure policy
    function (unit-tested; the threaded runtime consults it per event-loop
    wakeup)."""
    out = []
    for mid, dur in durations.items():
        med = op_medians.get(vertex_ops.get(mid, ""), None)
        if med is not None and med > 0 and dur >= factor * med:
            out.append(mid)
    return out
