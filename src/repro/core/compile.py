"""Plan compilation: lower a certified MEMGRAPH into a straight-line
executor program (DESIGN.md §15; ROADMAP item 4).

The threaded :class:`~repro.core.runtime.TurnipRuntime` *interprets* the
memgraph vertex-by-vertex: every launch takes a lock round-trip, a heap
pop, and a condition-variable wakeup. TURNIP's own argument says that
freedom only pays where transfer completion times are unknown at compile
time; everywhere else the plan certifier (DESIGN.md §13) has already
proved **every** legal execution order race-free and tier-coherent, so
those spans can be frozen into a zero-dispatch program.

:func:`lower` turns a built :class:`~repro.core.build.BuildResult` plus a
chosen :class:`~repro.core.dispatch.DispatchPolicy` into a
:class:`CompiledPlan`:

* **linearization** — one topological order of the memgraph, tie-broken
  by the policy's static priorities, so the compiled program makes the
  same choices the event loop would make when nothing is in flight;
* **pre-resolved engines/streams** — every instruction carries its
  engine class and a round-robin stream id fixed at compile time (the
  runtime no longer consults ``engine_of`` or a ready heap per vertex);
* **dependency tick counts** — ``Instr.ready_tick`` is one past the
  linear position of the instruction's last predecessor. Because the
  linearization is topological, ``ready_tick <= pos`` holds for every
  instruction — proved once at compile time (:meth:`CompiledPlan.verify`)
  — so the straight-line executor needs no per-vertex dependency
  bookkeeping at all: position order *is* dependency order;
* **region segmentation** — a compile-time replay of the linearization
  finds the spans where the runtime's choice could genuinely respond to
  real-time transfer completions: a *nondeterministic window* is open at
  a position when ≥2 timing-sensitive vertices (byte-moving transfers,
  or vertices directly fed by one) are simultaneously ready on the same
  engine class. Maximal marked spans become ``nondet`` regions that fall
  back to the interpreter at their seam vertex; everything else is a
  ``static`` region executed straight-line. Segmentation is *never* a
  correctness decision — the certificate proved all orders safe — it
  preserves the paper's performance nondeterminacy where it can matter;
* **seam-backend stamping** (DESIGN.md §17) — every nondet region is
  stamped with the executor backend its seam should run on: ``inline``
  (the thread-free ready-heap executor on the calling thread) when the
  region is small (``seam_threshold``, ``BuildConfig`` knob), narrow
  (ready width ≤ :data:`MAX_INLINE_WIDTH`), and certified stall-free on
  the caller (``liveness.inline_seam_certified`` — an ``ok`` §14
  certificate, or no pool/disk admission ops at all); ``threaded`` (the
  persistent engine-stream fleet) otherwise. The runtime can force
  either backend (``seam_backend``) — stamping is a performance hint
  with a certified safety floor, never a correctness decision;
* **fused DMA batches** — maximal runs of adjacent same-(device, engine)
  DMA instructions inside a static region are fused into one batched
  submission: one enqueue, one completion wait. Legality is structural:
  the linearization is topological and a batch is a contiguous span, so
  every member's out-of-batch predecessor necessarily sits *before* the
  batch head — all external dependencies are complete when the batch
  issues, and in-batch order is preserved by the stream's FIFO. Runs on
  the ``disk`` engine additionally require an ``ok`` liveness
  certificate (DESIGN.md §14): a fused disk submission holds several
  credit admissions behind a single completion wait, which is only
  known stall-free because the liveness proof bounded every admission.
  Under the same certificate the H2D/D2H *engine pair* of one device
  fuses too: both directions drain through one DMA controller, and the
  liveness proof bounds every admission the paired batch can hold.

Plans whose soundness certificate is missing or not ``ok`` lower to a
single whole-plan ``nondet`` region: the interpreter keeps full freedom
and the compiled backend adds nothing but the counters.

CLI (CI fast lane)::

    PYTHONPATH=src python -m repro.core.compile --seeds 24

lowers the seeded example-plan corpus under every dispatch policy,
verifies each plan's tick counts / regions / batches, and replays the
linearization through the sequential interpreter against the dataflow
oracle — every certified plan must lower and replay byte-exactly.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import TYPE_CHECKING, Sequence

from .analyze import certify
from .dispatch import (COMPUTE, D2H, DISK, H2D, TRANSFER_KINDS,
                       DispatchPolicy, engine_key, engine_of, get_policy)
from .executor import INLINE, THREADED
from .liveness import LivenessCertificate, inline_seam_certified
from .memgraph import MemGraph, MemOp

if TYPE_CHECKING:                      # no import cycle at runtime
    from .build import BuildResult

__all__ = ["CompiledPlan", "Instr", "Region", "PlanCompileError", "lower"]

STATIC = "static"
NONDET = "nondet"

# adjacent nondet regions separated by fewer than this many static
# positions are merged: each seam hands a thread fleet up and back down,
# so hairline static slivers between two windows cost more than they save
DEFAULT_MERGE_GAP = 3

# fused submissions are bounded so one batch's completion wait cannot
# defer an unboundedly long tail of downstream work
MAX_FUSE = 16

# seam-backend stamping (DESIGN.md §17): a nondet region at most this
# long runs on the thread-free inline executor — overridable per plan via
# BuildConfig.seam_threshold. Above it (or when the region's ready sets
# grow wider than MAX_INLINE_WIDTH — enough concurrent freedom that real
# streams could genuinely overlap), the threaded fleet keeps the paper's
# parallel event loop.
DEFAULT_SEAM_THRESHOLD = 64
MAX_INLINE_WIDTH = 8


class PlanCompileError(RuntimeError):
    """A CompiledPlan failed verification (lowering bug, or a hand-edited
    plan violating the tick/region/batch invariants)."""


@dataclasses.dataclass(frozen=True)
class Instr:
    """One lowered instruction: a memgraph vertex with its dispatch
    decisions pre-resolved."""

    mid: int
    pos: int                 # position in the linear order
    device: int
    engine: str              # engine class (dispatch.ENGINE_KINDS)
    stream: int              # pre-assigned stream id within (device, engine)
    ready_tick: int          # 1 + max linear position of predecessors (0 = source)
    region: int              # index into CompiledPlan.regions
    batch: int               # head position of the fused batch, or own pos


@dataclasses.dataclass(frozen=True)
class Region:
    """A contiguous span ``[start, end)`` of the linear order."""

    kind: str                # STATIC | NONDET
    start: int
    end: int
    # NONDET regions carry the seam backend the compiler chose for them
    # (DESIGN.md §17): "inline" for small certified seams, "threaded"
    # for large windows. STATIC regions leave it "".
    backend: str = ""

    def __len__(self) -> int:
        return self.end - self.start


@dataclasses.dataclass
class CompiledPlan:
    """The lowered program: linear order, instructions, regions, batches.

    ``batches`` are index spans ``(a, b)`` into ``order`` with
    ``b - a >= 2``: the instructions in a span issue as one fused DMA
    submission. ``seams`` are the memgraph ids at which the straight-line
    executor hands off to the interpreter (the first vertex of every
    nondet region)."""

    order: list[int]
    instrs: list[Instr]
    regions: list[Region]
    batches: list[tuple[int, int]]
    policy_name: str
    certified: bool                    # soundness certificate was ok
    liveness_certified: bool           # liveness certificate was ok
    # the inline-stamping size bound this plan was lowered under
    # (DESIGN.md §17) — verify() re-checks every inline region against it
    seam_threshold: int = DEFAULT_SEAM_THRESHOLD

    @property
    def n_vertices(self) -> int:
        return len(self.order)

    @property
    def n_static(self) -> int:
        return sum(len(r) for r in self.regions if r.kind == STATIC)

    @property
    def n_nondet(self) -> int:
        return sum(len(r) for r in self.regions if r.kind == NONDET)

    @property
    def n_inline(self) -> int:
        return sum(len(r) for r in self.regions
                   if r.kind == NONDET and r.backend == INLINE)

    @property
    def n_threaded(self) -> int:
        return sum(len(r) for r in self.regions
                   if r.kind == NONDET and r.backend == THREADED)

    @property
    def seams(self) -> tuple[int, ...]:
        return tuple(self.order[r.start] for r in self.regions
                     if r.kind == NONDET)

    @property
    def batch_heads(self) -> dict[int, tuple[int, int]]:
        """Batch-head position -> its ``(start, end)`` span."""
        return {a: (a, b) for a, b in self.batches}

    @property
    def fused_map(self) -> dict[int, int]:
        """Member mid -> batch-head mid, for every fused instruction
        (heads map to themselves). The simulator prices non-head members
        without the fixed submission latency
        (:func:`~repro.core.simulate.simulate`'s ``fused=``)."""
        out: dict[int, int] = {}
        for a, b in self.batches:
            head = self.order[a]
            for i in range(a, b):
                out[self.order[i]] = head
        return out

    def summary(self) -> str:
        return (f"compiled[{self.policy_name}]: {self.n_vertices} instrs, "
                f"{self.n_static} static / {self.n_nondet} nondet "
                f"({self.n_inline} inline, {self.n_threaded} threaded) over "
                f"{len(self.regions)} region(s), {len(self.batches)} fused "
                f"DMA batch(es), certified={self.certified}")

    # -- static verification ------------------------------------------------
    def verify(self, mg: MemGraph) -> None:
        """Re-prove the invariants the executor relies on; raises
        :class:`PlanCompileError` on any violation.

        * the linear order is a permutation of the memgraph;
        * tick counts: ``ready_tick == 1 + max(pos of preds)`` and
          ``ready_tick <= pos`` (the order is topological — position
          order implies dependency order);
        * regions partition ``[0, n)`` contiguously;
        * backend stamps: every nondet region carries ``inline`` or
          ``threaded``; static regions carry none; an inline region fits
          ``seam_threshold``, and — when the plan is not
          liveness-certified — contains no admission vertex (OFFLOAD /
          SPILL / LOAD), the vacuous face of the §17 soundness argument;
        * every batch is a contiguous span of one static region, all
          members share one (device, engine) DMA stream — or, on a
          liveness-certified plan, one device's H2D/D2H *engine pair* —
          and every member's out-of-batch predecessor precedes the
          batch head.
        """
        n = len(self.order)
        if sorted(self.order) != sorted(mg.vertices):
            raise PlanCompileError("linear order is not a permutation of "
                                   "the memgraph vertices")
        pos = {m: i for i, m in enumerate(self.order)}
        for ins in self.instrs:
            want = max((pos[p] + 1 for p in mg.preds[ins.mid]), default=0)
            if ins.ready_tick != want:
                raise PlanCompileError(
                    f"instr {ins.mid}@{ins.pos}: ready_tick "
                    f"{ins.ready_tick} != {want}")
            if ins.ready_tick > ins.pos:
                raise PlanCompileError(
                    f"instr {ins.mid}@{ins.pos}: not topological "
                    f"(ready_tick {ins.ready_tick})")
        at = 0
        for r in self.regions:
            if r.start != at or r.end <= r.start:
                raise PlanCompileError(f"regions do not partition the "
                                       f"order at {at}: {r}")
            at = r.end
            if r.kind == NONDET:
                if r.backend not in (INLINE, THREADED):
                    raise PlanCompileError(
                        f"nondet region {r} has no seam-backend stamp")
                if r.backend == INLINE:
                    if len(r) > self.seam_threshold:
                        raise PlanCompileError(
                            f"inline region {r} exceeds seam_threshold "
                            f"{self.seam_threshold}")
                    if not self.liveness_certified and any(
                            mg.vertices[self.order[i]].op in
                            (MemOp.OFFLOAD, MemOp.SPILL, MemOp.LOAD)
                            for i in range(r.start, r.end)):
                        raise PlanCompileError(
                            f"inline region {r} contains admission "
                            f"vertices on an uncertified-liveness plan — "
                            f"the calling thread could block (§17)")
            elif r.backend:
                raise PlanCompileError(
                    f"static region {r} carries a seam-backend stamp")
        if self.regions and at != n:
            raise PlanCompileError(f"regions end at {at}, order has {n}")
        region_of = [r for r in self.regions for _ in range(len(r))]
        for a, b in self.batches:
            if b - a < 2:
                raise PlanCompileError(f"batch ({a},{b}) has <2 members")
            head = mg.vertices[self.order[a]]
            key = engine_key(head)
            if key[1] not in TRANSFER_KINDS:
                raise PlanCompileError(f"batch ({a},{b}) head is not a "
                                       f"DMA instruction ({key[1]})")
            if region_of[a].kind != STATIC or region_of[b - 1] is not \
                    region_of[a]:
                raise PlanCompileError(
                    f"batch ({a},{b}) crosses a region boundary or sits "
                    f"in a nondet region")
            kinds = {engine_key(mg.vertices[self.order[i]])
                     for i in range(a, b)}
            if len(kinds) > 1:
                # one legal mixture: the H2D/D2H engine pair of one
                # device, and only on a liveness-certified plan (the
                # paired submission holds both DMA lanes behind one
                # completion wait — known stall-free only under §14)
                if not ({k for _, k in kinds} <= {H2D, D2H}
                        and len({d for d, _ in kinds}) == 1
                        and self.liveness_certified):
                    raise PlanCompileError(
                        f"batch ({a},{b}) mixes streams: "
                        f"{sorted(kinds)}")
            for i in range(a, b):
                for p in mg.preds[self.order[i]]:
                    if a <= pos[p] < i:
                        continue       # in-batch: stream FIFO preserves it
                    if pos[p] >= a:
                        raise PlanCompileError(
                            f"batch ({a},{b}): member {self.order[i]} "
                            f"depends on {p}@{pos[p]} which is not "
                            f"complete when the batch issues")


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------
def _timing_sensitive(mg: MemGraph) -> dict[int, bool]:
    """A vertex is timing-sensitive when its launch order can respond to a
    real-time transfer completion: it is a byte-moving transfer itself, or
    it is directly fed by one (its readiness instant *is* a transfer's
    completion instant)."""
    moves = {m: (engine_of(v) in TRANSFER_KINDS and v.nbytes > 0)
             for m, v in mg.vertices.items()}
    return {m: (moves[m] or any(moves[p] for p in mg.preds[m]))
            for m in mg.vertices}


def _segment(mg: MemGraph, order: list[int], *,
             merge_gap: int) -> list[Region]:
    """Replay the linearization, marking every position at which a
    nondeterministic window is open: ≥2 timing-sensitive vertices
    simultaneously ready on the same (device, engine class). Maximal
    marked spans (merged across static slivers shorter than
    ``merge_gap``) become nondet regions."""
    verts = mg.vertices
    ts = _timing_sensitive(mg)
    remaining = {m: len(mg.preds[m]) for m in verts}
    ready_ts: dict[tuple[int, str], int] = {}
    hot = 0                            # engine keys with >=2 ready ts verts

    def bump(m: int, delta: int) -> None:
        nonlocal hot
        if not ts[m]:
            return
        v = verts[m]
        key = engine_key(v)
        was = ready_ts.get(key, 0)
        now = was + delta
        ready_ts[key] = now
        if was < 2 <= now:
            hot += 1
        elif now < 2 <= was:
            hot -= 1

    for m, r in remaining.items():
        if r == 0:
            bump(m, +1)
    mark = [False] * len(order)
    for i, m in enumerate(order):
        mark[i] = hot > 0
        bump(m, -1)
        for s in mg.succs[m]:
            remaining[s] -= 1
            if remaining[s] == 0:
                bump(s, +1)

    # merge: a static sliver shorter than merge_gap between two nondet
    # spans is absorbed (each seam pays a thread-fleet spin-up)
    spans: list[list[int]] = []        # [start, end) of marked runs
    i = 0
    n = len(order)
    while i < n:
        if mark[i]:
            j = i
            while j < n and mark[j]:
                j += 1
            if spans and i - spans[-1][1] < merge_gap:
                spans[-1][1] = j
            else:
                spans.append([i, j])
            i = j
        else:
            i += 1

    regions: list[Region] = []
    at = 0
    for a, b in spans:
        if b - a == 1:
            # a window that admits exactly one position has exactly one
            # execution order — interpreting a 1-element subset recovers
            # the same straight-line step, so keep it static
            continue
        if a > at:
            regions.append(Region(STATIC, at, a))
        regions.append(Region(NONDET, a, b))
        at = b
    if at < n:
        regions.append(Region(STATIC, at, n))
    if not regions and n:
        regions.append(Region(STATIC, 0, n))
    return regions


def _fuse(mg: MemGraph, order: list[int], regions: list[Region], *,
          liveness_ok: bool, max_fuse: int) -> list[tuple[int, int]]:
    """Maximal runs of adjacent same-(device, engine) DMA instructions
    inside static regions; see the module docstring for the legality
    argument. Disk-engine runs require the liveness certificate — and so
    does fusing *across* one device's H2D/D2H engine pair (a paired
    submission holds both DMA lanes of the device behind a single
    completion wait; §14's proof is what makes that wait known
    stall-free). In-batch order is preserved either way: a fused span
    issues back-to-back in position order."""

    def fuse_key(m: int) -> tuple[int, str] | None:
        d, eng = engine_key(mg.vertices[m])
        if eng not in TRANSFER_KINDS:
            return None
        if eng == DISK and not liveness_ok:
            return None
        if liveness_ok and eng in (H2D, D2H):
            return (d, "h2d|d2h")      # the device's DMA engine pair
        return (d, eng)

    batches: list[tuple[int, int]] = []
    for r in regions:
        if r.kind != STATIC:
            continue
        i = r.start
        while i < r.end:
            key = fuse_key(order[i])
            if key is None:
                i += 1
                continue
            j = i + 1
            while j < r.end and j - i < max_fuse:
                if fuse_key(order[j]) != key:
                    break
                j += 1
            if j - i >= 2:
                batches.append((i, j))
            i = j
    return batches


def _ready_width(mg: MemGraph, mids: Sequence[int]) -> int:
    """The widest simultaneously-ready set a seam exposes, replaying its
    members in linearization order with out-of-seam predecessors treated
    as complete — the concurrency the threaded fleet could actually
    exploit. A seam this narrow (≤ MAX_INLINE_WIDTH) gains little from
    real streams, so it is a candidate for the inline backend."""
    subset = set(mids)
    remaining = {m: sum(1 for p in mg.preds[m] if p in subset)
                 for m in mids}
    ready = {m for m, r in remaining.items() if r == 0}
    width = len(ready)
    for m in mids:
        ready.discard(m)
        for s in mg.succs[m]:
            if s in remaining:
                remaining[s] -= 1
                if remaining[s] == 0:
                    ready.add(s)
        width = max(width, len(ready))
    return width


def _stamp_backends(mg: MemGraph, order: list[int],
                    regions: list[Region], *, seam_threshold: int,
                    lcert: LivenessCertificate | None) -> list[Region]:
    """Stamp every NONDET region with its seam backend (DESIGN.md §17):
    ``inline`` when the region is small (≤ ``seam_threshold``), narrow
    (ready width ≤ MAX_INLINE_WIDTH), and the no-blocking-waits claim is
    certified (:func:`~repro.core.liveness.inline_seam_certified`) —
    otherwise it demotes to ``threaded``."""
    out: list[Region] = []
    for r in regions:
        if r.kind != NONDET:
            out.append(r)
            continue
        mids = order[r.start:r.end]
        backend = THREADED
        if (len(r) <= seam_threshold
                and _ready_width(mg, mids) <= MAX_INLINE_WIDTH
                and inline_seam_certified(mg, mids, lcert)):
            backend = INLINE
        out.append(dataclasses.replace(r, backend=backend))
    return out


def lower(res: "BuildResult", *,
          policy: str | DispatchPolicy | None = None,
          seed: int | None = None,
          n_streams: int = 5, n_transfer_streams: int = 1,
          merge_gap: int = DEFAULT_MERGE_GAP,
          max_fuse: int = MAX_FUSE,
          seam_threshold: int | None = None) -> CompiledPlan:
    """Lower ``res`` under ``policy`` into a :class:`CompiledPlan`.

    Uses ``res.certificate`` when the build carried one
    (``BuildConfig.certify``); otherwise the soundness certifier runs
    here (race-freedom and tier coherence for all orders — the property
    that lets static regions drop runtime dispatch entirely). A plan
    that cannot be certified lowers to one whole-plan nondet region.
    ``res.liveness_certificate`` (when present and ok) additionally
    enables fusing disk-engine runs and the H2D/D2H pair.

    ``seam_threshold`` bounds inline-backend stamping (DESIGN.md §17);
    ``None`` defers to ``res.seam_threshold`` (``BuildConfig``'s knob)
    and then :data:`DEFAULT_SEAM_THRESHOLD`."""
    mg = res.memgraph
    pol = get_policy(policy, seed=seed)
    pol.prepare(mg)
    verts = mg.vertices
    if seam_threshold is None:
        seam_threshold = getattr(res, "seam_threshold", None)
    if seam_threshold is None:
        seam_threshold = DEFAULT_SEAM_THRESHOLD

    order = mg.topo_order(
        key=lambda m: (pol.priority(m), verts[m].seq, m))
    pos = {m: i for i, m in enumerate(order)}

    cert = res.certificate
    if cert is None:
        cert = certify(mg)
    certified = bool(getattr(cert, "ok", False))
    lcert = res.liveness_certificate
    liveness_ok = bool(lcert is not None and getattr(lcert, "ok", False))

    if certified and order:
        regions = _segment(mg, order, merge_gap=merge_gap)
    elif order:
        # uncertified: the interpreter keeps full freedom over the plan
        regions = [Region(NONDET, 0, len(order))]
    else:
        regions = []
    regions = _stamp_backends(mg, order, regions,
                              seam_threshold=seam_threshold, lcert=lcert)
    batches = _fuse(mg, order, regions, liveness_ok=liveness_ok,
                    max_fuse=max_fuse)
    head_of: dict[int, int] = {}
    for a, b in batches:
        for i in range(a, b):
            head_of[i] = a

    region_idx = [ri for ri, r in enumerate(regions)
                  for _ in range(len(r))]
    streams: dict[tuple[int, str], int] = {}
    instrs: list[Instr] = []
    for i, m in enumerate(order):
        v = verts[m]
        eng = engine_of(v)
        key = (v.device, eng)
        width = n_streams if eng == COMPUTE else n_transfer_streams
        s = streams.get(key, 0)
        streams[key] = (s + 1) % max(width, 1)
        instrs.append(Instr(
            mid=m, pos=i, device=v.device, engine=eng, stream=s,
            ready_tick=max((pos[p] + 1 for p in mg.preds[m]), default=0),
            region=region_idx[i], batch=head_of.get(i, i)))

    plan = CompiledPlan(order=order, instrs=instrs, regions=regions,
                        batches=batches, policy_name=pol.name,
                        certified=certified, liveness_certified=liveness_ok,
                        seam_threshold=seam_threshold)
    plan.verify(mg)
    return plan


# ---------------------------------------------------------------------------
# CLI: lower + replay the seeded example-plan corpus (CI fast lane)
# ---------------------------------------------------------------------------
def main(argv: Sequence[str] | None = None) -> int:
    import random as pyrandom

    import numpy as np

    from .analyze import _corpus_taskgraph
    from .build import BuildConfig, MemgraphOOM, build_memgraph
    from .dispatch import POLICY_NAMES
    from .liveness import certify_progress, default_pool_config
    from .runtime import TurnipRuntime, eval_taskgraph, run_in_order

    p = argparse.ArgumentParser(
        prog="python -m repro.core.compile",
        description="Lower the seeded example-plan corpus under every "
                    "dispatch policy: each certified plan must lower, "
                    "verify, and replay byte-exactly (DESIGN.md §15).")
    p.add_argument("--seeds", type=int, default=24,
                   help="corpus size (default 24)")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="print one summary line per plan")
    args = p.parse_args(argv)

    host_caps = (None, 1, 2, 3)
    disk_caps = (None, 0, 2, 4, 50)
    n_ok = n_oom = failed = 0
    total_static = total_nondet = total_batches = 0
    total_inline = total_threaded = 0
    for seed in range(args.seeds):
        rng = pyrandom.Random(1000 + seed)
        tg = _corpus_taskgraph(rng)
        host_cap = rng.choice(host_caps)
        disk_cap = rng.choice(disk_caps) if host_cap is not None else None
        cfg = BuildConfig(capacity=3, host_capacity=host_cap,
                          disk_capacity=disk_cap, rng_seed=seed,
                          size_fn=lambda v: 1, backend="compiled")
        try:
            res = build_memgraph(tg, cfg)
        except MemgraphOOM:
            n_oom += 1
            if args.verbose:
                print(f"seed {seed}: rejected at compile time (OOM)")
            continue
        # attach a liveness certificate when the proof goes through, so
        # the corpus also exercises disk-engine fusion (gated on it)
        try:
            lcert = certify_progress(
                res.memgraph,
                default_pool_config(cfg.host_budget()),
                disk_capacity=cfg.disk_capacity)
            if lcert.ok:
                res.liveness_certificate = lcert
        except Exception:
            pass
        inputs = {t: np.random.default_rng(seed).integers(
                      -3, 4, v.out.shape).astype(np.float64)
                  for t, v in tg.vertices.items()
                  if v.kind.value == "input"}
        ref = eval_taskgraph(tg, inputs)
        bad = False
        for pol_name in POLICY_NAMES:
            try:
                plan = lower(res, policy=pol_name, seed=seed)
                # the linearization itself must be a valid schedule
                out = run_in_order(tg, res, inputs, plan.order)
                for k in ref:
                    if not np.array_equal(out[k], ref[k]):
                        raise PlanCompileError(
                            f"linearization replay diverged on output {k}")
                # every nondet region must carry a seam-backend stamp
                # (DESIGN.md §17); plan.verify() enforces the inline
                # soundness conditions on top
                for r in plan.regions:
                    if r.kind == NONDET and r.backend not in (INLINE,
                                                              THREADED):
                        raise PlanCompileError(
                            f"unstamped nondet region {r}")
                total_static += plan.n_static
                total_nondet += plan.n_nondet
                total_inline += plan.n_inline
                total_threaded += plan.n_threaded
                total_batches += len(plan.batches)
            except Exception as e:
                print(f"seed {seed}/{pol_name}: FAILED ({e})")
                bad = True
        # the full compiled executor (straight-line + seam backends),
        # under the compiler's stamps and with every seam forced inline
        for seam_backend in ("auto", INLINE):
            try:
                rr = TurnipRuntime(tg, res, mode="nondet",
                                   policy="critical-path", seed=seed,
                                   seam_backend=seam_backend).run(inputs)
                for k in ref:
                    if not np.array_equal(rr.outputs[k], ref[k]):
                        raise PlanCompileError(
                            f"compiled executor diverged on output {k}")
                assert rr.n_compiled + rr.n_interpreted == \
                    len(res.memgraph.vertices)
                assert rr.n_inline + rr.n_threaded == rr.n_interpreted
                if seam_backend == INLINE:
                    assert rr.n_threaded == 0
            except Exception as e:
                print(f"seed {seed}/executor[{seam_backend}]: "
                      f"FAILED ({e})")
                bad = True
        if bad:
            failed += 1
        else:
            n_ok += 1
            if args.verbose:
                print(f"seed {seed}: ok ({plan.summary()})")
    print(f"corpus: {n_ok} plans lowered + replayed byte-exactly, "
          f"{n_oom} rejected at compile time, {failed} failed; "
          f"{total_static} static / {total_nondet} nondet instrs "
          f"({total_inline} inline, {total_threaded} threaded), "
          f"{total_batches} fused batches across all policies")
    return 1 if failed else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
