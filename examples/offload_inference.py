"""Paper task 1 at example scale: memory-constrained prefill through the
full TURNIP stack — trace a transformer, compile MEMGRAPHs under shrinking
device budgets, execute with the threaded runtime, and report how offload
traffic and simulated latency grow as memory shrinks (a miniature Fig. 10).

    PYTHONPATH=src python examples/offload_inference.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import BuildConfig, MemgraphOOM, POLICY_NAMES, build_memgraph
from repro.core.runtime import TurnipRuntime, eval_taskgraph
from repro.core.simulate import HardwareModel, simulate
from repro.core.trace import TraceConfig, trace_prefill


def main() -> None:
    cfg = ArchConfig(name="demo-120m", family="dense", n_layers=4,
                     d_model=256, n_heads=8, n_kv_heads=8, d_ff=1024,
                     vocab_size=512)
    tr = trace_prefill(cfg, seq_len=256, trace=TraceConfig(
        n_devices=2, head_group=2, q_block=64, mlp_slices=2))
    inputs = tr.make_inputs(seed=1, scale=0.1)
    ref = eval_taskgraph(tr.tg, inputs)
    total = sum(v.out.nbytes for v in tr.tg.vertices.values()
                if v.device == 0)
    hw = HardwareModel(flops=9e12, h2d_bw=11e9, d2h_bw=11e9,
                       transfer_jitter=0.5, seed=0)
    print(f"graph: {tr.tg.stats()}")
    print(f"{'budget':>8s} {'offloads':>9s} {'reloads':>8s} "
          f"{'sim ms':>8s} {'exact':>6s}")
    tightest = None
    for frac in (1.0, 0.5, 0.25, 0.12, 0.05):
        cap = int(total * frac)
        try:
            res = build_memgraph(tr.tg, BuildConfig(capacity=cap))
        except MemgraphOOM:
            print(f"{frac:8.2f} {'OOM':>9s}")
            continue
        rr = TurnipRuntime(tr.tg, res, mode="nondet", seed=0).run(inputs)
        exact = np.allclose(rr.outputs[tr.meta["logits"]],
                            ref[tr.meta["logits"]], rtol=1e-5)
        sim = simulate(res.memgraph, hw)
        print(f"{frac:8.2f} {res.n_offloads:9d} {res.n_reloads:8d} "
              f"{sim.makespan*1e3:8.2f} {str(exact):>6s}")
        tightest = res

    # dispatch-policy ablation at the tightest feasible budget: same graph,
    # same memory plan, different ready-queue ranking (simulated makespan —
    # the threaded analogue lives in benchmarks/threaded_runtime.py).
    if tightest is not None:
        print("\ndispatch policies at tightest budget "
              f"({tightest.n_reloads} reloads):")
        fixed_ms = simulate(tightest.memgraph, hw, mode="fixed").makespan
        for policy in POLICY_NAMES:
            sim = simulate(tightest.memgraph, hw, policy=policy)
            print(f"  {policy:>14s}: {sim.makespan*1e3:8.2f} ms "
                  f"(fixed-issue order: {fixed_ms/sim.makespan:.2f}x slower)")


if __name__ == "__main__":
    main()
