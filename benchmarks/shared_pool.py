"""Shared host pool benchmark: one arbitrated budget, two consumers
(DESIGN.md §12).

TURNIP treats CPU RAM as the cheap tier that makes a small device budget
survivable — but CPU RAM is one physical pool, and the MEMGRAPH runtime's
offload traffic and the serving engine's KV mirror used to budget it
independently. This benchmark runs both against ONE
:class:`~repro.core.pool.HostPool` and answers three questions:

1. **Does arbitration preserve results?** For every arbitration policy
   (static / demand / priority), a MEMGRAPH plan and the serving engine
   run *concurrently* on one pool; the plan's outputs must be
   byte-identical to an isolated-pool run and the engine's tokens must
   match the isolated engine token-for-token. Leases move grants, fire
   revocations, and defer transfers — timing only, never results.

2. **Is the bound real?** The pool's ``peak_bytes`` (reservations + plan
   occupancy) must never exceed its capacity, while each consumer still
   makes progress — the whole point of pool-level arbitration over
   per-consumer budgets that can jointly overcommit.

3. **What does contention cost?** The discrete-event simulator prices the
   cross-consumer revocation stalls (``HardwareModel.pool_contention`` /
   ``revoke_stall``): the same plan is simulated with an isolated pool
   (contention 0) and under serving pressure, quantifying the makespan a
   co-resident consumer costs a MEMGRAPH plan.

CSV contract: ``name,us_per_call,derived`` via :func:`benchmarks.common.emit`.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                     # noqa: E402
import numpy as np                                             # noqa: E402

from repro.configs.base import ArchConfig                      # noqa: E402
from repro.core import (ARBITRATION_POLICY_NAMES, BuildConfig,  # noqa: E402
                        HostPool, build_memgraph)
from repro.core.runtime import TurnipRuntime, eval_taskgraph   # noqa: E402
from repro.core.simulate import simulate                       # noqa: E402
from repro.models import build_model                           # noqa: E402
from repro.serve import (Engine, PagedKVCache, ServeConfig,    # noqa: E402
                         naive_generate)

from .common import P100_SERVER, emit                          # noqa: E402
from .tiered_offload import activation_workload                # noqa: E402

ARCH = ArchConfig(name="pool-demo", family="dense", n_layers=2,
                  d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                  vocab_size=256, dtype="float32")
MAX_LEN = 64
BLOCK = 8


def _serve_cfg() -> ServeConfig:
    return ServeConfig(max_len=MAX_LEN, batch_buckets=(1,), block_size=BLOCK,
                       offload=True, hot_window=0, offload_fraction=1.0,
                       preempt_every=3, h2d_bw=500e6, d2h_bw=500e6,
                       disk_bw=300e6)


def run(quick: bool = True) -> list[dict]:
    # ---- the two workloads -------------------------------------------
    tg = activation_workload(n_layers=6 if quick else 12, batch=16, d=64)
    act_bytes = tg.vertices[0].out.nbytes
    res = build_memgraph(tg, BuildConfig(capacity=6 * act_bytes,
                                         host_capacity=6 * act_bytes))
    assert res.n_spills > 0, "plan never pressed the host tier"
    rng = np.random.default_rng(0)
    inputs = {t: rng.standard_normal(v.out.shape).astype(np.float32) * 0.1
              for t, v in tg.vertices.items() if v.kind.value == "input"}
    ref = eval_taskgraph(tg, inputs)

    model = build_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(rng.integers(1, ARCH.vocab_size, n))
               for n in (24, 18, 9)]
    max_new = 6
    want = [naive_generate(model, params, p, max_new=max_new,
                           max_len=MAX_LEN, rid=i, seed=0)
            for i, p in enumerate(prompts)]
    blk = PagedKVCache(model, 1, MAX_LEN, block_size=BLOCK).block_nbytes

    # ---- isolated baseline: each consumer on a private pool ----------
    rr_iso = TurnipRuntime(tg, res, mode="nondet", policy="critical-path",
                           seed=0).run(inputs)
    for k in ref:
        np.testing.assert_array_equal(rr_iso.outputs[k], ref[k])
    with Engine(model, params, _serve_cfg()) as eng:
        out_iso = eng.generate(prompts, max_new=max_new)
    assert out_iso == want
    emit("shared_pool/isolated", rr_iso.makespan * 1e6,
         f"runtime_peak_host_B={rr_iso.peak_host_bytes};"
         f"tokens={sum(len(o) for o in out_iso)}")

    rows: list[dict] = []
    # ---- 1+2: both consumers, one pool, every arbitration policy ------
    mem_floor = rr_iso.peak_host_bytes
    capacity = 8 * blk + mem_floor
    for arb in ARBITRATION_POLICY_NAMES:
        pool = HostPool(capacity, policy=arb)
        mem_lease = pool.lease("memgraph", min_bytes=mem_floor, priority=1)
        box: dict = {}

        def run_runtime():
            rt = TurnipRuntime(tg, res, mode="nondet",
                               policy="critical-path", seed=0,
                               host_lease=mem_lease)
            box["rr"] = rt.run(inputs)

        with Engine(model, params, _serve_cfg(), pool=pool) as eng:
            th = threading.Thread(target=run_runtime)
            th.start()
            out = eng.generate(prompts, max_new=max_new)
            th.join(120)
            assert not th.is_alive(), f"pooled runtime wedged under {arb}"
            st = eng.stats
            snap = pool.snapshot()      # before close() retires the leases
        rr = box["rr"]
        # the headline invariants: byte-identical results, bounded pool
        assert out == want, f"{arb}: serving tokens diverged"
        for k in ref:
            np.testing.assert_array_equal(
                rr.outputs[k], ref[k],
                err_msg=f"{arb}: runtime output {k} diverged")
        assert snap["peak_bytes"] <= snap["capacity"], \
            f"{arb}: pool burst its budget ({snap})"
        assert snap["used_bytes"] == snap["leases"]["memgraph"]["used"], \
            f"{arb}: serving leases did not drain"
        rows.append(dict(policy=arb, makespan_ms=rr.makespan * 1e3,
                         peak=snap["peak_bytes"], cap=snap["capacity"],
                         revocations=snap["revocations"],
                         deferrals=st.lease_deferrals))
        emit(f"shared_pool/{arb}", rr.makespan * 1e6,
             f"peak_B={snap['peak_bytes']}/{snap['capacity']};"
             f"revocations={snap['revocations']};"
             f"deferrals={st.lease_deferrals};"
             f"kv_refusals={snap['leases']['kv']['refusals']};"
             f"byte_identical=1")

    # ---- 3: the simulator prices cross-consumer revocation stalls -----
    hw = dataclasses.replace(P100_SERVER["hw"], transfer_jitter=0.0)
    s_iso = simulate(res.memgraph, hw, mode="nondet", policy="critical-path")
    hw_shared = dataclasses.replace(hw, pool_contention=0.3,
                                    revoke_stall=2e-3)
    s_shared = simulate(res.memgraph, hw_shared, mode="nondet",
                        policy="critical-path")
    assert s_shared.makespan >= s_iso.makespan
    rows.append(dict(sim_iso_ms=s_iso.makespan * 1e3,
                     sim_shared_ms=s_shared.makespan * 1e3))
    emit("shared_pool/contention_price", s_shared.makespan * 1e6,
         f"isolated_ms={s_iso.makespan*1e3:.2f};"
         f"shared_ms={s_shared.makespan*1e3:.2f};"
         f"slowdown={s_shared.makespan/max(s_iso.makespan, 1e-12):.2f}x")
    return rows


if __name__ == "__main__":   # PYTHONPATH=src python -m benchmarks.shared_pool
    run(quick=True)
