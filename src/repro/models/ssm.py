"""Mamba2 / SSD blocks (for zamba2-7b) — chunked state-space duality scan.

Faithful to Mamba-2 (arXiv:2405.21060) structure: in-proj → short causal
conv → SSD with scalar-per-head decay A, per-token Δ, B, C of state size N —
computed with the chunked algorithm (intra-chunk quadratic + inter-chunk
state passing via ``lax.scan``), which is the TPU-friendly formulation (the
Pallas kernel in :mod:`repro.kernels.ssd_scan` tiles the same algorithm).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def ssd_init(key: Array, d_model: int, *, d_state: int = 64,
             headdim: int = 64, expand: int = 2, d_conv: int = 4,
             dtype=jnp.float32) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d_model)
    return {
        # projections: [z (gate), x, B, C, dt]
        "in_proj": jax.random.normal(
            ks[0], (d_model, 2 * d_inner + 2 * d_state + n_heads), dtype) * s,
        "conv_w": jax.random.normal(
            ks[1], (d_conv, d_inner + 2 * d_state), dtype) * 0.1,
        "conv_b": jnp.zeros((d_inner + 2 * d_state,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_g": jnp.ones((d_inner,), dtype),
        "out_proj": jax.random.normal(
            ks[2], (d_inner, d_model), dtype) / math.sqrt(d_inner),
    }


def _ssd_chunked(xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                 chunk: int = 128,
                 h0: Array | None = None) -> tuple[Array, Array]:
    """Chunked SSD scan.

    xh: [B, S, H, P] per-head inputs; dt: [B, S, H] (softplus'ed);
    A: [H] (negative decay rates); Bm/Cm: [B, S, N].
    Returns (y: [B, S, H, P], final state [B, H, P, N])."""
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    nch = max(1, (S + chunk - 1) // chunk)
    pad = nch * chunk - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    L = nch * chunk
    xc = xh.reshape(Bsz, nch, chunk, H, Pd)
    dtc = dt.reshape(Bsz, nch, chunk, H)
    Bc = Bm.reshape(Bsz, nch, chunk, N)
    Cc = Cm.reshape(Bsz, nch, chunk, N)

    dA = dtc * A[None, None, None, :]                 # [B,c,l,H] (negative)
    seg = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum

    def chunk_step(h, inp):
        xj, dtj, Bj, Cj, dAj, segj = inp              # [B,l,...]
        # intra-chunk (quadratic in l): y_intra[t] = C_t · Σ_{s<=t} ...
        # mask the exponent INPUT: upper-triangle diffs are positive and can
        # overflow exp to inf, which poisons the where-VJP with inf·0 = NaN.
        diff = segj[:, :, None, :] - segj[:, None, :, :]             # [B,t,s,H]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.exp(jnp.where(tri[None, :, :, None], diff, -1e30))
        cb = jnp.einsum("btn,bsn->bts", Cj, Bj)       # [B,t,s]
        w = cb[..., None] * decay * dtj[:, None, :, :]        # [B,t,s,H]
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xj)
        # contribution of incoming state
        y_state = jnp.einsum("btn,bhpn,bth->bthp", Cj, h,
                             jnp.exp(segj))
        # state update: h' = h * exp(sum dA) + Σ_s exp(seg_end - seg_s) dt_s B_s x_s
        tail = jnp.exp(segj[:, -1:, :] - segj)        # [B,l,H]
        upd = jnp.einsum("bsh,bsn,bshp->bhpn", tail * dtj, Bj, xj)
        h_new = h * jnp.exp(dAj.sum(axis=1))[:, :, None, None] + upd
        return h_new, y_intra + y_state

    h0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32) if h0 is None else h0
    # checkpoint the chunk body: backward stores only the [B,H,P,N] chunk
    # boundary states, recomputing the [c,c] decay tensors per chunk.
    hT, ys = jax.lax.scan(
        jax.checkpoint(chunk_step), h0,
        tuple(jnp.moveaxis(t, 1, 0) for t in (xc, dtc, Bc, Cc, dA, seg)))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, L, H, Pd)[:, :S]
    return y, hT


def ssd_block(p: dict, x: Array, *, d_state: int = 64, headdim: int = 64,
              expand: int = 2, chunk: int = 128,
              state: Array | None = None, conv_state: Array | None = None,
              return_state: bool = False):
    """Full Mamba2 mixer. x: [B, S, D]. In decode mode pass ``state``
    ([B,H,P,N]) and ``conv_state`` ([B, d_conv-1, convdim]) and S may be 1."""
    Bsz, S, D = x.shape
    d_inner = expand * D
    H = d_inner // headdim
    N = d_state
    proj = x @ p["in_proj"]
    z, xr, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)   # [B,S,convdim]
    dconv = p["conv_w"].shape[0]
    if conv_state is not None:
        conv_in_full = jnp.concatenate([conv_state, conv_in], axis=1)
        new_conv_state = conv_in_full[:, -(dconv - 1):]
    else:
        conv_in_full = jnp.pad(conv_in, ((0, 0), (dconv - 1, 0), (0, 0)))
        new_conv_state = conv_in_full[:, -(dconv - 1):] if return_state else None
    # depthwise causal conv as dconv shifted multiply-accumulates — avoids
    # materializing a [B, S, dconv, convdim] window tensor.
    conv = jnp.zeros_like(conv_in)
    for j in range(dconv):
        conv = conv + conv_in_full[:, j:j + S] * p["conv_w"][j]
    conv = jax.nn.silu(conv + p["conv_b"])
    xr, Bm, Cm = jnp.split(conv, [d_inner, d_inner + N], axis=-1)

    A = -jnp.exp(p["A_log"])                            # [H]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xr.reshape(Bsz, S, H, headdim)
    y, hT = _ssd_chunked(xh.astype(jnp.float32), dt, A,
                         Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                         chunk=min(chunk, max(S, 1)), h0=state)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    from .layers import rmsnorm
    y = rmsnorm(y * jax.nn.silu(z), p["norm_g"])
    out = y @ p["out_proj"]
    if return_state or state is not None:
        return out, (hT, new_conv_state)
    return out
