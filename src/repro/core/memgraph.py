"""MEMGRAPH intermediate representation (paper §4).

A MEMGRAPH is a *dependency* graph (not a dataflow graph): an edge
``u -> v`` means only that ``v`` may not start until ``u`` has completed.
Two edge kinds exist:

* ``DATA`` — inherited from the TASKGRAPH (or created by offload/reload
  insertion): ``v`` consumes the bytes produced by ``u``;
* ``MEM`` — a memory dependency inserted so that a vertex safely overwrites
  the previous occupant of its assigned memory location (paper §4/§6).

Every vertex's output is bound at compile time to a :class:`Loc` — a
``(device, offset, size)`` extent in that device's arena — except OFFLOAD
vertices, whose output lives in the host store. There is no dynamic
allocation at runtime (paper §5): any execution order that respects the
dependencies reads and writes exactly the planned extents.

The class also carries validation helpers used heavily by the test suite:
acyclicity, safe-overwrite race-freedom (paper §7), and a slot-table
interpreter that executes the graph under an arbitrary topological order to
prove order-independence of the final outputs.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Iterable

__all__ = ["MemOp", "STORE_OPS", "DepKind", "Loc", "MemVertex", "MemGraph",
           "RaceError"]


class RaceError(AssertionError):
    """A race condition or cycle detected during MEMGRAPH validation."""


class MemOp(str, enum.Enum):
    INPUT = "input"        # load a graph input from the host store
    COMPUTE = "compute"
    TRANSFER = "transfer"  # device-to-device
    OFFLOAD = "offload"    # device -> host   (output in host store)
    RELOAD = "reload"      # host -> device
    SPILL = "spill"        # host -> disk  (second hop of a tiered eviction;
    #                        params={'drop': True} releases dead bytes for free)
    LOAD = "load"          # disk -> host  (first hop of a two-hop reload)
    ALLOC0 = "alloc0"      # zero-init of a streaming-reduce accumulator (§B)
    ADD_INTO = "add_into"  # commutative accumulation into a locked loc (§B)
    JOIN = "join"          # completion marker of a streaming-reduce group
    XFER = "xfer"          # host -> remote host over the NIC (inter-replica
    #                        KV migration; priced by the simulator's sixth
    #                        channel — the plan builder never emits it)


# ops whose output lives in a store tier, not a device extent (loc is None)
STORE_OPS = frozenset({MemOp.OFFLOAD, MemOp.SPILL, MemOp.LOAD})


@dataclasses.dataclass(frozen=True)
class Loc:
    """An extent in a device arena. ``size`` is in abstract units."""

    device: int
    offset: int
    size: int

    @property
    def key(self) -> tuple[int, int]:
        return (self.device, self.offset)

    def overlaps(self, other: "Loc") -> bool:
        return (self.device == other.device
                and self.offset < other.offset + other.size
                and other.offset < self.offset + self.size)


class DepKind(str, enum.Enum):
    DATA = "data"
    MEM = "mem"


@dataclasses.dataclass
class MemVertex:
    mid: int
    op: MemOp
    device: int                      # device whose engine executes the vertex
    src_tid: int | None = None       # originating TASKGRAPH vertex, if any
    loc: Loc | None = None           # output extent (None for OFFLOAD)
    seq: int = -1                    # simulation execution order (fixed-exec order)
    op_name: str = ""                # runtime op-registry name
    params: dict[str, Any] = dataclasses.field(default_factory=dict)
    flops: float = 0.0
    size: int = 0                    # output size in units (host size for OFFLOAD)
    nbytes: int = 0                  # output size in bytes (for the simulator)
    name: str = ""
    # storage tier an OFFLOAD/RELOAD ultimately talks to: "host" (one hop)
    # or "disk" (this vertex is one leg of a two-hop spill/reload chain).
    # SPILL/LOAD vertices are always tier "disk".
    tier: str = "host"
    # True on a LOAD hoisted ahead of its consumer's horizon by the
    # compiler's PrefetchPlan (the reload pipeline starts before the
    # consumer needs the bytes); False on reactive force-reload LOADs.
    prefetch: bool = False
    lock_group: tuple[int, int] | None = None  # ADD_INTO write-lock key (§B)
    # ordered operand list (mids; duplicates allowed) — dependency *sets* lose
    # operand order, which the runtime needs to bind kernel arguments.
    operands: list[int] = dataclasses.field(default_factory=list)


class MemGraph:
    """Dependency graph with typed edges plus validation/execution helpers."""

    def __init__(self) -> None:
        self.vertices: dict[int, MemVertex] = {}
        self.preds: dict[int, dict[int, DepKind]] = {}
        self.succs: dict[int, dict[int, DepKind]] = {}
        self.superfluous_mem_deps = 0  # mem deps skipped: data dep already there
        self._next_mid = 0
        # memoized transitive order (descendant bitsets); any structural
        # mutation must call _invalidate_reach() or later happens_before()
        # answers describe a graph that no longer exists.
        self._reach: tuple[dict[int, int], dict[int, int]] | None = None

    # -- construction -----------------------------------------------------
    def add_vertex(self, op: MemOp, device: int, **kw: Any) -> int:
        mid = self._next_mid
        self._next_mid += 1
        self.vertices[mid] = MemVertex(mid, op, device, **kw)
        self.preds[mid] = {}
        self.succs[mid] = {}
        self._invalidate_reach()
        return mid

    def remove_vertex(self, mid: int) -> None:
        """Retract a vertex. Unwired vertices (the builder's
        abandoned-prefetch path) simply vanish; wired vertices — plan
        surgery, hazard injection in tests — are detached from *both* edge
        maps so no dangling pred/succ entry survives. Transitive ordering
        implied by the removed vertex is deliberately NOT re-bridged: the
        caller asked for the vertex (and its ordering constraints) to go."""
        for p in self.preds.pop(mid):
            del self.succs[p][mid]
        for s in self.succs.pop(mid):
            del self.preds[s][mid]
        del self.vertices[mid]
        self._invalidate_reach()

    def add_dep(self, u: int, v: int, kind: DepKind) -> None:
        """Add ``u -> v``. A MEM dep duplicating an existing DATA dep is
        superfluous (paper Fig. 5 dashed edge) and is counted, not stored."""
        if u == v:
            return
        existing = self.preds[v].get(u)
        if existing is not None:
            if kind == DepKind.MEM:
                self.superfluous_mem_deps += 1
            elif existing == DepKind.MEM:
                # upgrade MEM -> DATA (data implies the ordering)
                self.preds[v][u] = DepKind.DATA
                self.succs[u][v] = DepKind.DATA
            return
        self.preds[v][u] = kind
        self.succs[u][v] = kind
        self._invalidate_reach()

    def remove_dep(self, u: int, v: int) -> None:
        """Remove the edge ``u -> v`` (hazard injection / plan surgery)."""
        del self.preds[v][u]
        del self.succs[u][v]
        self._invalidate_reach()

    # -- queries -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.vertices)

    def data_preds(self, v: int) -> list[int]:
        return [u for u, k in self.preds[v].items() if k == DepKind.DATA]

    def data_succs(self, v: int) -> list[int]:
        return [u for u, k in self.succs[v].items() if k == DepKind.DATA]

    def n_edges(self) -> tuple[int, int]:
        data = sum(1 for v in self.preds for k in self.preds[v].values()
                   if k == DepKind.DATA)
        mem = sum(1 for v in self.preds for k in self.preds[v].values()
                  if k == DepKind.MEM)
        return data, mem

    def topo_order(self, key: Callable[[int], Any] | None = None) -> list[int]:
        """Topological order; ``key`` breaks ties (e.g. ``seq`` for the
        fixed-execution ablation, or a PRNG for property tests)."""
        import heapq

        indeg = {m: len(self.preds[m]) for m in self.vertices}
        keyf = key or (lambda m: m)
        heap = [(keyf(m), m) for m, d in indeg.items() if d == 0]
        heapq.heapify(heap)
        order: list[int] = []
        while heap:
            _, m = heapq.heappop(heap)
            order.append(m)
            for s in self.succs[m]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(heap, (keyf(s), s))
        if len(order) != len(self.vertices):
            raise RaceError("MEMGRAPH contains a cycle")
        return order

    # -- validation (paper §7) ----------------------------------------------
    def validate(self, check_races: bool = True,
                 host_capacity: int | None = None,
                 disk_capacity: int | None = None) -> None:
        """Structural validation; ``host_capacity``/``disk_capacity``
        additionally replay the compile-time schedule and check the
        host-tier / disk-tier budgets (units)."""
        self.topo_order()
        for m, v in self.vertices.items():
            if v.op in STORE_OPS:
                if v.loc is not None:
                    raise RaceError(f"{v.op.value} {m} has a device loc")
            elif v.loc is None:
                raise RaceError(f"{v.op} vertex {m} has no loc")
        if host_capacity is not None or disk_capacity is not None:
            prof = self.host_tier_profile()
            if (host_capacity is not None
                    and prof["peak_units"] > host_capacity):
                raise RaceError(
                    f"host-tier budget exceeded: peak {prof['peak_units']} "
                    f"units > capacity {host_capacity}")
            if (disk_capacity is not None
                    and prof["peak_disk_units"] > disk_capacity):
                raise RaceError(
                    f"disk-tier budget exceeded: peak "
                    f"{prof['peak_disk_units']} units > capacity "
                    f"{disk_capacity}")
        if check_races:
            self._check_safe_overwrites()

    def host_tier_profile(self) -> dict[str, int]:
        """Replay the compile-time (seq) schedule, tracking host-tier
        occupancy in units: OFFLOAD and LOAD admit bytes into the host
        arena, SPILL (including drops) releases them. Disk occupancy is
        replayed per host key (``operands[0]``): the first real SPILL of a
        key creates its immutable blob, a drop releases it; LOADs leave the
        blob valid. Conservative w.r.t. runtime orders: every SPILL is
        ordered (by construction in ``build.py``) after the host copy's
        readers and before the tenant that reuses its space, and every
        drop after the blob's readers — per-key create/free is totally
        ordered, so any legal order peaks no higher than this replay."""
        occ = peak = 0
        disk_occ = disk_peak = 0
        on_disk: dict[Any, int] = {}      # host key -> blob units
        spilled = loaded = dropped = prefetched = 0
        for m in sorted(self.vertices, key=lambda m: self.vertices[m].seq):
            v = self.vertices[m]
            if v.op == MemOp.OFFLOAD:
                occ += v.size
            elif v.op == MemOp.LOAD:
                occ += v.size
                loaded += 1
                if v.prefetch:
                    prefetched += 1
            elif v.op == MemOp.SPILL:
                occ -= v.size
                key = v.operands[0] if v.operands else m
                if v.params.get("drop"):
                    dropped += 1
                    disk_occ -= on_disk.pop(key, 0)
                else:
                    spilled += 1
                    if key not in on_disk:
                        on_disk[key] = v.size
                        disk_occ += v.size
            peak = max(peak, occ)
            disk_peak = max(disk_peak, disk_occ)
        return {"peak_units": peak, "final_units": occ,
                "peak_disk_units": disk_peak, "final_disk_units": disk_occ,
                "n_spills": spilled, "n_loads": loaded, "n_drops": dropped,
                "n_prefetches": prefetched}

    # -- transitive order (the certifier's substrate, DESIGN.md §13) --------
    def _invalidate_reach(self) -> None:
        self._reach = None

    def reachability(self) -> tuple[dict[int, int], dict[int, int]]:
        """``(bitpos, desc)``: ``desc[m]`` is an int bitmask with bit
        ``bitpos[x]`` set iff there is a (non-empty) path ``m -> x``.
        Computed once per graph shape in one reverse-topological sweep over
        big-int bitsets and memoized; mutation invalidates the memo."""
        if self._reach is None:
            order = self.topo_order()
            bitpos = {m: i for i, m in enumerate(order)}
            desc: dict[int, int] = {}
            for m in reversed(order):
                bits = 0
                for s in self.succs[m]:
                    bits |= (1 << bitpos[s]) | desc[s]
                desc[m] = bits
            self._reach = (bitpos, desc)
        return self._reach

    def happens_before(self, u: int, v: int) -> bool:
        """True iff ``u`` precedes ``v`` in *every* legal execution order
        (there is a dependency path ``u -> v``). Irreflexive."""
        bitpos, desc = self.reachability()
        return bool(desc[u] >> bitpos[v] & 1)

    def _ancestors(self, dst: int, cache: dict) -> set[int]:
        """The ancestor set of ``dst`` (all vertices with a path to it),
        memoized in ``cache``."""
        anc = cache.get(dst)
        if anc is None:
            anc = set()
            stack = [dst]
            while stack:
                x = stack.pop()
                for p in self.preds[x]:
                    if p not in anc:
                        anc.add(p)
                        stack.append(p)
            cache[dst] = anc
        return anc

    def _reachable(self, srcs: set[int], dst: int, cache: dict) -> bool:
        """Is there a path from any of ``srcs`` to ``dst``? (ancestors of dst)"""
        return bool(srcs & self._ancestors(dst, cache))

    def _check_safe_overwrites(self) -> None:
        """For every pair of vertices whose outputs overlap in memory, one
        must safely overwrite the other: each reader of the earlier writer
        must be an ancestor of the later writer (paper §4). ADD_INTO vertices
        of one lock group commute and are exempt w.r.t. each other.
        O(writers² per extent) — intended for test-sized graphs."""
        order = self.topo_order()
        pos = {m: i for i, m in enumerate(order)}
        cache: dict[int, set[int]] = {}
        by_dev: dict[int, list[int]] = {}
        for m, v in self.vertices.items():
            if v.loc is not None:
                by_dev.setdefault(v.loc.device, []).append(m)
        for dev, ms in by_dev.items():
            ms.sort(key=lambda m: pos[m])
            for i, m1 in enumerate(ms):
                v1 = self.vertices[m1]
                for m2 in ms[i + 1:]:
                    v2 = self.vertices[m2]
                    if not v1.loc.overlaps(v2.loc):
                        continue
                    if (v1.lock_group is not None
                            and v1.lock_group == v2.lock_group):
                        continue  # commutative accumulation (§B)
                    # v2 is the later writer: every reader of v1 (and v1
                    # itself) must be an ancestor of v2.
                    readers = set(self.data_succs(m1)) | {m1}
                    anc = self._ancestors(m2, cache)
                    bad = {r for r in readers if r != m2 and r not in anc
                           and pos[r] < pos[m2]}
                    # A reader *after* v2 in topo pos but not ordered w.r.t.
                    # it is also a race.
                    bad |= {r for r in readers if r != m2 and r not in anc
                            and pos[r] >= pos[m2]
                            and not self._reachable({m2}, r, cache)}
                    if bad:
                        raise RaceError(
                            f"race on dev{dev} {v1.loc}: writer {m2} does not "
                            f"safely overwrite {m1}; unordered readers {bad}")

    # -- stats ---------------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        kinds: dict[str, int] = {}
        off_bytes = rel_bytes = spill_bytes = load_bytes = 0
        n_prefetch = prefetch_bytes = 0
        for v in self.vertices.values():
            kinds[v.op.value] = kinds.get(v.op.value, 0) + 1
            if v.op == MemOp.OFFLOAD:
                off_bytes += v.nbytes
            elif v.op == MemOp.RELOAD:
                rel_bytes += v.nbytes
            elif v.op == MemOp.SPILL:
                spill_bytes += v.nbytes
            elif v.op == MemOp.LOAD:
                load_bytes += v.nbytes
                if v.prefetch:
                    n_prefetch += 1
                    prefetch_bytes += v.nbytes
        data, mem = self.n_edges()
        return {
            "n_vertices": len(self),
            "by_op": kinds,
            "data_deps": data,
            "mem_deps": mem,
            "superfluous_mem_deps": self.superfluous_mem_deps,
            "offload_bytes": off_bytes,
            "reload_bytes": rel_bytes,
            "disk_spill_bytes": spill_bytes,
            "disk_load_bytes": load_bytes,
            "n_prefetch_loads": n_prefetch,
            "prefetch_bytes": prefetch_bytes,
        }
