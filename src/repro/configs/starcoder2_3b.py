"""starcoder2-3b [dense]: GQA(kv=2) + RoPE, layernorm + gelu MLP.
[arXiv:2402.19173; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    norm="layernorm", mlp="gelu", qkv_bias=True, rope_theta=1e5,
    source="arXiv:2402.19173; hf",
)
