"""Fleet-scale serving: a router over N engine replicas (DESIGN.md §16).

The :class:`Router` owns a shared admission queue in front of
``topology.n_replicas`` independent :class:`~repro.serve.Engine` replicas,
each with its own host/disk tier population (and, when
``topology.host_bytes_per_replica`` is set, its own arbitrated
:class:`~repro.core.pool.HostPool`). Three mechanisms make it a fleet and
not just N engines:

* **Placement** — every admission picks a replica through a pluggable
  policy (:data:`PLACEMENT_POLICY_NAMES`) reading the live
  :meth:`Engine.load` signals. Request ids are allocated *globally* by the
  router and pinned with ``submit(rid=)``: the sampling key schedule folds
  only ``(seed, rid, position)``, so a request's tokens are identical
  wherever it lands — placement, like dispatch order inside one replica,
  changes timing and never bytes (the TURNIP property, lifted one level).

* **Migration** — swapped requests move between replicas as
  :class:`~repro.serve.MigrationTicket` payloads serialized through
  :func:`encode_ticket` / :func:`decode_ticket` — the same framed-record
  format as the disk tier's ``spill.log`` (magic + length header per
  payload), shipped over a dedicated inter-replica transfer stream
  (:class:`_NicStream`) whose wire time is priced with the same constants
  as the simulator's sixth channel (``HardwareModel.nic_bw``), so
  :func:`~repro.core.simulate.migration_crossover` predicts when shipping
  KV beats re-prefilling it. Import is **all-or-nothing**
  (:meth:`Engine.import_migration`): a refused ticket leaves no byte,
  charge, or record on the destination and falls back to cold re-prefill
  of ``prompt + out`` — token-exact either way.

* **Drain** — each replica's run loop beats a
  :class:`~repro.ft.supervisor.Heartbeat`; a replica that crashes
  (:class:`~repro.serve.ReplicaKilled`) or goes silent (missed heartbeats
  — the pause/wedge failure mode) is drained: taken out of placement,
  hard-killed, its worker joined (so its DMA streams are joined and no
  thread leaks), every in-flight request checkpointed at its last emitted
  token (:meth:`Engine.drain_tickets` — host/disk tiers are owned by the
  host process and survive the dead worker, so SWAPPED requests ship
  *warm*), shipped to survivors, and resumed token-exact.

Lock order (audited by the suite-wide sanitizer): Router → ServeEngine;
Heartbeat and NicStream are leaves; no path ever holds two ServeEngine
locks at once.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import random
import threading
import time

import numpy as np

from ..core import lockcheck
from ..core.pool import HostPool
from ..core.stores import DiskStore
from ..ft.supervisor import Heartbeat
from ..launch.mesh import FleetTopology
from .engine import (DONE, Engine, MigrationRefused, MigrationTicket,
                     ReplicaKilled, ServeConfig)

__all__ = ["Router", "RouterStats", "PLACEMENT_POLICY_NAMES",
           "PlacementPolicy", "get_placement",
           "encode_ticket", "decode_ticket"]


# --------------------------------------------------------------------------
# wire codec — spill.log's framed-record format, reused verbatim
# --------------------------------------------------------------------------
# One ticket on the wire is a sequence of records, each framed exactly like
# a DiskStore spill.log record (magic + payload length, then raw bytes):
# first a JSON header (identity, progress, per-block leaf specs), then — for
# a warm ticket — one record per (block, leaf) payload in sorted leaf order.
# Reusing the frame means the same torn-record/bad-magic checks guard both
# the disk tier and the inter-replica link, and a migration blob is exactly
# what the disk tier would have logged for the same blocks.
_MAGIC = DiskStore._MAGIC
_HDR = DiskStore._HDR


def _frame(payload: bytes) -> bytes:
    return _HDR.pack(_MAGIC, len(payload)) + payload


def _unframe(data: bytes, off: int) -> tuple[bytes, int]:
    hdr = data[off:off + _HDR.size]
    if len(hdr) != _HDR.size:
        raise ValueError("torn migration record header")
    magic, n = _HDR.unpack(hdr)
    if magic != _MAGIC:
        raise ValueError(f"bad migration record magic {magic!r}")
    off += _HDR.size
    payload = data[off:off + n]
    if len(payload) != n:
        raise ValueError(f"torn migration record payload: "
                         f"{len(payload)}/{n} bytes")
    return payload, off + n


def encode_ticket(t: MigrationTicket) -> bytes:
    """Serialize a ticket to one self-describing blob, bit-exact."""
    blocks = t.blocks if t.blocks is not None else []
    arrs = [[(k, np.ascontiguousarray(np.asarray(b[k]))) for k in sorted(b)]
            for b in blocks]
    head = {
        "rid": t.rid, "prompt": list(map(int, t.prompt)),
        "out": list(map(int, t.out)), "max_new": t.max_new,
        "pos": t.pos, "last": t.last, "block_size": t.block_size,
        "t_submit": t.t_submit, "t_first": t.t_first,
        "warm": t.blocks is not None,
        "blocks": [[[k, list(a.shape), str(a.dtype)] for k, a in blk]
                   for blk in arrs],
    }
    parts = [_frame(json.dumps(head).encode())]
    for blk in arrs:
        for _, a in blk:
            parts.append(_frame(a.tobytes()))
    return b"".join(parts)


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including the ml_dtypes families (bfloat16,
    float8_*) jax caches use but plain numpy cannot look up by name."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def decode_ticket(data: bytes) -> MigrationTicket:
    """Inverse of :func:`encode_ticket`; validates every frame and refuses
    trailing bytes, so a truncated or corrupted ship fails loudly instead
    of landing garbage KV."""
    head_b, off = _unframe(data, 0)
    head = json.loads(head_b.decode())
    blocks = None
    if head["warm"]:
        blocks = []
        for specs in head["blocks"]:
            blk = {}
            for name, shape, dtype in specs:
                payload, off = _unframe(data, off)
                arr = np.frombuffer(payload, dtype=_np_dtype(dtype))
                blk[name] = arr.reshape(tuple(shape))
            blocks.append(blk)
    if off != len(data):
        raise ValueError(f"{len(data) - off} trailing bytes after ticket")
    return MigrationTicket(
        rid=head["rid"], prompt=list(head["prompt"]), out=list(head["out"]),
        max_new=head["max_new"], pos=head["pos"], last=head["last"],
        block_size=head["block_size"], t_submit=head["t_submit"],
        t_first=head["t_first"], blocks=blocks)


# --------------------------------------------------------------------------
# placement policies
# --------------------------------------------------------------------------
PLACEMENT_POLICY_NAMES = ("least-loaded", "join-shortest-kv", "random")


class PlacementPolicy:
    """Pick a replica for an admission (or a migration target) from the
    alive set. Policies read :meth:`Engine.load` — they change *where* a
    request runs, never *what* it emits (the rid rides with it)."""

    name = "base"

    def pick(self, replicas: "list[_Replica]") -> "_Replica":
        raise NotImplementedError


class LeastLoadedPlacement(PlacementPolicy):
    """Fewest live requests wins; ties break on replica index."""

    name = "least-loaded"

    def pick(self, replicas):
        return min(replicas, key=lambda r: (r.engine.load()[0], r.index))


class JoinShortestKVPlacement(PlacementPolicy):
    """Fewest resident+committed KV tokens wins — the memory-pressure
    analogue of join-shortest-queue; ties break on replica index."""

    name = "join-shortest-kv"

    def pick(self, replicas):
        return min(replicas, key=lambda r: (r.engine.load()[1], r.index))


class RandomPlacement(PlacementPolicy):
    """Seeded uniform choice — the chaos harness's adversarial baseline."""

    name = "random"

    def __init__(self, seed: int = 0) -> None:
        self._rng = random.Random(seed)

    def pick(self, replicas):
        return self._rng.choice(replicas)


def get_placement(policy: str | PlacementPolicy | None, *,
                  seed: int = 0) -> PlacementPolicy:
    if isinstance(policy, PlacementPolicy):
        return policy
    if policy is None or policy == "least-loaded":
        return LeastLoadedPlacement()
    if policy == "join-shortest-kv":
        return JoinShortestKVPlacement()
    if policy == "random":
        return RandomPlacement(seed)
    raise ValueError(f"unknown placement policy {policy!r} "
                     f"(have {PLACEMENT_POLICY_NAMES})")


# --------------------------------------------------------------------------
# the inter-replica transfer stream
# --------------------------------------------------------------------------
class _NicStream(threading.Thread):
    """The fleet's sixth engine class at runtime: one dedicated thread
    serving framed ticket blobs FIFO, sleeping the simulated wire time
    (``latency + nbytes / bw`` — the same cost model as the simulator's
    NIC channel) before invoking the delivery callback. Deliveries run on
    this thread with no router lock held, so an import that takes the
    destination's engine lock can never deadlock against the router."""

    def __init__(self, bw: float, latency: float) -> None:
        super().__init__(name="nic", daemon=True)
        self.bw = bw
        self.latency = latency
        self._cond = threading.Condition(lockcheck.make_lock("NicStream"))
        self._queue: collections.deque = collections.deque()
        self._shutdown = False
        self.shipped_bytes = 0
        self.transfers = 0

    def send(self, data: bytes, deliver) -> tuple[threading.Event, dict]:
        """Enqueue one blob; returns ``(done, box)`` — ``done`` is set
        after delivery, ``box['error']`` carries a delivery exception."""
        done = threading.Event()
        box: dict = {}
        with self._cond:
            if self._shutdown:
                raise RuntimeError("nic stream is shut down")
            self._queue.append((data, deliver, done, box))
            self._cond.notify_all()
        return done, box

    def shutdown(self) -> None:
        with self._cond:
            self._shutdown = True
            self._cond.notify_all()

    def run(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._shutdown:
                    self._cond.wait()
                if not self._queue:
                    return
                data, deliver, done, box = self._queue.popleft()
            time.sleep(self.latency + len(data) / self.bw)
            try:
                deliver(data)
            except BaseException as e:   # noqa: BLE001 — surfaced via box
                box["error"] = e
            finally:
                with self._cond:
                    self.shipped_bytes += len(data)
                    self.transfers += 1
                done.set()


# --------------------------------------------------------------------------
# the router
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Replica:
    index: int
    name: str
    engine: Engine
    pool: HostPool | None
    thread: threading.Thread | None = None
    alive: bool = True
    fault: BaseException | None = None
    closed: bool = False


@dataclasses.dataclass
class RouterStats:
    submitted: int = 0
    completed: int = 0
    migrations: int = 0          # warm tickets delivered (drain + rebalance)
    migrated_bytes: int = 0      # wire bytes of delivered warm tickets
    reprefills: int = 0          # cold fallbacks (device state lost)
    replicas_killed: int = 0
    drain_time: float = 0.0      # wall seconds spent draining dead replicas
    ttft_p99: dict[str, float] = dataclasses.field(default_factory=dict)


class Router:
    """N serving replicas behind one admission queue. See module docstring
    for the design; the operational surface is::

        with Router(model, params, cfg, topology=topo) as router:
            rids = [router.submit(p, max_new=32) for p in prompts]
            router.wait(rids)
            outs = [router.result(r) for r in rids]

    Replica worker threads start at construction and idle cheaply between
    bursts; :meth:`close` (or the context exit) joins every thread the
    router ever started."""

    def __init__(self, model, params, cfg: ServeConfig = ServeConfig(), *,
                 topology: FleetTopology | None = None,
                 placement: str | PlacementPolicy = "least-loaded",
                 seed: int | None = None) -> None:
        self.topology = topology if topology is not None else FleetTopology()
        self.cfg = cfg
        if seed is None:
            seed = cfg.seed
        self.placement = get_placement(placement, seed=seed)
        self._lock = lockcheck.make_lock("Router")
        self._cond = threading.Condition(self._lock)
        self.heartbeat = Heartbeat(
            timeout_s=self.topology.heartbeat_timeout_s)
        self.nic = _NicStream(self.topology.nic_bw, self.topology.nic_latency)
        self.stats = RouterStats()
        self._records: dict[int, dict] = {}
        self._admit: collections.deque = collections.deque()
        self._next_rid = 0
        self._stop = threading.Event()
        self._error: BaseException | None = None
        self.replicas: list[_Replica] = []
        for i, name in enumerate(self.topology.replica_names):
            pool = (HostPool(self.topology.host_bytes_per_replica)
                    if self.topology.host_bytes_per_replica else None)
            eng = Engine(model, params, cfg, pool=pool, name=name)
            # each run-loop iteration beats the replica's heartbeat OFF the
            # engine lock; a wedged/paused loop stops beating and the
            # monitor drains it
            eng.on_step = (lambda _eng, _name=name:
                           self.heartbeat.beat(_name))
            self.replicas.append(_Replica(i, name, eng, pool))
        self.nic.start()
        for rep in self.replicas:
            rep.thread = threading.Thread(
                target=self._worker, args=(rep,),
                name=f"router-{rep.name}", daemon=True)
            rep.thread.start()
        self._monitor = threading.Thread(
            target=self._monitor_loop, name="router-monitor", daemon=True)
        self._monitor.start()

    # --------------------------------------------------------- admission
    def submit(self, prompt, max_new: int = 32) -> int:
        """Enqueue a request on the shared admission queue; returns its
        globally unique rid (pinned on whichever replica serves it)."""
        prompt = [int(t) for t in prompt]
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._records[rid] = {
                "prompt": prompt, "max_new": max_new, "prefix": [],
                "replica": None, "done": False,
                "t_submit": time.monotonic(), "t_first": 0.0}
            self._admit.append(rid)
            self.stats.submitted += 1
            self._dispatch_locked()
        return rid

    def _dispatch_locked(self) -> None:
        """Drain the admission queue onto alive replicas (placement-picked).
        With every replica down the queue holds until the monitor notices a
        recovery — requests are never dropped on the floor."""
        while self._admit:
            alive = [r for r in self.replicas if r.alive]
            if not alive:
                return
            rid = self._admit.popleft()
            rec = self._records[rid]
            rep = self.placement.pick(alive)
            rep.engine.submit(rec["prompt"], rec["max_new"], rid=rid)
            rec["replica"] = rep

    # --------------------------------------------------------- results
    def result(self, rid: int) -> list[int]:
        """Tokens emitted so far: the router-held prefix (tokens emitted
        before a cold migration) plus the hosting replica's live tail.
        Complete once :meth:`done` reports True."""
        with self._lock:
            rec = self._records[rid]
            prefix = list(rec["prefix"])
            rep = rec["replica"]
        if rep is None:
            return prefix
        with rep.engine._lock:
            req = rep.engine.reqs.get(rid)
            tail = list(req.out) if req is not None else []
        return prefix + tail

    def done(self, rid: int) -> bool:
        with self._lock:
            rec = self._records[rid]
            if rec["done"]:
                return True
            rep = rec["replica"]
        if rep is None:
            return False
        with rep.engine._lock:
            req = rep.engine.reqs.get(rid)
            finished = req is not None and req.state == DONE
        if finished:
            with self._lock:
                if not rec["done"]:
                    rec["done"] = True
                    self.stats.completed += 1
        return finished

    def wait(self, rids: "list[int] | None" = None,
             timeout: float | None = None) -> None:
        """Block until every request in ``rids`` (default: all submitted)
        completes. Re-raises any router-level fault (a non-kill replica
        crash, a failed drain) rather than hanging on it."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._lock:
                if self._error is not None:
                    raise self._error
                pending = list(self._records if rids is None else rids)
            if all(self.done(r) for r in pending):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"requests still pending after {timeout}s: "
                    f"{[r for r in pending if not self.done(r)]}")
            time.sleep(0.005)

    # --------------------------------------------------------- fleet loops
    def _worker(self, rep: _Replica) -> None:
        """One replica's driver: run the engine whenever it has live work,
        beat the heartbeat while idle. Exits on router stop or replica
        death; a :class:`ReplicaKilled` raised by the engine marks the
        replica faulted for the monitor to drain."""
        eng = rep.engine
        try:
            while not self._stop.is_set():
                with eng._lock:
                    busy = bool(eng._live)
                    killed = eng._killed
                if killed:
                    # a kill can land while the replica is idle (between
                    # requests); run() would never observe it, so exit
                    # here or the drain's join blocks until router close
                    return
                if not busy:
                    self.heartbeat.beat(rep.name)
                    time.sleep(0.005)
                    continue
                eng.run()
        except ReplicaKilled as e:
            if not self._stop.is_set():
                with self._cond:
                    rep.fault = e
                    self._cond.notify_all()
        except BaseException as e:   # noqa: BLE001 — surfaced via wait()
            with self._cond:
                rep.fault = e
                if not isinstance(e, ReplicaKilled):
                    self._error = e
                self._cond.notify_all()

    def _monitor_loop(self) -> None:
        """Supervision: drain replicas that crashed (worker fault) or went
        silent (missed heartbeats), and keep the admission queue moving."""
        while not self._stop.is_set():
            with self._cond:
                self._cond.wait(timeout=0.02)
                if self._stop.is_set():
                    return
                dead = set(self.heartbeat.dead_workers())
                faulted = [r for r in self.replicas if r.alive
                           and (r.fault is not None or r.name in dead)]
            for rep in faulted:
                try:
                    self._drain_replica(rep)
                except BaseException as e:   # noqa: BLE001
                    with self._lock:
                        self._error = e
                    return
            with self._lock:
                self._dispatch_locked()

    def _drain_replica(self, rep: _Replica) -> None:
        """The fault-tolerance path, in the one order that guarantees no
        double execution and no leaked threads: remove from placement →
        hard-kill (idempotent for an already-crashed loop) → resume (a
        paused loop must wake to observe the kill) → join the worker (its
        ``run()`` finally joins every DMA stream) → forget the heartbeat →
        checkpoint every live request → ship each over the NIC (warm
        import, cold re-prefill fallback) → retire the replica's store."""
        t0 = time.monotonic()
        with self._lock:
            if not rep.alive:
                return
            rep.alive = False
        rep.engine.hard_kill()
        rep.engine.resume()
        if rep.thread is not None:
            rep.thread.join()
        self.heartbeat.forget(rep.name)
        tickets = rep.engine.drain_tickets()
        for ticket in tickets:
            self._ship(ticket)
        rep.engine.close()
        rep.closed = True
        with self._lock:
            self.stats.replicas_killed += 1
            self.stats.drain_time += time.monotonic() - t0

    def _ship(self, ticket: MigrationTicket) -> None:
        """Serialize one ticket, pick a surviving target, push it through
        the transfer stream, and wait for delivery."""
        data = encode_ticket(ticket)
        with self._lock:
            alive = [r for r in self.replicas if r.alive]
        if not alive:
            raise RuntimeError(
                f"request {ticket.rid}: no surviving replica to drain to")
        target = self.placement.pick(alive)
        done, box = self.nic.send(
            data, lambda blob, _t=target: self._deliver(blob, _t))
        done.wait()
        if "error" in box:
            raise box["error"]

    def _deliver(self, data: bytes, target: _Replica) -> None:
        """NIC-thread delivery: decode, try the warm all-or-nothing import,
        fall back to cold re-prefill. Router state is updated *after* the
        engine call, never while holding both locks."""
        ticket = decode_ticket(data)
        if ticket.warm:
            try:
                target.engine.import_migration(ticket)
                with self._lock:
                    rec = self._records.get(ticket.rid)
                    if rec is not None:
                        rec["replica"] = target
                        if ticket.t_first and not rec["t_first"]:
                            rec["t_first"] = ticket.t_first
                    self.stats.migrations += 1
                    self.stats.migrated_bytes += len(data)
                return
            except MigrationRefused:
                pass   # destination kept its invariants; go cold
        self._cold_resume(ticket, target)

    def _cold_resume(self, ticket: MigrationTicket,
                     target: _Replica) -> None:
        """Re-prefill ``prompt + out`` on the target. Token-exact: the next
        sample folds (seed, rid, len(prompt + out)) — exactly the key the
        original continuation would have used — and the emitted tokens so
        far move into the router-held prefix so ``result()`` never loses or
        double-counts them."""
        remaining = ticket.max_new - len(ticket.out)
        with self._lock:
            rec = self._records.get(ticket.rid)
            if rec is not None:
                rec["prefix"].extend(ticket.out)
                rec["replica"] = target
                if ticket.t_first and not rec["t_first"]:
                    rec["t_first"] = ticket.t_first
                if remaining < 1:
                    rec["done"] = True
                    self.stats.completed += 1
                    return
            elif remaining < 1:
                return
            self.stats.reprefills += 1
        target.engine.submit(ticket.prompt + ticket.out, remaining,
                             rid=ticket.rid)

    # --------------------------------------------------------- rebalance
    def rebalance_once(self) -> bool:
        """Live migration (no fault): detach the most-loaded alive
        replica's longest-waiting swapped request and ship it to a
        placement-picked peer. Returns True if a ticket moved."""
        with self._lock:
            alive = [r for r in self.replicas if r.alive]
        if len(alive) < 2:
            return False
        src = max(alive, key=lambda r: (r.engine.load()[0], -r.index))
        ticket = src.engine.export_one_swapped()
        if ticket is None:
            return False
        data = encode_ticket(ticket)
        peers = [r for r in alive if r is not src]
        target = self.placement.pick(peers)
        done, box = self.nic.send(
            data, lambda blob, _t=target: self._deliver(blob, _t))
        done.wait()
        if "error" in box:
            raise box["error"]
        return True

    # --------------------------------------------------------- kill seams
    def kill_replica(self, name: str) -> None:
        """Chaos seam: hard-kill one replica by name (the monitor drains
        it). No-op if it is already dead."""
        for rep in self.replicas:
            if rep.name == name:
                rep.engine.hard_kill()
                return
        raise KeyError(f"no replica named {name!r}")

    # --------------------------------------------------------- accounting
    def ttft_samples(self) -> dict[str, list[float]]:
        """Per-replica time-to-first-token samples (seconds), attributed to
        the replica that finally hosts each request."""
        out: dict[str, list[float]] = {}
        with self._lock:
            recs = [(rid, dict(rec)) for rid, rec in self._records.items()]
        for rid, rec in recs:
            rep = rec["replica"]
            if rep is None:
                continue
            t_first = rec["t_first"]
            if not t_first:
                with rep.engine._lock:
                    req = rep.engine.reqs.get(rid)
                    t_first = req.t_first if req is not None else 0.0
            if t_first:
                out.setdefault(rep.name, []).append(
                    t_first - rec["t_submit"])
        return out

    def summary(self) -> dict:
        """Router-level counters + per-replica p99 TTFT + NIC totals —
        the shape BENCH_9 records."""
        for rid in list(self._records):
            self.done(rid)           # fold any just-finished completions in
        p99 = {name: float(np.percentile(v, 99))
               for name, v in self.ttft_samples().items()}
        with self._lock:
            self.stats.ttft_p99 = p99
            d = dataclasses.asdict(self.stats)
        with self.nic._cond:
            d["nic"] = {"transfers": self.nic.transfers,
                        "shipped_bytes": self.nic.shipped_bytes}
        d["replicas"] = {rep.name: {"alive": rep.alive,
                                    "stats": dataclasses.asdict(
                                        rep.engine.stats)}
                         for rep in self.replicas}
        return d

    # --------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Tear the fleet down: stop the monitor, kill and join every
        worker (a killed run loop joins its DMA streams on the way out),
        drain the NIC, retire every engine store. Idempotent."""
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if self._monitor.is_alive():
            self._monitor.join()
        for rep in self.replicas:
            rep.engine.hard_kill()
            rep.engine.resume()
            if rep.thread is not None and rep.thread.is_alive():
                rep.thread.join()
        self.nic.shutdown()
        if self.nic.is_alive():
            self.nic.join()
        for rep in self.replicas:
            if not rep.closed:
                rep.engine.close()
                rep.closed = True

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
