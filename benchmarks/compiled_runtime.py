"""Compiled-plan executor benchmark (DESIGN.md §15; ISSUE 8 acceptance).

Measures *per-vertex dispatch overhead* of the two executor backends on a
≥500-vertex tiered-offload plan: the interpreted backend pays a lock
round-trip, a heap pop, and a condition-variable wakeup per vertex, the
compiled backend runs certified-static regions straight-line (position
check only) and hands off to the interpreter at nondet seams. Latency
injection is off, so wall-clock *is* dispatch + op cost and the ratio
isolates the scheduling machinery the compiler removed.

Also rides along:

* byte-exactness of the compiled backend against the dataflow oracle
  under all four dispatch policies (the acceptance gate — the full sweep
  lives in ``tests/test_differential.py``);
* a mixed-plan seam gate (DESIGN.md §17): a plan with real nondet
  windows, whose small seams the compiler stamps onto the thread-free
  inline executor — its per-vertex cost must stay within
  ``SEAM_TARGET_RATIO`` of the all-static plan's (seams priced at heap
  pops, not OS wakeups);
* a fused-DMA ablation through the discrete-event simulator: the same
  plan priced with and without ``CompiledPlan.fused_map`` (non-head batch
  members skip the fixed submission latency).

The ≥2x dispatch-overhead ratio and the ≤1.3x seam-overhead ratio are
asserted: this file failing in the bench-smoke lane *is* the perf
regression signal.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import BuildConfig, MemgraphOOM, TaskGraph, build_memgraph
from repro.core.compile import lower
from repro.core.dispatch import POLICY_NAMES
from repro.core.runtime import TurnipRuntime, eval_taskgraph
from repro.core.simulate import simulate

from .common import P100_SERVER, emit

SHAPE = (4, 4)
MIN_VERTICES = 500
TARGET_RATIO = 2.0
# mixed-plan gate: per-vertex cost with inline seams vs all-static
SEAM_TARGET_RATIO = 1.3


def braided_workload(n_ops: int, dist: int = 17) -> TaskGraph:
    """A mostly-sequential chain that every ninth step folds in a tensor
    from ``dist`` steps back: with ``capacity=3`` and a 1-unit host tier
    every old reference forces an offload→spill→load→reload chain through
    the disk tier, so the memgraph is transfer-dense yet chain-shaped.
    At ``dist=17`` the single host unit serializes the tiering chains —
    the certifier proves the whole order forced and the plan compiles
    fully static. At ``dist=31`` chains overlap enough that transfer
    completion order legitimately matters, opening nondet windows — the
    seam-handoff configuration."""
    tg = TaskGraph()
    tids = [tg.add_input(0, SHAPE, name=f"in{i}") for i in range(2)]
    for i in range(n_ops):
        if i % 9 == 3 and len(tids) > dist + 3:
            old = tids[len(tids) - dist]
            tids.append(tg.add_compute(0, (tids[-1], old), SHAPE, op="add",
                                       name=f"b{i}"))
        else:
            tids.append(tg.add_compute(0, (tids[-1],), SHAPE, op="relu",
                                       name=f"u{i}"))
    return tg


def build_tiered_plan(min_vertices: int = MIN_VERTICES, dist: int = 17):
    """Grow the workload until the lowered plan has ≥ ``min_vertices``
    memgraph vertices with real SPILL/LOAD traffic."""
    n_ops = 420
    while True:
        tg = braided_workload(n_ops, dist)
        try:
            res = build_memgraph(tg, BuildConfig(
                capacity=3, host_capacity=1, disk_capacity=200, rng_seed=0,
                size_fn=lambda v: 1, certify_liveness=True))
        except MemgraphOOM:
            n_ops += 64
            continue
        if len(res.memgraph.vertices) >= min_vertices and res.n_loads:
            return tg, res
        n_ops += 64


def best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def paired_times(fn_a, fn_b, repeats: int) -> list[tuple[float, float]]:
    """Time two workloads over interleaved A,B,A,B… rounds and return the
    per-round (t_a, t_b) pairs: within a round both sides see the same
    machine conditions, so a per-round ratio cancels common-mode noise
    (allocator state, CPU frequency, background load) that back-to-back
    separate loops would not."""
    out: list[tuple[float, float]] = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn_a()
        t_a = time.perf_counter() - t0
        t0 = time.perf_counter()
        fn_b()
        out.append((t_a, time.perf_counter() - t0))
    return out


def run(quick=False) -> list[dict]:
    tg, res = build_tiered_plan()
    mg = res.memgraph
    n = len(mg.vertices)
    rng = np.random.default_rng(0)
    inputs = {t: rng.integers(-3, 4, v.out.shape).astype(np.float64)
              for t, v in tg.vertices.items() if v.kind.value == "input"}
    ref = eval_taskgraph(tg, inputs)
    rows: list[dict] = []

    # -- byte-exactness gate: compiled backend vs oracle, all 4 policies
    for policy in POLICY_NAMES:
        rr = TurnipRuntime(tg, res, mode="nondet", policy=policy, seed=0,
                           exec_backend="compiled").run(inputs)
        for k in ref:
            np.testing.assert_array_equal(rr.outputs[k], ref[k])
        assert rr.n_compiled + rr.n_interpreted == n

    # -- dispatch overhead per vertex, interpreted vs compiled ----------
    # one runtime per backend: the CompiledPlan is lowered once and
    # cached, so the timing loop measures execution, not lowering
    repeats = 3 if quick else 5
    interp = TurnipRuntime(tg, res, mode="nondet", policy="critical-path",
                           seed=0, exec_backend="interpreted")
    comp = TurnipRuntime(tg, res, mode="nondet", policy="critical-path",
                         seed=0, exec_backend="compiled")
    interp.run(inputs)                   # warm (thread stacks, allocator)
    comp.run(inputs)                     # warm (lower + verify cached)
    t_interp = best_of(lambda: interp.run(inputs), repeats)
    t_comp = best_of(lambda: comp.run(inputs), repeats)
    rr = comp.run(inputs)
    ratio = t_interp / t_comp
    us_i = t_interp / n * 1e6
    us_c = t_comp / n * 1e6
    emit("compiled/interpreted_per_vertex", us_i, f"n={n}")
    emit("compiled/compiled_per_vertex", us_c,
         f"n={n} static={rr.n_compiled} seam={rr.n_interpreted}")
    emit("compiled/dispatch_speedup", t_comp * 1e6,
         f"interp/compiled={ratio:.2f}x (target >= {TARGET_RATIO}x)")
    rows.append(dict(metric="dispatch_overhead", n_vertices=n,
                     interpreted_us_per_vertex=us_i,
                     compiled_us_per_vertex=us_c, speedup=ratio,
                     n_compiled=rr.n_compiled,
                     n_interpreted=rr.n_interpreted,
                     ok=bool(ratio >= TARGET_RATIO)))

    # -- seam-handoff cost on a mixed plan (the §17 inline gate) --------
    # dist=31 overlaps the tiering chains: transfer completion order
    # legitimately matters, so the compiler keeps nondet regions. Small
    # seams are stamped onto the thread-free inline executor — a seam
    # vertex must cost heap pops, not OS wakeups, so the mixed plan's
    # per-vertex cost is gated against the all-static plan's.
    tg_mix, res_mix = build_tiered_plan(dist=31)
    n_mix = len(res_mix.memgraph.vertices)
    inputs_mix = {t: rng.integers(-3, 4, v.out.shape).astype(np.float64)
                  for t, v in tg_mix.vertices.items()
                  if v.kind.value == "input"}
    ref_mix = eval_taskgraph(tg_mix, inputs_mix)
    interp_m = TurnipRuntime(tg_mix, res_mix, mode="nondet",
                             policy="critical-path", seed=0,
                             exec_backend="interpreted")
    comp_m = TurnipRuntime(tg_mix, res_mix, mode="nondet",
                           policy="critical-path", seed=0,
                           exec_backend="compiled")
    interp_m.run(inputs_mix)
    rr_m = comp_m.run(inputs_mix)
    for k in ref_mix:
        np.testing.assert_array_equal(rr_m.outputs[k], ref_mix[k])
    assert rr_m.n_interpreted > 0, "mixed plan opened no nondet seams"
    assert rr_m.n_inline > 0, \
        "no seam ran inline — backend stamping regressed"
    t_im = best_of(lambda: interp_m.run(inputs_mix), repeats)
    # the gate is a RATIO of two ~10ms measurements. Time them as
    # interleaved pairs and gate on the *median per-round* ratio: a
    # round's two runs share machine conditions (common-mode noise
    # cancels), and the median rejects one-sided spikes — a disk flush
    # landing on just one run pollutes some rounds but not most, while a
    # genuine wakeup regression inflates every round.
    pairs = paired_times(lambda: comp.run(inputs),
                         lambda: comp_m.run(inputs_mix),
                         4 * repeats + 1)
    t_static = min(a for a, _ in pairs)
    t_cm = min(b for _, b in pairs)
    ratios = sorted(b / a for a, b in pairs)
    seam_ratio = ratios[len(ratios) // 2] * (n / n_mix)
    emit("compiled/mixed_plan_per_vertex", t_cm / n_mix * 1e6,
         f"n={n_mix} static={rr_m.n_compiled} seam={rr_m.n_interpreted} "
         f"(inline={rr_m.n_inline} threaded={rr_m.n_threaded}) "
         f"vs-static={seam_ratio:.2f}x (target <= {SEAM_TARGET_RATIO}x)")
    rows.append(dict(metric="mixed_plan_dispatch", n_vertices=n_mix,
                     interpreted_us_per_vertex=t_im / n_mix * 1e6,
                     compiled_us_per_vertex=t_cm / n_mix * 1e6,
                     speedup=t_im / t_cm, n_compiled=rr_m.n_compiled,
                     n_interpreted=rr_m.n_interpreted,
                     n_inline=rr_m.n_inline, n_threaded=rr_m.n_threaded,
                     seam_overhead_vs_static=seam_ratio,
                     ok=bool(seam_ratio <= SEAM_TARGET_RATIO
                             and rr_m.n_inline > 0)))

    # -- fused-DMA ablation (simulator pricing) -------------------------
    plan = lower(res, policy="critical-path")
    hw = P100_SERVER["hw"]
    mk_unfused = simulate(mg, hw, mode="fixed").makespan
    mk_fused = simulate(mg, hw, mode="fixed", fused=plan.fused_map).makespan
    saved = 1.0 - mk_fused / mk_unfused
    emit("compiled/fused_dma_ablation", mk_fused * 1e6,
         f"batches={len(plan.batches)} unfused={mk_unfused * 1e6:.1f}us "
         f"saved={saved * 100:.1f}%")
    rows.append(dict(metric="fused_dma_ablation",
                     n_batches=len(plan.batches),
                     makespan_unfused_us=mk_unfused * 1e6,
                     makespan_fused_us=mk_fused * 1e6,
                     saved_fraction=saved,
                     ok=bool(mk_fused <= mk_unfused)))

    assert ratio >= TARGET_RATIO, (
        f"compiled dispatch overhead only {ratio:.2f}x lower than "
        f"interpreted (target {TARGET_RATIO}x) on {n} vertices")
    assert seam_ratio <= SEAM_TARGET_RATIO, (
        f"mixed-plan per-vertex cost {seam_ratio:.2f}x the all-static "
        f"plan's (target <= {SEAM_TARGET_RATIO}x) — inline seams are "
        f"paying wakeups again")
    assert plan.batches, "tiered plan produced no fused DMA batches"
    return rows


if __name__ == "__main__":  # PYTHONPATH=src python -m benchmarks.compiled_runtime
    run(quick=True)
