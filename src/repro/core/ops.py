"""Numeric op registry for MEMGRAPH execution.

The TURNIP runtime is kernel-agnostic: a TASKGRAPH vertex names an op in this
registry (paper: cuTensor calls / hand-written CUDA kernels; here: numpy
kernels on the CPU container, with the Pallas TPU kernels in
:mod:`repro.kernels` registered under the same names for TPU targets).

Every op is a pure function ``f(*operand_values, **params) -> np.ndarray``.
Ops must be deterministic given their operands so that any dependency-
respecting execution order yields identical results (floating-point
commutativity of the streaming ``add_into`` accumulation is the one paper-
sanctioned exception, §8 "asynchronous partial summations").
"""
from __future__ import annotations

from typing import Callable

import numpy as np

OPS: dict[str, Callable] = {}


def register(name: str) -> Callable[[Callable], Callable]:
    def deco(fn: Callable) -> Callable:
        if name in OPS:
            raise ValueError(f"op {name!r} already registered")
        OPS[name] = fn
        return fn
    return deco


def get_op(name: str) -> Callable:
    try:
        return OPS[name]
    except KeyError:
        raise KeyError(f"unknown op {name!r}; registered: {sorted(OPS)}") from None


# ---------------------------------------------------------------- basics
@register("copy")
def _copy(x, **_):
    return np.asarray(x)


@register("zeros")
def _zeros(*_, shape=(1,), dtype="float32", **__):
    return np.zeros(shape, np.dtype(dtype))


@register("add")
def _add(x, y, **_):
    return x + y


@register("sum")
def _sum(*xs, **_):
    out = xs[0]
    for x in xs[1:]:
        out = out + x
    return out


@register("mul")
def _mul(x, y, **_):
    return x * y


@register("scale")
def _scale(x, *, alpha=1.0, **_):
    return x * alpha


@register("matmul")
def _matmul(x, y, **_):
    return np.matmul(x, y)


@register("matmul_t")
def _matmul_t(x, y, **_):
    return np.matmul(x, np.swapaxes(y, -1, -2))


@register("relu")
def _relu(x, **_):
    return np.maximum(x, 0)


@register("gelu")
def _gelu(x, **_):
    return 0.5 * x * (1.0 + np.tanh(0.7978845608028654 * (x + 0.044715 * x ** 3)))


@register("silu")
def _silu(x, **_):
    return x / (1.0 + np.exp(-x))


@register("tanh")
def _tanh(x, **_):
    return np.tanh(x)


@register("transpose")
def _transpose(x, **_):
    return np.swapaxes(x, -1, -2)


@register("slice_rows")
def _slice_rows(x, *, start=0, stop=None, **_):
    return x[start:stop]


@register("concat")
def _concat(*xs, axis=0, **_):
    return np.concatenate(xs, axis=axis)


# ---------------------------------------------------------- attention bits
@register("rmsnorm")
def _rmsnorm(x, g, *, eps=1e-6, **_):
    var = np.mean(x.astype(np.float64) ** 2, axis=-1, keepdims=True)
    return (x / np.sqrt(var + eps) * g).astype(x.dtype)


@register("softmax")
def _softmax(x, **_):
    m = np.max(x, axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / np.sum(e, axis=-1, keepdims=True)


@register("scores")
def _scores(q, k, *, scale=1.0, causal=False, q_offset=0, **_):
    """q: [Sq, Dh] block at absolute offset q_offset; k: [Skv, Dh]."""
    s = np.matmul(q, np.swapaxes(k, -1, -2)) * scale
    if causal:
        n, m = s.shape[-2], s.shape[-1]
        qpos = np.arange(n) + q_offset
        mask = np.arange(m)[None, :] <= qpos[:, None]
        s = np.where(mask, s, -1e30)
    return s


@register("attn_out")
def _attn_out(p, v, **_):
    return np.matmul(p, v)


@register("lora_delta")
def _lora_delta(x, a, b, *, alpha=16.0, rank=16, **_):
    # x @ A^T @ B^T * (alpha/rank) — LoRA adapter path (paper §8 training)
    return np.matmul(np.matmul(x, np.swapaxes(a, -1, -2)),
                     np.swapaxes(b, -1, -2)) * (alpha / rank)


# ------------------------------------------------- exact backward fragments
@register("matmul_tn")
def _matmul_tn(x, y, **_):
    """x^T @ y — the dW fragment."""
    return np.matmul(np.swapaxes(x, -1, -2), y)


@register("softmax_bwd")
def _softmax_bwd(p, dp, **_):
    """VJP of softmax: p ⊙ (dp − Σ(dp⊙p))."""
    return p * (dp - np.sum(dp * p, axis=-1, keepdims=True))


@register("gelu_bwd")
def _gelu_bwd(x, dy, **_):
    c = 0.7978845608028654
    t = np.tanh(c * (x + 0.044715 * x ** 3))
    dg = 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t ** 2) * c * (1 + 3 * 0.044715 * x ** 2)
    return dy * dg


@register("rmsnorm_bwd")
def _rmsnorm_bwd(x, g, dy, *, eps=1e-6, **_):
    """Exact VJP of rmsnorm wrt x (gamma frozen in LoRA training)."""
    xf = x.astype(np.float64)
    D = xf.shape[-1]
    r = 1.0 / np.sqrt(np.mean(xf ** 2, axis=-1, keepdims=True) + eps)
    dyg = dy.astype(np.float64) * g
    dx = r * dyg - xf * (r ** 3 / D) * np.sum(dyg * xf, axis=-1, keepdims=True)
    return dx.astype(x.dtype)


@register("split_heads")
def _split_heads(x, *, n_heads=1, **_):
    """[T, H*dh] → [H, T, dh] (batched per-head attention math)."""
    T, W = x.shape
    dh = W // n_heads
    return np.ascontiguousarray(x.reshape(T, n_heads, dh).transpose(1, 0, 2))


@register("merge_heads")
def _merge_heads(x, **_):
    """[H, T, dh] → [T, H*dh]."""
    H, T, dh = x.shape
    return np.ascontiguousarray(x.transpose(1, 0, 2).reshape(T, H * dh))


@register("slice_rows_3d")
def _slice_rows_3d(x, *, start=0, stop=None, **_):
    """Slice axis 1 of [H, T, dh]."""
    return x[:, start:stop]
