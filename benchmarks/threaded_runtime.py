"""Threaded-runtime ablation: nondet-vs-fixed measured on *real threads*.

The discrete-event results in :mod:`benchmarks.stall_ablation` show what an
ideal engine model predicts; this benchmark closes the loop by running the
actual :class:`~repro.core.runtime.TurnipRuntime` — condition-variable
scheduler, per-direction DMA streams, pluggable dispatch policy — with
injected per-vertex latencies scaled from the P100 hardware model, so that
transfer/compute overlap (or fixed-order head-of-line blocking) shows up in
wall-clock makespan.

Reported: makespan per (mode, policy) and the fixed/nondet slowdown ratio —
the threaded analogue of the paper's §8 "fixed execution" ablation.
"""
from __future__ import annotations

from repro.core import BuildConfig, MemgraphOOM, TaskGraph, build_memgraph
from repro.core.dispatch import POLICY_NAMES
from repro.core.runtime import TurnipRuntime, eval_taskgraph
from repro.core.simulate import HardwareModel

import numpy as np

from .common import P100_SERVER, emit

# wall-clock scale: model durations are ~µs; stretch to ~ms so thread
# scheduling noise (~100 µs) is far below the signal.
LATENCY_SCALE = 150.0


def tiled_workload(n_layers: int = 4, n_tiles: int = 4,
                   d: int = 256, batch: int = 64) -> TaskGraph:
    """Layered tiled matmuls on one device: tight budgets force offload
    chains whose reloads either overlap compute (nondet) or stall the issue
    head (fixed)."""
    tg = TaskGraph()
    tile = d // n_tiles
    x = tg.add_input(0, (batch, d), name="x")
    h = x
    for l in range(n_layers):
        tiles = []
        for t in range(n_tiles):
            w = tg.add_input(0, (d, tile), name=f"w{l}.{t}")
            tiles.append(tg.add_compute(0, (h, w), (batch, tile), op="matmul",
                                        flops=2 * batch * d * tile,
                                        name=f"mm{l}.{t}"))
        cat = tg.add_compute(0, tuple(tiles), (batch, d), op="concat",
                             params={"axis": -1}, name=f"cat{l}")
        h = tg.add_compute(0, (cat,), (batch, d), op="gelu",
                           flops=8 * batch * d, name=f"act{l}")
    return tg


def measured_makespans(tg: TaskGraph, res, inputs, *, repeats: int = 1,
                       hw: HardwareModel | None = None) -> dict[str, float]:
    """Best-of-``repeats`` makespan for fixed mode and each nondet policy."""
    hw = hw or P100_SERVER["hw"]

    def latency(v):
        return hw.duration(v) * LATENCY_SCALE

    out: dict[str, float] = {}
    configs = [("fixed", "fixed")] + [("nondet", p) for p in POLICY_NAMES]
    for mode, policy in configs:
        key = mode if mode == "fixed" else f"nondet/{policy}"
        best = float("inf")
        for r in range(repeats):
            rr = TurnipRuntime(tg, res, mode=mode, policy=policy, seed=r,
                               latency=latency).run(inputs)
            best = min(best, rr.makespan)
        out[key] = best
    return out


def run(quick=False) -> list[dict]:
    n_layers = 3 if quick else 5
    tg = tiled_workload(n_layers=n_layers)
    # tightest feasible budget → heavy offload traffic (reload stalls are
    # exactly what the fixed issue order cannot hide)
    total = sum(v.out.nbytes for v in tg.vertices.values())
    res = None
    for div in range(12, 3, -1):
        try:
            res = build_memgraph(tg, BuildConfig(capacity=total // div))
            break
        except MemgraphOOM:
            continue
    assert res is not None, "no feasible budget"

    rng = np.random.default_rng(0)
    inputs = {t: rng.standard_normal(v.out.shape).astype(np.float32) * 0.1
              for t, v in tg.vertices.items() if v.kind.value == "input"}
    ref = eval_taskgraph(tg, inputs)

    spans = measured_makespans(tg, res, inputs, repeats=1 if quick else 3)
    rows = []
    fixed_ms = spans["fixed"] * 1e3
    for key, mk in spans.items():
        ratio = spans["fixed"] / mk
        rows.append(dict(config=key, makespan_ms=mk * 1e3,
                         fixed_over_this=ratio))
        emit(f"threaded/{key}", mk * 1e6,
             f"fixed/this={ratio:.2f}x n_off={res.n_offloads}")
    best_nondet = min(v for k, v in spans.items() if k != "fixed")
    emit("threaded/fixed_slowdown", fixed_ms * 1e3,
         f"fixed/best_nondet={spans['fixed'] / best_nondet:.2f}x")

    # correctness spot check rides along: real-thread schedules are still
    # order-independent.
    rr = TurnipRuntime(tg, res, mode="nondet", policy="critical-path",
                       seed=0).run(inputs)
    for k in ref:
        np.testing.assert_allclose(rr.outputs[k], ref[k], rtol=1e-5)
    return rows


if __name__ == "__main__":   # PYTHONPATH=src python -m benchmarks.threaded_runtime
    run(quick=True)
