"""The unified executor core (DESIGN.md §17): the shared ReadyKernel's
ready sets against a brute-force dependency recount, inline dispatch
replaying the compile-time linearization tie-break, fixed-mode
head-of-line issue, policy ``prepare()`` caching across a run's seams,
and lock-order-sanitizer coverage of the scheduler lock."""
import random as pyrandom

import numpy as np

from repro.core import (BuildConfig, MemgraphOOM, build_memgraph,
                        lockcheck)
from repro.core.compile import NONDET, lower
from repro.core.dispatch import (POLICY_NAMES, CriticalPathPolicy, engine_of,
                                 get_policy)
from repro.core.executor import (ExecContext, ReadyKernel, ThreadedExecutor,
                                 select_best)
from repro.core.runtime import TurnipRuntime, eval_taskgraph

from helpers import fig3_taskgraph, int_inputs, random_taskgraph

UNITS = dict(size_fn=lambda v: 1)


def build(tg, seed=0, **kw):
    cfg = BuildConfig(capacity=3, rng_seed=seed, **UNITS, **kw)
    return build_memgraph(tg, cfg)


def try_build(tg, seed=0, **kw):
    """Corpus loops skip the rare random plan that does not fit."""
    try:
        return build(tg, seed, **kw)
    except MemgraphOOM:
        return None


# ------------------------------------------------------------ select_best
class TestSelectBest:
    def test_picks_minimum_rank(self):
        assert select_best([3, 1, 2], lambda x: x) == 1
        assert select_best([5], lambda x: -x) == 0

    def test_first_of_tied_candidates_wins(self):
        # stable like min(): the serve reload policies rely on seq being
        # part of the rank, but ties must still resolve deterministically
        assert select_best(["b", "z", "a"], lambda s: 0) == 0

    def test_rank_evaluated_at_call_time(self):
        # dynamic ranks (serve reload deadlines) are re-evaluated per call
        prio = {"x": 2, "y": 1}
        assert select_best(["x", "y"], prio.__getitem__) == 1
        prio["x"] = 0
        assert select_best(["x", "y"], prio.__getitem__) == 0


# ------------------------------------------------------------ ReadyKernel
class TestReadyKernel:
    def test_ready_sets_match_brute_force(self):
        """At every dispatch step, the kernel's ready view must equal the
        from-scratch recount — vertices whose in-subset predecessors all
        completed — each filed under its own (device, engine) key."""
        for seed in range(6):
            rng = pyrandom.Random(seed)
            tg = random_taskgraph(rng)
            res = try_build(tg, seed)
            if res is None:
                continue
            mg = res.memgraph
            for pname in POLICY_NAMES:
                pol = get_policy(pname, seed=seed)
                pol.prepare(mg)
                members = list(mg.vertices)
                k = ReadyKernel(mg, members, pol, "nondet")
                for m in k.load(members):
                    k.publish(m)
                done: set = set()
                popped: set = set()
                while not k.done:
                    want = {m for m in members if m not in popped
                            and all(p in done for p in mg.preds[m])}
                    view = k.ready_view()
                    got = {m for ms in view.values() for m in ms}
                    assert got == want
                    for key, ms in view.items():
                        for m in ms:
                            v = mg.vertices[m]
                            assert (v.device, engine_of(v)) == key
                    m = k.pop_best()
                    assert m is not None and m in want
                    popped.add(m)
                    done.add(m)
                    for s in k.complete(m):
                        k.publish(s)
                assert popped == set(members)

    def test_subset_job_treats_outside_preds_as_complete(self):
        """A job over a suffix of a chain must start immediately: the
        cross-region dependency points backward (already executed)."""
        rng = pyrandom.Random(4)
        tg = random_taskgraph(rng)
        res = build(tg, 4)
        mg = res.memgraph
        pol = get_policy("fixed")
        pol.prepare(mg)
        all_m = sorted(mg.vertices, key=lambda m: mg.vertices[m].seq)
        tail = all_m[len(all_m) // 2:]
        k = ReadyKernel(mg, tail, pol, "nondet")
        ready = k.load(tail)
        # brute-force: ready iff no predecessor INSIDE the job is pending
        tailset = set(tail)
        want = [m for m in tail
                if not any(p in tailset for p in mg.preds[m])]
        assert sorted(ready) == sorted(want)

    def test_fixed_mode_issues_strict_seq_order(self):
        """Fixed mode is head-of-line: the pops replay the build's issue
        order exactly, whatever the heap keys would have preferred."""
        for seed in (1, 4):
            rng = pyrandom.Random(seed)
            tg = random_taskgraph(rng)
            res = build(tg, seed)
            mg = res.memgraph
            pol = get_policy("fixed")
            pol.prepare(mg)
            members = list(mg.vertices)
            k = ReadyKernel(mg, members, pol, "fixed")
            for m in k.load(members):
                k.publish(m)
            seqs = []
            while not k.done:
                m = k.pop_best()
                assert m is not None, "head-of-line vertex never became ready"
                seqs.append(mg.vertices[m].seq)
                for s in k.complete(m):
                    k.publish(s)
            assert seqs == sorted(mg.vertices[m].seq for m in members)

    def test_inline_pop_replays_linearization(self):
        """pop_best's ``(priority, seq, mid)`` choice is exactly the
        compile-time linearization tie-break, so an inline seam under a
        deterministic policy executes its plan-order slice verbatim —
        the inline backend is the linearizer re-run at execution time."""
        checked = 0
        for seed in range(6):
            rng = pyrandom.Random(seed)
            tg = random_taskgraph(rng)
            res = try_build(tg, seed)
            if res is None:
                continue
            mg = res.memgraph
            for pname in ("fixed", "critical-path", "transfer-first"):
                pol = get_policy(pname)
                pol.prepare(mg)
                plan = lower(res, policy=pol)
                for r in plan.regions:
                    if r.kind != NONDET:
                        continue
                    mids = list(plan.order[r.start:r.end])
                    k = ReadyKernel(mg, mids, pol, "nondet")
                    for m in k.load(mids):
                        k.publish(m)
                    got = []
                    while not k.done:
                        m = k.pop_best()
                        assert m is not None
                        got.append(m)
                        for s in k.complete(m):
                            k.publish(s)
                    assert got == mids
                    checked += 1
        assert checked > 0, "corpus produced no nondet regions"


# --------------------------------------------------- policy prepare cache
class _CountingPolicy(CriticalPathPolicy):
    def __init__(self):
        super().__init__()
        self.calls = 0

    def prepare(self, mg):
        self.calls += 1
        super().prepare(mg)


class TestPolicyPrepareCaching:
    def test_one_prepare_per_run_however_many_seams(self):
        """Dispatch state is computed once per run and shared by every
        seam executor — N nondet regions must not mean N prepare()
        passes (and the lowering's own prepare is the run's one)."""
        tg = fig3_taskgraph()
        res = build(tg)
        pol = _CountingPolicy()
        rt = TurnipRuntime(tg, res, exec_backend="compiled", policy=pol)
        ref = eval_taskgraph(tg, int_inputs(tg))
        rr = rt.run(int_inputs(tg))
        assert rr.n_interpreted > 0, "plan has no seams to exercise"
        n_seams = sum(1 for r in rt._compiled.regions if r.kind == NONDET)
        assert n_seams >= 1
        assert pol.calls == 1
        for k in ref:
            np.testing.assert_array_equal(rr.outputs[k], ref[k])
        # a second run reuses the cached plan but refreshes dispatch state
        rt.run(int_inputs(tg))
        assert pol.calls == 2


# ------------------------------------------------------------- lockcheck
class TestSchedulerLock:
    def test_scheduler_lock_is_sanitized(self):
        tg = fig3_taskgraph()
        res = build(tg)
        mg = res.memgraph
        pol = get_policy("fixed")
        pol.prepare(mg)
        ctx = ExecContext.make(mg, tg, None, None, pol, "nondet", None,
                               0.0, [])
        ex = ThreadedExecutor(ctx, [])
        try:
            assert isinstance(ex.lock, lockcheck.SanitizedLock)
            assert "ExecutorScheduler" in repr(ex.lock)
        finally:
            ex.close()

    def test_scheduler_lock_stays_a_leaf_under_tiered_runs(self):
        """No sanitized lock (store, pool) may ever be taken while the
        scheduler lock is held: vertices execute OUTSIDE it. A tiered
        threaded run exercises store locks from worker threads; the
        acquisition graph must show no outgoing edge from the scheduler
        lock, and stay acyclic overall."""
        tg = fig3_taskgraph()
        res = build(tg, host_capacity=2, disk_capacity=50)
        ref = eval_taskgraph(tg, int_inputs(tg))
        for exec_backend in ("interpreted", "compiled"):
            rr = TurnipRuntime(tg, res, exec_backend=exec_backend,
                               policy="random", seed=0).run(int_inputs(tg))
            for k in ref:
                np.testing.assert_array_equal(rr.outputs[k], ref[k])
        out = lockcheck.edges().get("ExecutorScheduler", set())
        assert not out, f"locks acquired under the scheduler lock: {out}"
        lockcheck.assert_acyclic()
