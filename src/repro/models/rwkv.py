"""RWKV-6 "Finch" blocks (for rwkv6-7b) — data-dependent decay linear
attention (arXiv:2404.05892).

Time-mix: token-shift interpolation with data-dependent mix (via a small
LoRA), per-channel data-dependent decay ``w_t``, and the WKV linear-attention
recurrence over per-head state ``S ∈ R^{P×P}``:

    S_t = diag(w_t) S_{t-1} + k_t^T (v_t)        y_t = (r_t S_t) + bonus u

Channel-mix: squared-ReLU gated MLP with token shift. Both are expressed as
``lax.scan`` recurrences (O(1) state — this is why rwkv6 runs the
``long_500k`` shape); the chunked-parallel Pallas kernel lives in
:mod:`repro.kernels.rwkv6`.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def rwkv6_init(key: Array, d_model: int, *, headdim: int = 64,
               lora_r: int = 32, d_ff: int | None = None,
               dtype=jnp.float32) -> dict:
    H = d_model // headdim
    d_ff = d_ff or int(3.5 * d_model)
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d_model)
    return {
        # time-mix
        "mix_rkvwg": jnp.zeros((5, d_model), dtype),        # static mix coeffs
        "mix_lora_A": jax.random.normal(ks[0], (d_model, 5 * lora_r), dtype) * s,
        "mix_lora_B": jnp.zeros((5, lora_r, d_model), dtype),
        "w_lora_A": jax.random.normal(ks[1], (d_model, lora_r), dtype) * s,
        "w_lora_B": jnp.zeros((lora_r, d_model), dtype),
        "w_base": jnp.full((d_model,), -6.0, jnp.float32),  # decay base
        "wr": jax.random.normal(ks[2], (d_model, d_model), dtype) * s,
        "wk": jax.random.normal(ks[3], (d_model, d_model), dtype) * s,
        "wv": jax.random.normal(ks[4], (d_model, d_model), dtype) * s,
        "wg": jax.random.normal(ks[5], (d_model, d_model), dtype) * s,
        "bonus_u": jnp.zeros((H, headdim), jnp.float32),
        "ln_x_g": jnp.ones((d_model,), dtype),
        "wo": jax.random.normal(ks[6], (d_model, d_model), dtype) * s,
        # channel-mix
        "cmix_k": jnp.zeros((d_model,), dtype),
        "cmix_r": jnp.zeros((d_model,), dtype),
        "ck": jax.random.normal(ks[7], (d_model, d_ff), dtype) * s,
        "cv": jax.random.normal(ks[8], (d_ff, d_model), dtype) / math.sqrt(d_ff),
        "cr": jax.random.normal(ks[9], (d_model, d_model), dtype) * s,
    }


def _token_shift(x: Array, prev: Array | None = None) -> Array:
    """x[t-1] (zeros / carried ``prev`` at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv6_chunked(r: Array, k: Array, v: Array, lw: Array, u: Array, *,
                 chunk: int = 32,
                 s0: Array | None = None) -> tuple[Array, Array]:
    """Chunked WKV6 recurrence (exact; the training-friendly form).

    r/k/v: [B,S,H,P] f32; ``lw`` = log decay (≤ 0); u: [H,P] bonus.
    Within a chunk all decay factors appear as exp(differences of cumulative
    log-decays) with non-positive exponents — numerically safe without 1/w
    divisions. Backward stores only chunk-boundary states (the naive
    per-token scan would store an [B,H,P,P] residual per token).
    Returns (y: [B,S,H,P], final state [B,H,P,P])."""
    B, S, H, Pd = r.shape
    c = min(chunk, S)
    n = (S + c - 1) // c
    pad = n * c - S
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, lw = z(r), z(k), z(v), z(lw)
    resh = lambda t: jnp.moveaxis(
        t.reshape(B, n, c, H, Pd), 1, 0)                 # [n,B,c,H,P]
    rj_, kj_, vj_, lwj_ = resh(r), resh(k), resh(v), resh(lw)

    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)          # strict lower

    def chunk_step(S_in, inp):
        rj, kj, vj, lwj = inp                             # [B,c,H,P]
        lcw = jnp.cumsum(lwj, axis=1)                     # inclusive cumsum
        prev = lcw - lwj                                  # lcw_{t-1}
        # intra-chunk: A[t,s] = Σ_p r_t k_s e^{prev_t - lcw_s}, s < t.
        # Mask the exponent INPUT (s ≥ t diffs are positive → exp overflow
        # → NaN in the where-VJP), not the exp output.
        diff = prev[:, :, None] - lcw[:, None]            # [B,t,s,H,P]
        E = jnp.exp(jnp.where(tri[None, :, :, None, None], diff, -1e30))
        A = jnp.einsum("bthp,btshp,bshp->bths", rj, E, kj)
        y = jnp.einsum("bths,bshq->bthq", A, vj)
        # diagonal bonus term: (r_t · u ⊙ k_t) v_t
        du = jnp.einsum("bthp,hp,bthp->bth", rj, u, kj)
        y = y + du[..., None] * vj
        # incoming state
        y = y + jnp.einsum("bthp,bhpq->bthq", rj * jnp.exp(prev), S_in)
        # state passing
        tailw = jnp.exp(lcw[:, -1:] - lcw)                # [B,c,H,P] ≤ 1
        S_out = (jnp.exp(lcw[:, -1])[..., None] * S_in     # [B,H,P,1]·[B,H,P,Q]
                 + jnp.einsum("bshp,bshq->bhpq", kj * tailw, vj))
        return S_out, y

    S_in = (jnp.zeros((B, H, Pd, Pd), jnp.float32) if s0 is None else s0)
    S_fin, ys = jax.lax.scan(jax.checkpoint(chunk_step), S_in,
                             (rj_, kj_, vj_, lwj_))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, n * c, H, Pd)[:, :S]
    return y, S_fin


def rwkv6_time_mix(p: dict, x: Array, *, headdim: int = 64, chunk: int = 32,
                   state: tuple | None = None, return_state: bool = False):
    """x: [B, S, D]. ``state``: (shift [B,1,D], wkv [B,H,P,P])."""
    B, S, D = x.shape
    H = D // headdim
    Pd = headdim
    prev = state[0] if state is not None else None
    xs = _token_shift(x, prev)
    dx = xs - x
    # data-dependent mixing coefficients (5 heads of a shared LoRA)
    lr = jnp.tanh(x @ p["mix_lora_A"]).reshape(B, S, 5, -1)
    mixes = p["mix_rkvwg"][None, None] + jnp.einsum(
        "bsfr,frd->bsfd", lr, p["mix_lora_B"])           # [B,S,5,D]
    xr, xk, xv, xw, xg = [x + dx * mixes[:, :, i] for i in range(5)]

    r = (xr @ p["wr"]).reshape(B, S, H, Pd)
    k = (xk @ p["wk"]).reshape(B, S, H, Pd)
    v = (xv @ p["wv"]).reshape(B, S, H, Pd)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay  w ∈ (0,1): log w = -exp(...)  (≤ 0 always)
    lw = p["w_base"] + (jnp.tanh(xw @ p["w_lora_A"]) @ p["w_lora_B"]
                        ).astype(jnp.float32)
    lw = -jnp.exp(lw).reshape(B, S, H, Pd)

    s0 = state[1] if state is not None else None
    y, sT = wkv6_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), lw, p["bonus_u"],
                         chunk=chunk, s0=s0)
    y = y.reshape(B, S, D).astype(x.dtype)

    from .layers import rmsnorm   # GroupNorm≈per-head rmsnorm simplification
    y = rmsnorm(y, p["ln_x_g"]) * g
    out = y @ p["wo"]
    if return_state or state is not None:
        return out, (x[:, -1:], sT)
    return out


def rwkv6_channel_mix(p: dict, x: Array, *, state: Array | None = None,
                      return_state: bool = False):
    prev = state if state is not None else None
    xs = _token_shift(x, prev)
    dx = xs - x
    xk = x + dx * p["cmix_k"]
    xr = x + dx * p["cmix_r"]
    k = jnp.square(jax.nn.relu(xk @ p["ck"]))
    kv = k @ p["cv"]
    out = jax.nn.sigmoid(xr @ p["cr"]) * kv
    if return_state or state is not None:
        return out, x[:, -1:]
    return out
