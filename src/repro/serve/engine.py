"""Batched serving engine: prefill → decode with bucketed static shapes.

The paper's limitation (§9) — TURNIP needs a static graph, so recursive
generation requires pre-compiled plans — becomes systematic here: decode
steps are jitted per (batch-bucket, cache-bucket) and requests are batched
into the smallest bucket that fits (the "naive solution" the paper sketches,
made production-shaped). The KV cache is preallocated at the bucket size, so
serving does no allocation per token — the same static-memory discipline as
the MEMGRAPH runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    batch_buckets: tuple[int, ...] = (1, 4, 8)
    temperature: float = 0.0          # 0 = greedy


class Engine:
    def __init__(self, model, params, cfg: ServeConfig = ServeConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._steps: dict[int, Any] = {}

    def _bucket(self, n: int) -> int:
        for b in self.cfg.batch_buckets:
            if n <= b:
                return b
        raise ValueError(f"batch {n} exceeds largest bucket")

    def _step_fn(self, bucket: int):
        if bucket not in self._steps:
            self._steps[bucket] = jax.jit(self.model.decode_step)
        return self._steps[bucket]

    def generate(self, prompts: list[list[int]], *, max_new: int = 32,
                 seed: int = 0) -> list[list[int]]:
        """Greedy/temperature decode for a batch of prompts (pad to bucket)."""
        n = len(prompts)
        bucket = self._bucket(n)
        cfg = self.model.cfg
        max_prompt = max(len(p) for p in prompts)
        total = max_prompt + max_new
        if total > self.cfg.max_len:
            raise ValueError("sequence exceeds max_len")
        cache = self.model.init_cache(bucket, self.cfg.max_len)
        step = self._step_fn(bucket)
        toks = np.zeros((bucket, total), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        out: list[list[int]] = [[] for _ in range(bucket)]
        key = jax.random.PRNGKey(seed)
        cur = jnp.asarray(toks[:, 0:1])
        for t in range(total - 1):
            logits, cache = step(self.params, cache, cur,
                                 jnp.asarray(t, "int32"))
            if self.cfg.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits / self.cfg.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = np.asarray(nxt, np.int32)
            tpos = t + 1
            for i in range(bucket):
                if tpos < len(prompts[i]) if i < n else True:
                    pass
            # teacher-force prompt tokens, free-run afterwards
            forced = toks[:, tpos] if tpos < total else None
            step_tok = np.where(
                np.array([tpos < len(prompts[i]) if i < n else True
                          for i in range(bucket)]),
                forced, nxt)
            for i in range(n):
                if tpos >= len(prompts[i]) and len(out[i]) < max_new:
                    out[i].append(int(step_tok[i]))
            cur = jnp.asarray(step_tok[:, None])
        return [out[i] for i in range(n)]
