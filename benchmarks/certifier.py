"""Plan-certifier cost: certification time vs plan size on tiered-offload
plans (DESIGN.md §13). The certifier is a compile-time tool — this prices
what `BuildConfig(certify=True)` adds to a build: the reachability
closure, the all-pairs overlap sweep, and the max-weight-antichain budget
bound, per MEMGRAPH vertex. Plans come from the activation-offload
workload (`tiered_offload.activation_workload`) with the host tier
bounded at half its working set, so every plan carries real
OFFLOAD/RELOAD traffic plus disk SPILL/LOAD chains."""
from __future__ import annotations

import time

from repro.core import BuildConfig, build_memgraph, certify

from .common import emit
from .tiered_offload import activation_workload


def run(quick=False) -> list[dict]:
    rows = []
    layer_counts = (6, 12) if quick else (6, 12, 24, 48)
    for n_layers in layer_counts:
        tg = activation_workload(n_layers=n_layers)
        act_bytes = tg.vertices[0].out.nbytes
        cap = 6 * act_bytes          # tight device budget: acts offload
        probe = build_memgraph(tg, BuildConfig(capacity=cap))
        host_cap = max(1, probe.peak_host // 2)    # half the working set:
        t0 = time.time()                           # forces disk spills
        res = build_memgraph(tg, BuildConfig(capacity=cap,
                                             host_capacity=host_cap))
        build_s = time.time() - t0
        assert res.n_spills > 0, "workload stopped spilling to disk"
        mg = res.memgraph
        t0 = time.time()
        cert = certify(mg, host_capacity=host_cap)
        cert_s = time.time() - t0
        assert cert.ok, cert.summary()
        n = len(mg)
        rows.append(dict(n_layers=n_layers, verts=n, build_s=build_s,
                         cert_s=cert_s,
                         pairs=cert.n_pairs_checked,
                         residencies=cert.n_host_residencies,
                         blobs=cert.n_disk_blobs,
                         worst_host=cert.worst_host_units))
        emit(f"certifier/layers{n_layers}", cert_s / n * 1e6,
             f"verts={n};pairs={cert.n_pairs_checked};"
             f"res={cert.n_host_residencies};blobs={cert.n_disk_blobs};"
             f"cert_vs_build={cert_s / max(build_s, 1e-9):.2f}x")
    return rows


if __name__ == "__main__":
    run()
