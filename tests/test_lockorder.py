"""The debug-mode lock-order sanitizer (lockcheck.py, DESIGN.md §13).

The autouse fixture in ``conftest.py`` runs every test in the suite under
the sanitizer and asserts the recorded acquisition graph acyclic at
teardown; these tests exercise the machinery itself — that real runtime
traffic records the documented edge orientations, that a deliberate
inversion is caught with the concrete cycle, and that the instrumented
locks still back condition variables.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import BuildConfig, HostPool, build_memgraph, lockcheck
from repro.core.runtime import TurnipRuntime, eval_taskgraph

from helpers import fig3_taskgraph, int_inputs

UNITS = dict(size_fn=lambda v: 1)


def test_deliberate_inversion_is_caught():
    """Taking two lock classes in opposite orders on two code paths must
    fail with the concrete cycle, on any schedule (no deadlock needed)."""
    a, b = lockcheck.make_lock("A"), lockcheck.make_lock("B")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    with pytest.raises(lockcheck.LockOrderError, match="A -> B|B -> A"):
        lockcheck.assert_acyclic()
    lockcheck.reset()          # leave the autouse fixture a clean slate


def test_benign_nesting_passes():
    a, b = lockcheck.make_lock("outer"), lockcheck.make_lock("inner")
    for _ in range(3):
        with a:
            with b:
                pass
    assert "inner" in lockcheck.edges().get("outer", set())
    lockcheck.assert_acyclic()


def test_runtime_traffic_records_documented_orientation():
    """A pooled tiered run takes the real locks: the store lock must be
    observed *outside* the HostPool/DiskStore leaves, never inside."""
    tg = fig3_taskgraph()
    res = build_memgraph(tg, BuildConfig(capacity=3, host_capacity=1,
                                         **UNITS))
    inputs = int_inputs(tg)
    ref = eval_taskgraph(tg, inputs)
    pool = HostPool(1 << 20)
    lease = pool.lease("rt", weight=1.0)
    out = TurnipRuntime(tg, res, mode="nondet", policy="random", seed=0,
                        host_lease=lease).run(inputs).outputs
    for k in ref:
        np.testing.assert_array_equal(out[k], ref[k])
    g = lockcheck.edges()
    inner = g.get("TieredStore", set())
    assert inner & {"HostPool", "DiskStore"}, g
    # the leaves never wrap the store lock
    assert "TieredStore" not in g.get("HostPool", set())
    assert "TieredStore" not in g.get("DiskStore", set())
    lockcheck.assert_acyclic()


def test_sanitized_lock_backs_condition_variables():
    """threading.Condition over a SanitizedLock: wait/notify across two
    threads works and records balanced acquire/release."""
    lk = lockcheck.make_lock("CondLock")
    cond = threading.Condition(lk)
    state = {"ready": False}

    def waiter():
        with cond:
            while not state["ready"]:
                cond.wait(timeout=5)

    t = threading.Thread(target=waiter)
    t.start()
    with cond:
        state["ready"] = True
        cond.notify()
    t.join(timeout=5)
    assert not t.is_alive()
    assert not lk.locked()


def test_wait_reacquire_records_no_false_cycle():
    """Waiting on an *outer* condition while holding an *inner* lock is a
    legitimate pattern: ``Condition.wait()`` releases the outer lock, so
    nothing is held-and-wanted in both directions and no deadlock is
    possible. A wait-blind sanitizer records the post-notify reacquire as
    ``inner -> outer`` — inverting the real ``outer -> inner`` nesting of
    the same single code path and reporting a false cycle. The wait-aware
    hooks must keep the graph acyclic here."""
    outer = lockcheck.make_lock("wait_outer")
    inner = lockcheck.make_lock("wait_inner")
    cond = threading.Condition(outer)
    state = {"ready": False}

    def waiter():
        with cond:                # outer held
            with inner:           # records the real outer -> inner edge
                while not state["ready"]:
                    cond.wait(timeout=5)   # releases outer, inner stays

    t = threading.Thread(target=waiter)
    t.start()
    # let the waiter reach the wait before notifying
    deadline = time.monotonic() + 5
    while not outer.locked() and time.monotonic() < deadline:
        time.sleep(0.001)
    with cond:
        state["ready"] = True
        cond.notify()
    t.join(timeout=5)
    assert not t.is_alive()
    g = lockcheck.edges()
    assert "wait_inner" in g.get("wait_outer", set()), g
    # the reacquire after the wait must NOT have recorded the inversion
    assert "wait_outer" not in g.get("wait_inner", set()), g
    lockcheck.assert_acyclic()


def test_fleet_traffic_records_documented_orientation():
    """Router↔Supervisor↔Engine nesting under real fleet traffic
    (DESIGN.md §16): a 2-replica router run with a mid-decode kill takes
    every fleet lock class on real threads — admission under the router
    lock, heartbeats from run loops, NIC delivery into an engine, drain.
    The documented orientation (Router → ServeEngine, with Heartbeat and
    NicStream as leaves) must be recorded, never its inversion; the
    autouse sanitizer re-asserts acyclicity at teardown."""
    import jax
    from repro.configs import get_arch, reduced
    from repro.launch.mesh import FleetTopology
    from repro.models import build_model
    from repro.serve import Router, ServeConfig

    model = build_model(reduced(get_arch("olmo-1b")))
    params = model.init(jax.random.PRNGKey(0))
    cfg = ServeConfig(max_len=64, batch_buckets=(1, 2), block_size=16,
                      offload=True, hot_window=16, preempt_every=2, seed=3)
    topo = FleetTopology(n_replicas=2, heartbeat_timeout_s=60.0,
                         host_bytes_per_replica=64 << 20)
    with Router(model, params, cfg, topology=topo,
                placement="least-loaded") as router:
        # armed before any submit so the kill (and its drain edges) fire
        # deterministically at step 2 on every schedule
        router.replicas[0].engine.fault_after_steps = 2
        rids = [router.submit([1 + i, 2, 3, 4, 5], max_new=8)
                for i in range(5)]
        router.wait(rids, timeout=300)
        for r in rids:
            assert router.done(r)
    g = lockcheck.edges()
    # admission/dispatch nests the engine under the router lock — the one
    # documented compound hold; the inversion must never be recorded
    assert "ServeEngine" in g.get("Router", set()), g
    assert "Router" not in g.get("ServeEngine", set()), g
    # Heartbeat and NicStream are leaves: they never wrap a fleet lock
    for leaf in ("Heartbeat", "NicStream"):
        assert not (g.get(leaf, set())
                    & {"Router", "ServeEngine", "HostPool"}), (leaf, g)
    # pooled replicas charge their leases under the engine lock
    assert "HostPool" in g.get("ServeEngine", set()), g
    lockcheck.assert_acyclic()


def test_wait_reacquire_restores_stack_position():
    """After a wait resumes, later acquisitions must still see the
    waited-on lock as *held* (it is) and in its original nesting slot:
    an acquisition under it records outer -> new, not nothing."""
    outer = lockcheck.make_lock("restack_outer")
    other = lockcheck.make_lock("restack_other")
    cond = threading.Condition(outer)
    state = {"ready": False}

    def waiter():
        with cond:
            while not state["ready"]:
                cond.wait(timeout=5)
            with other:           # post-wait nesting: outer -> other
                pass

    t = threading.Thread(target=waiter)
    t.start()
    deadline = time.monotonic() + 5
    while not outer.locked() and time.monotonic() < deadline:
        time.sleep(0.001)
    with cond:
        state["ready"] = True
        cond.notify()
    t.join(timeout=5)
    assert not t.is_alive()
    assert "restack_other" in lockcheck.edges().get("restack_outer", set())
    lockcheck.assert_acyclic()
