"""Tiered-memory benchmark: bounded host tier + disk spill (DESIGN.md §10).

Three questions, extending the paper's claims one storage rung down:

1. **Throughput vs host-tier fraction.** The same offload-heavy plan is
   rebuilt with ``host_capacity`` at a sweep of fractions of the unbounded
   host working set. Shrinking the host tier forces Belady spills to disk
   and two-hop ``disk→host→device`` reload chains; simulated makespan
   quantifies the cost of each rung of the hierarchy.

2. **Nondet vs fixed under two-hop reload latency.** Disk reloads are the
   slowest, most variable transfers in the system — exactly the
   "seemingly nondeterministic" latencies (§2) the dispatch machinery
   exists to absorb. With transfer jitter on (paired random numbers), the
   fixed issue order stalls behind slow disk hops while nondeterministic
   dispatch reorders around them.

3. **Engine isolation (timeline-verified).** Every spill/load occupies the
   ``disk`` engine and nothing else: disk transfers never ride — or block —
   a compute, h2d, d2h, or d2d stream. A threaded-runtime spot check
   confirms disk-spilling plans stay oracle-equal on real threads under
   random/fixed/critical-path dispatch.

4. **Prefetch on/off stall ablation (DESIGN.md §11).** Sections 1–3 build
   with ``prefetch_distance=0`` (reactive force-reload placement, the PR-3
   baseline). This section rebuilds the same workload at host fractions
   < 1 with the PrefetchPlan on: disk→host LOADs hoisted ahead of the
   consumers' horizon must strictly cut simulated compute stall — the
   compiler knows every future reload, so the runtime should never block
   on a transfer it could have started earlier (paper §1).

CSV contract: ``name,us_per_call,derived`` via :func:`benchmarks.common.emit`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import BuildConfig, MemgraphOOM, TaskGraph, build_memgraph
from repro.core.dispatch import COMPUTE, D2D, D2H, DISK, H2D
from repro.core.runtime import TurnipRuntime, eval_taskgraph
from repro.core.simulate import simulate

from .common import P100_SERVER, emit

DISK_OPS = ("spill", "load", "drop")


def activation_workload(n_layers: int = 12, batch: int = 64,
                        d: int = 256, n_chains: int = 2) -> TaskGraph:
    """Forward/backward activation offload — the canonical host-pressure
    pattern: each chain's forward pass saves one activation per layer (all
    evicted to host under a tight device budget), the backward pass
    consumes them in *reverse* order. The host working set is the whole
    depth, and the activations reloaded last (early layers) are exactly the
    ones a bounded host tier spills to disk first (Belady). ``n_chains``
    independent microbatches interleave in the serialized order: under
    fixed-order issue, one chain's slow two-hop reload head-of-line blocks
    the other chain's ready compute — the gap nondet dispatch closes."""
    tg = TaskGraph()
    # flops metadata models each layer as a d→d_ff→d MLP block (the
    # simulator's cost model reads flops; the runtime executes the cheap
    # elementwise op) so simulated compute is commensurate with transfers
    d_ff = 8192
    layer_flops = 2 * batch * d * d_ff
    xs = [tg.add_input(0, (batch, d), name=f"x{c}") for c in range(n_chains)]
    acts: list[list[int]] = [[] for _ in range(n_chains)]
    hs = list(xs)
    for l in range(n_layers):
        for c in range(n_chains):
            hs[c] = tg.add_compute(0, (hs[c],), (batch, d), op="gelu",
                                   flops=layer_flops, name=f"fwd{c}.{l}")
            acts[c].append(hs[c])
    gs = [tg.add_compute(0, (hs[c],), (batch, d), op="relu",
                         name=f"loss{c}") for c in range(n_chains)]
    for l in reversed(range(n_layers)):
        for c in range(n_chains):
            gs[c] = tg.add_compute(0, (gs[c], acts[c][l]), (batch, d),
                                   op="mul", flops=2 * layer_flops,
                                   name=f"bwd{c}.{l}")
    return tg


def _is_disk_vertex(name: str) -> bool:
    return any(name.startswith(op + ":") for op in DISK_OPS)


def verify_timeline(sim) -> int:
    """Assert disk I/O only ever occupies the disk engine. Returns the
    number of disk-engine timeline entries."""
    n_disk = 0
    for (_t0, _t1, _dev, eng, name) in sim.timeline:
        if eng == DISK:
            assert _is_disk_vertex(name), \
                f"non-disk vertex {name!r} on the disk engine"
            n_disk += 1
        elif eng in (COMPUTE, H2D, D2H, D2D):
            assert not _is_disk_vertex(name), \
                f"disk transfer {name!r} on engine {eng!r}"
    return n_disk


def run(quick: bool = True) -> list[dict]:
    tg = activation_workload(n_layers=10 if quick else 24)
    act_bytes = tg.vertices[0].out.nbytes
    cap = 6 * act_bytes              # tight device budget: acts must offload
    res_unbounded = build_memgraph(tg, BuildConfig(capacity=cap))
    # live host working set: a bound wide enough to never spill still lets
    # the bounded builder retire dead host copies, so its peak is the true
    # simultaneous footprint (the unbounded peak only accumulates)
    res_base = build_memgraph(tg, BuildConfig(
        capacity=cap, host_capacity=res_unbounded.peak_host,
        prefetch_distance=0))
    assert res_base.n_spills == 0
    host_ws = res_base.peak_host
    hw = dataclasses.replace(P100_SERVER["hw"], transfer_jitter=0.6)

    rows: list[dict] = []
    # ---- 1. throughput vs host-tier fraction ---------------------------
    # sections 1-3 pin prefetch off: they measure the *reactive* tiering
    # baseline; section 4 ablates the PrefetchPlan against it
    fracs = (1.0, 0.5, 0.25) if quick else (1.0, 0.75, 0.5, 0.25, 0.125)
    tightest = None
    for frac in fracs:
        host_cap = max(int(host_ws * frac), 1)
        try:
            res = build_memgraph(tg, BuildConfig(capacity=cap,
                                                 host_capacity=host_cap,
                                                 prefetch_distance=0))
        except MemgraphOOM as e:
            emit(f"tiered/hostfrac{frac:g}", 0.0, f"OOM:{e}")
            continue
        res.memgraph.validate(check_races=False, host_capacity=host_cap)
        sim = simulate(res.memgraph, hw, mode="nondet",
                       policy="critical-path")
        rows.append(dict(frac=frac, makespan_ms=sim.makespan * 1e3,
                         n_spills=res.n_spills, n_loads=res.n_loads,
                         peak_host=res.peak_host))
        emit(f"tiered/hostfrac{frac:g}", sim.makespan * 1e6,
             f"spills={res.n_spills};loads={res.n_loads};"
             f"peak_host={res.peak_host}/{host_cap}")
        tightest = res
    assert tightest is not None and tightest.n_loads > 0, \
        "sweep never exercised the disk tier"

    # ---- 2. fixed vs nondet under two-hop reload latency ---------------
    fx = simulate(tightest.memgraph, hw, mode="fixed")
    best = None
    for policy in ("random", "critical-path", "transfer-first"):
        nd = simulate(tightest.memgraph, hw, mode="nondet", policy=policy)
        ratio = fx.makespan / nd.makespan
        rows.append(dict(dispatch=policy, ms=nd.makespan * 1e3,
                         fixed_ratio=ratio))
        emit(f"tiered/dispatch/{policy}", nd.makespan * 1e6,
             f"fixed/nondet={ratio:.2f}x")
        if best is None or nd.makespan < best:
            best = nd.makespan
    emit("tiered/fixed_slowdown", fx.makespan * 1e6,
         f"fixed/best_nondet={fx.makespan / best:.2f}x")
    assert fx.makespan > best, \
        "fixed-order issue failed to lose under two-hop reload latency"

    # ---- 3. engine isolation + threaded correctness --------------------
    sim = simulate(tightest.memgraph, hw, mode="nondet",
                   policy="critical-path", record_timeline=True)
    n_disk = verify_timeline(sim)
    assert n_disk > 0, "timeline recorded no disk transfers"
    emit("tiered/timeline_disk_isolated", 0.0,
         f"n_disk_ops={n_disk};disk_busy_ms={sim.transfer_time[DISK]*1e3:.2f}")

    rng = np.random.default_rng(0)
    inputs = {t: rng.standard_normal(v.out.shape).astype(np.float32) * 0.1
              for t, v in tg.vertices.items() if v.kind.value == "input"}
    ref = eval_taskgraph(tg, inputs)
    for policy in ("random", "fixed", "critical-path"):
        rr = TurnipRuntime(tg, tightest, mode="nondet", policy=policy,
                           seed=0).run(inputs)
        for k in ref:
            np.testing.assert_allclose(rr.outputs[k], ref[k], rtol=1e-5)
        assert rr.disk_spill_bytes > 0 and rr.disk_load_bytes > 0
    emit("tiered/threaded_oracle_equal", 0.0,
         f"spill_MB={rr.disk_spill_bytes/2**20:.1f};"
         f"load_MB={rr.disk_load_bytes/2**20:.1f}")

    # ---- 4. prefetch on/off stall ablation (DESIGN.md §11) -------------
    # deterministic (jitter off): the win is structural — hoisted LOADs
    # overlap disk I/O under compute instead of stalling the consumer —
    # so it must show without nondeterministic noise
    hw_det = dataclasses.replace(P100_SERVER["hw"], transfer_jitter=0.0)
    pf_fracs = (0.5, 0.25) if quick else (0.75, 0.5, 0.25, 0.125)
    won = 0
    for frac in pf_fracs:
        host_cap = max(int(host_ws * frac), 1)
        try:
            off = build_memgraph(tg, BuildConfig(
                capacity=cap, host_capacity=host_cap, prefetch_distance=0))
            on = build_memgraph(tg, BuildConfig(
                capacity=cap, host_capacity=host_cap))
        except MemgraphOOM as e:
            emit(f"tiered/prefetch/hostfrac{frac:g}", 0.0, f"OOM:{e}")
            continue
        on.memgraph.validate(check_races=False, host_capacity=host_cap)
        s_off = simulate(off.memgraph, hw_det, mode="nondet",
                         policy="critical-path")
        s_on = simulate(on.memgraph, hw_det, mode="nondet",
                        policy="critical-path")
        stall_cut = s_off.total_stall - s_on.total_stall
        rows.append(dict(frac=frac, prefetch=True,
                         stall_off_ms=s_off.total_stall * 1e3,
                         stall_on_ms=s_on.total_stall * 1e3,
                         n_prefetches=on.n_prefetches,
                         stall_bytes_hidden=on.stall_bytes_hidden))
        emit(f"tiered/prefetch/hostfrac{frac:g}", s_on.makespan * 1e6,
             f"stall_off_ms={s_off.total_stall*1e3:.2f};"
             f"stall_on_ms={s_on.total_stall*1e3:.2f};"
             f"n_prefetches={on.n_prefetches};"
             f"hidden_MB={on.stall_bytes_hidden/2**20:.1f}")
        assert on.n_prefetches > 0, \
            f"prefetch plan emitted nothing at host fraction {frac}"
        won += stall_cut > 0
    assert won > 0, "prefetch-on never beat prefetch-off on stall time"
    return rows


if __name__ == "__main__":   # PYTHONPATH=src python -m benchmarks.tiered_offload
    run(quick=True)
