"""Checkpoint fault tolerance: real sharding (size-threshold leaf packing),
per-file digests, and restore falling back to the newest *complete* step
when the latest checkpoint is corrupt or truncated."""
import json
import pathlib
import time

import numpy as np
import pytest

from repro.ckpt.store import (complete_steps, latest_step,
                              restore_checkpoint, save_checkpoint,
                              save_checkpoint_async)


def tree_at(step: int) -> dict:
    return {
        "params": {
            "w0": np.full((64, 64), float(step), np.float32),   # 16 KiB
            "w1": np.full((64, 64), float(step + 1), np.float32),
            "w2": np.full((32,), float(step + 2), np.float32),
        },
        "step": np.int32(step),
    }


def save_small_shards(tmp_path, step):
    """Force multi-shard layout: threshold below one big leaf's bytes."""
    return save_checkpoint(tmp_path, step, tree_at(step),
                           shard_bytes=8 * 1024)


class TestSharding:
    def test_leaves_split_across_shards(self, tmp_path):
        p = save_small_shards(tmp_path, 3)
        shards = sorted(f.name for f in p.glob("shard_*.npz"))
        assert len(shards) >= 3          # two 16 KiB leaves can't share one
        manifest = json.loads((p / "MANIFEST.json").read_text())
        assert set(manifest["files"]) == set(shards)
        assert {l["file"] for l in manifest["leaves"]} == set(shards)
        # per-file digests: every shard is covered
        assert all(len(d) == 64 for d in manifest["files"].values())

    def test_multi_shard_roundtrip(self, tmp_path):
        t = tree_at(5)
        save_small_shards(tmp_path, 5)
        got, step = restore_checkpoint(tmp_path, t)
        assert step == 5
        for a, b in zip(np.asarray(got["params"]["w1"]).ravel(),
                        t["params"]["w1"].ravel()):
            assert a == b
        np.testing.assert_array_equal(got["params"]["w2"],
                                      t["params"]["w2"])

    def test_monolithic_default_still_single_shard(self, tmp_path):
        p = save_checkpoint(tmp_path, 1, tree_at(1))   # default threshold
        assert sorted(f.name for f in p.glob("shard_*.npz")) == \
            ["shard_0.npz"]


def _corrupt(path: pathlib.Path) -> None:
    data = bytearray(path.read_bytes())
    data[len(data) // 2] ^= 0xFF
    path.write_bytes(bytes(data))


class TestFallback:
    def test_corrupt_newest_shard_falls_back(self, tmp_path):
        save_small_shards(tmp_path, 3)
        p9 = save_small_shards(tmp_path, 9)
        _corrupt(next(iter(sorted(p9.glob("shard_*.npz")))))
        assert latest_step(tmp_path) == 9          # manifest still there...
        assert complete_steps(tmp_path) == [3]     # ...but step 9 is broken
        got, step = restore_checkpoint(tmp_path, tree_at(3))
        assert step == 3                           # newest COMPLETE step
        np.testing.assert_array_equal(got["params"]["w0"],
                                      tree_at(3)["params"]["w0"])

    def test_corrupt_manifest_falls_back(self, tmp_path):
        save_small_shards(tmp_path, 2)
        p7 = save_small_shards(tmp_path, 7)
        (p7 / "MANIFEST.json").write_text("{ not json")
        got, step = restore_checkpoint(tmp_path, tree_at(2))
        assert step == 2

    def test_missing_shard_falls_back(self, tmp_path):
        save_small_shards(tmp_path, 4)
        p8 = save_small_shards(tmp_path, 8)
        sorted(p8.glob("shard_*.npz"))[-1].unlink()
        _, step = restore_checkpoint(tmp_path, tree_at(4))
        assert step == 4

    def test_all_corrupt_raises(self, tmp_path):
        p = save_small_shards(tmp_path, 6)
        for shard in p.glob("shard_*.npz"):
            _corrupt(shard)
        with pytest.raises(IOError, match="corruption"):
            restore_checkpoint(tmp_path, tree_at(6))

    def test_explicit_step_never_falls_back(self, tmp_path):
        save_small_shards(tmp_path, 1)
        p5 = save_small_shards(tmp_path, 5)
        _corrupt(next(iter(p5.glob("shard_*.npz"))))
        with pytest.raises(IOError, match="corruption"):
            restore_checkpoint(tmp_path, tree_at(5), step=5)

    def test_shape_mismatch_not_swallowed_by_fallback(self, tmp_path):
        """Structure errors mean the caller asked for the wrong tree —
        falling back to an older step would silently restore stale
        params."""
        save_small_shards(tmp_path, 2)
        save_small_shards(tmp_path, 9)
        bad = tree_at(9)
        bad["params"]["w0"] = np.zeros((3, 3), np.float32)
        with pytest.raises(ValueError):
            restore_checkpoint(tmp_path, bad)


class TestMidWriteCrash:
    """A crash while shards are being written (power loss, OOM-kill,
    raising filesystem) must leave the checkpoint tree exactly as it was:
    no partial step directory, no leaked tmp dir, prior steps restorable."""

    def _crashing_writer(self, monkeypatch, fail_on_call: int):
        import repro.ckpt.store as store_mod
        calls = {"n": 0}
        real = store_mod._write_shard

        def boom(path, arrays):
            calls["n"] += 1
            if calls["n"] == fail_on_call:
                raise OSError("injected: disk died mid-shard-write")
            real(path, arrays)

        monkeypatch.setattr(store_mod, "_write_shard", boom)
        return calls

    def test_crash_mid_write_leaves_no_partial_step(self, tmp_path,
                                                    monkeypatch):
        save_small_shards(tmp_path, 3)
        calls = self._crashing_writer(monkeypatch, fail_on_call=2)
        with pytest.raises(OSError, match="mid-shard-write"):
            save_small_shards(tmp_path, 9)
        # really died partway; pipelined writes already in flight on the
        # disk-tier stream when shard 2 failed may still have run
        assert calls["n"] >= 2
        # nothing published, nothing leaked
        names = sorted(p.name for p in tmp_path.iterdir())
        assert names == ["step_0000000003"]
        # and the tree still restores cleanly
        got, step = restore_checkpoint(tmp_path, tree_at(3))
        assert step == 3
        np.testing.assert_array_equal(got["params"]["w0"],
                                      tree_at(3)["params"]["w0"])

    def test_crash_on_first_shard_of_first_checkpoint(self, tmp_path,
                                                      monkeypatch):
        self._crashing_writer(monkeypatch, fail_on_call=1)
        with pytest.raises(OSError):
            save_small_shards(tmp_path, 1)
        assert list(tmp_path.iterdir()) == []       # pristine directory
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(tmp_path, tree_at(1))

    def test_leftover_tmp_dir_is_invisible(self, tmp_path):
        """A tmp dir orphaned by a hard kill (no exception handler ran)
        must be ignored by discovery and restore."""
        save_small_shards(tmp_path, 4)
        orphan = tmp_path / ".tmp_orphaned"
        orphan.mkdir()
        (orphan / "shard_0.npz").write_bytes(b"garbage")
        assert latest_step(tmp_path) == 4
        assert complete_steps(tmp_path) == [4]
        _, step = restore_checkpoint(tmp_path, tree_at(4))
        assert step == 4


class TestAsyncOverlap:
    """Checkpointing rides the disk-tier stream: the training step loop
    must make progress *while* shard bytes are being written (ROADMAP
    item 5 tail), and the published checkpoint must be byte-identical to
    a blocking save's."""

    def test_step_loop_overlaps_shard_writes(self, tmp_path, monkeypatch):
        import repro.ckpt.store as store_mod
        real = store_mod._write_shard
        windows = []                       # (t_start, t_end) per shard write

        def slow_write(path, arrays):
            t0 = time.perf_counter()
            time.sleep(0.05)               # a slow spindle
            real(path, arrays)
            windows.append((t0, time.perf_counter()))

        monkeypatch.setattr(store_mod, "_write_shard", slow_write)
        pend = save_checkpoint_async(tmp_path, 7, tree_at(7),
                                     shard_bytes=8 * 1024)
        # the "step loop": keep stepping while the save is in flight
        steps = []
        while not pend.done():
            steps.append(time.perf_counter())
            time.sleep(0.002)
        path = pend.result()
        assert path.name == "step_0000000007"
        assert len(windows) >= 3           # multi-shard layout held
        # overlap assertion: some step ran strictly inside a shard-write
        # window — checkpointing did not block the loop
        assert any(a < t < b for t in steps for (a, b) in windows), \
            "no training step overlapped a shard write"
        # and the published bytes are a real, restorable checkpoint
        got, step = restore_checkpoint(tmp_path, tree_at(7))
        assert step == 7
        np.testing.assert_array_equal(got["params"]["w0"],
                                      tree_at(7)["params"]["w0"])

    def test_async_failure_surfaces_and_leaks_nothing(self, tmp_path,
                                                      monkeypatch):
        import repro.ckpt.store as store_mod

        def boom(path, arrays):
            raise OSError("injected: disk died mid-shard-write")

        monkeypatch.setattr(store_mod, "_write_shard", boom)
        pend = save_checkpoint_async(tmp_path, 5, tree_at(5),
                                     shard_bytes=8 * 1024)
        with pytest.raises(OSError, match="mid-shard-write"):
            pend.result(timeout=30)
        # monkeypatch must be undone before other tests reuse the stream
        monkeypatch.undo()
        assert list(tmp_path.iterdir()) == []      # no partial tmp dir

    def test_blocking_save_pipelined_writes_stay_ordered(self, tmp_path,
                                                         monkeypatch):
        """The blocking path now routes shard writes through the same
        stream; the manifest/digest contract is unchanged."""
        import repro.ckpt.store as store_mod
        seen = []
        real = store_mod._write_shard

        def record(path, arrays):
            seen.append(path.name)
            real(path, arrays)

        monkeypatch.setattr(store_mod, "_write_shard", record)
        save_small_shards(tmp_path, 2)
        assert seen == sorted(seen)        # shard_0, shard_1, ... in order
        assert len(seen) >= 3
        got, step = restore_checkpoint(tmp_path, tree_at(2))
        assert step == 2
