"""The storage hierarchy behind the MEMGRAPH runtime and the serving engine.

TURNIP's premise is that "inexpensive CPU RAM is used to increase the amount
of storage available" — but CPU RAM is itself finite, and online serving
workloads (NEO, PAPERS.md) hit the host-RAM ceiling first. This module
models the full hierarchy::

    device HBM  --d2h/h2d-->  host RAM (pinned arena)  --disk I/O-->  disk

* :class:`HostStore` — the unbounded pinned host arena (paper §B
  ``cudaHostAlloc``): graph inputs + offloaded tensors, with traffic,
  occupancy, and peak counters.
* :class:`DiskStore` — the next rung: a file-backed blob store (one
  append-only ``spill.log``; framed records, in-memory index) with its
  own traffic/occupancy/peak counters and an optional byte ``capacity``.
  Disk is the *last* tier: there is nowhere further to evict, so an
  admission that would overflow the capacity is **refused** with a typed
  :class:`DiskFullError` rather than silently growing (the compile-time
  feasibility check in ``build.py`` makes this unreachable for compiled
  plans; serving and standalone users get the prompt error instead of an
  unbounded tier). A record that has been torn or bit-rotted raises
  :class:`DiskCorruptionError` — promptly, on the disk stream, never a
  hang.
* :class:`TieredStore` — a :class:`HostStore` whose offload arena is
  capacity-bounded and backed by a :class:`DiskStore`. Victims can be
  chosen two ways, matching the compiler/runtime split:

  - **plan-driven** (the MEMGRAPH path): ``host_capacity=None`` and the
    compiled plan's SPILL/LOAD vertices call :meth:`spill`/:meth:`load`
    explicitly — the compiler already chose victims Belady-optimally over
    the serialized schedule (``build.py``);
  - **auto-LRU** (the serving path, or standalone use): ``host_capacity``
    set and ``auto_spill=True`` spills the least-recently-touched keys on
    overflow — at runtime the future is unknown, so recency is the best
    available signal. The serving engine instead sets ``auto_spill=False``
    and drives spills through a dedicated disk DMA stream so the I/O cost
    lands on a timeline, not inside ``put_offload``.

Tier choice must never change results, only timing: :meth:`get_offload`
reads *through* to disk, so a value is always recoverable no matter which
tier currently holds its bytes.
"""
from __future__ import annotations

import os
import pathlib
import shutil
import struct
import tempfile
from typing import Any

import numpy as np

from . import lockcheck

__all__ = ["HostStore", "DiskStore", "TieredStore", "DiskFullError",
           "DiskCorruptionError"]


class DiskFullError(RuntimeError):
    """An admission would exceed the disk tier's capacity. Disk is the last
    rung of the hierarchy — there is no further tier to evict to — so the
    write is refused instead of silently overflowing the budget."""


class DiskCorruptionError(IOError):
    """A spilled blob's backing file is missing or unreadable (truncated,
    deleted, bit-rotted). Raised promptly by :meth:`DiskStore.get` so a
    disk-stream LOAD fails fast instead of wedging its consumers."""


def _nbytes(value) -> int:
    """Total bytes of an ndarray or a flat dict of ndarrays (a KV block)."""
    if isinstance(value, dict):
        return sum(v.nbytes for v in value.values())
    return value.nbytes


class HostStore:
    """Host (CPU-RAM) storage: graph inputs + offloaded tensors.

    Keys are opaque hashables: the MEMGRAPH runtime offloads under its
    OFFLOAD vertex mids, and the serving engine (:mod:`repro.serve`) uses
    the same arena class with ``(request, block)`` keys (pass one store to
    both to share a single pinned pool and traffic counters).
    ``offload_bytes``/``reload_bytes`` count cumulative d2h/h2d traffic;
    ``resident_bytes`` is current occupancy and ``peak_resident_bytes``
    its high-water mark."""

    def __init__(self, inputs: dict[int, np.ndarray]) -> None:
        self.inputs = {t: np.asarray(v) for t, v in inputs.items()}
        self.offloaded: dict[Any, Any] = {}
        self.offload_bytes = 0
        self.reload_bytes = 0
        self.resident_bytes = 0
        self.peak_resident_bytes = 0
        # lock class = concrete type: TieredStore code paths hold this
        # lock around DiskStore and HostPool calls, and the lock-order
        # sanitizer (lockcheck.py) checks those pairs stay acyclic
        self._lock = lockcheck.make_lock(type(self).__name__)

    # subclass hooks (no-ops here) -------------------------------------
    def _touch(self, key) -> None:
        """Record a use of ``key`` for recency-based victim choice."""

    def _admit_locked(self, key, *, fresh: bool = True) -> None:
        """Called (lock held) after ``key`` lands in the host arena.
        ``fresh`` distinguishes a new write (which supersedes any older
        copy on a lower tier) from a disk→host staging (whose disk copy
        stays authoritative)."""

    def _account_locked(self, delta: int) -> None:
        """Called (lock held) on every ``resident_bytes`` change — the
        seam a pool :class:`~repro.core.pool.Lease` mirrors occupancy
        through."""

    def put_offload(self, key, value) -> None:
        """Store an offloaded tensor (or flat dict of tensors — a serving
        KV block) under ``key``; counts d2h traffic + occupancy."""
        n = _nbytes(value)
        with self._lock:
            prev = self.offloaded.get(key)
            prev_n = _nbytes(prev) if prev is not None else 0
            self.offloaded[key] = value
            self.offload_bytes += n
            self.resident_bytes += n - prev_n
            self.peak_resident_bytes = max(self.peak_resident_bytes,
                                           self.resident_bytes)
            self._account_locked(n - prev_n)
            self._admit_locked(key)

    def get_offload(self, key):
        """Fetch an offloaded value for reload; counts h2d traffic."""
        with self._lock:
            val = self.offloaded[key]
            self.reload_bytes += _nbytes(val)
            self._touch(key)
        return val

    def pop_offload(self, key) -> None:
        """Free a host copy (no traffic: dead data is simply released)."""
        with self._lock:
            val = self.offloaded.pop(key, None)
            if val is not None:
                self.resident_bytes -= _nbytes(val)
                self._account_locked(-_nbytes(val))

    def peek_offload(self, key):
        """Read a value without counting traffic (final-output collection).
        Returns ``None`` when no copy exists on any tier."""
        with self._lock:
            return self.offloaded.get(key)

    def tier_of(self, key) -> str | None:
        """Which tier currently holds ``key``'s bytes (``None`` = nowhere)."""
        with self._lock:
            return "host" if key in self.offloaded else None

    def get_for_reload(self, v) -> np.ndarray:
        """RELOAD vertex read: the offloaded copy (operands[0] is the host
        key) or the immutable input store."""
        if v.operands:
            return self.get_offload(v.operands[0])
        with self._lock:
            val = self.inputs[v.src_tid]       # immutable input store
            self.reload_bytes += val.nbytes
        return val

    def close(self) -> None:
        """Release any backing resources (no-op for a pure host store)."""


class DiskStore:
    """File-backed blob store — the disk tier of the hierarchy.

    All blobs live in a single append-only log (``spill.log`` under
    ``directory``, a private temp dir by default, removed on
    :meth:`close`). A file per key would pay an open/create/close
    round-trip (~150 us of syscalls) on every spill — two orders of
    magnitude more than the write itself for KB-scale tensors — so the
    store keeps one write handle open and appends framed records: a
    12-byte header (magic + payload length) followed by the raw array
    bytes. Reads are positioned ``pread`` calls on a second handle; the
    frame turns truncation or bit-rot into a prompt
    :class:`DiskCorruptionError` instead of garbage bytes. Values are
    ndarrays or flat dicts of ndarrays (serving KV blocks); dtype/shape
    live in the in-memory index — the log holds bytes only, so nothing
    about a record can be recovered without its index entry and the
    store is scoped to one process lifetime, exactly like the device
    arena it backs.

    ``write_bytes``/``read_bytes`` count cumulative spill/load traffic;
    ``resident_bytes``/``peak_resident_bytes`` track *live* occupancy.
    :meth:`drop` retires a record logically (the capacity check frees
    its bytes immediately). The physical log space of retired records is
    reclaimed by **compaction**: when dead bytes dominate the log
    (``compact_dead_fraction`` of the file, once it exceeds
    ``compact_min_bytes``), the live records are streamed into a fresh
    log which atomically replaces the old one (``os.replace``), under
    the same store lock every mutation already holds. A crash at any
    instant leaves either the complete old log or the complete new one —
    never a torn mixture — and in-flight readers holding the old read
    handle retry against the new index (a generation counter guards the
    swap). ``capacity`` (bytes, ``None`` = unbounded) makes :meth:`put`
    refuse admissions that would overflow the tier with a
    :class:`DiskFullError` — overwriting an existing key only charges
    the delta."""

    _ARR = "__arr__"              # spec field name for a bare-ndarray value
    _MAGIC = b"TNIP"
    _HDR = struct.Struct("<4sQ")  # record frame: magic, payload nbytes

    def __init__(self, directory: str | os.PathLike | None = None, *,
                 capacity: int | None = None,
                 compact_dead_fraction: float | None = 0.5,
                 compact_min_bytes: int = 1 << 20) -> None:
        self._dir = pathlib.Path(directory) if directory is not None else None
        self._owns_dir = directory is None
        self.capacity = capacity
        # compaction knobs: rewrite the log once dead bytes exceed this
        # fraction of the file (None disables), but never bother below
        # the size floor (small logs are cheaper to leave alone)
        self.compact_dead_fraction = compact_dead_fraction
        self.compact_min_bytes = compact_min_bytes
        # key -> (log offset, payload nbytes, ((name, dtype, shape, nb), ...))
        self._files: dict[Any, tuple[int, int, tuple]] = {}
        self._log_path: pathlib.Path | None = None
        self._wfd: int | None = None
        self._rfd: int | None = None
        self._end = 0                 # next append offset
        self.write_bytes = 0
        self.read_bytes = 0
        self.resident_bytes = 0
        self.peak_resident_bytes = 0
        # dead (retired-record) bytes currently wasting log space,
        # frame headers included — what compaction reclaims
        self.dead_bytes = 0
        self.n_compactions = 0
        self.compacted_reclaimed_bytes = 0
        # bumped on every log rewrite: readers that resolved an index
        # entry against an older generation retry their read
        self._gen = 0
        # read handles retired by compaction: a reader may be mid-pread
        # on one, so they stay open until close()
        self._retired_fds: list[int] = []
        self._lock = lockcheck.make_lock("DiskStore")

    def _root(self) -> pathlib.Path:
        if self._dir is None:
            self._dir = pathlib.Path(tempfile.mkdtemp(prefix="turnip-disk-"))
        else:
            self._dir.mkdir(parents=True, exist_ok=True)
        return self._dir

    def _open_log(self) -> None:
        """Open (or reopen after :meth:`close`) the log pair: an append
        write handle and a positioned-read handle. Call with the lock."""
        if self._wfd is None:
            path = self._root() / "spill.log"
            self._log_path = path
            self._wfd = os.open(str(path),
                                os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
            self._rfd = os.open(str(path), os.O_RDONLY)
            self._end = os.fstat(self._wfd).st_size

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._files

    def put(self, key, value) -> int:
        """Append ``key``'s bytes to the log; returns the payload size.
        Raises :class:`DiskFullError` when a ``capacity`` is set and
        admitting the bytes would overflow it (the write is refused,
        nothing changes). A re-put appends a fresh record and retires
        the old one — records are immutable once written, which is what
        makes the lock-free positioned reads in :meth:`get` safe."""
        payload = value if isinstance(value, dict) else {self._ARR: value}
        arrays = {k: np.ascontiguousarray(np.asarray(v))
                  for k, v in payload.items()}
        spec = tuple((k, a.dtype.str, a.shape, a.nbytes)
                     for k, a in arrays.items())
        blob = b"".join(a.tobytes() for a in arrays.values())
        n = len(blob)
        rec = self._HDR.pack(self._MAGIC, n) + blob
        with self._lock:
            prev_entry = self._files.get(key)
            prev = prev_entry[1] if prev_entry is not None else 0
            if (self.capacity is not None
                    and self.resident_bytes - prev + n > self.capacity):
                raise DiskFullError(
                    f"disk tier full: {n} B for {key!r} would push occupancy "
                    f"{self.resident_bytes - prev} B past capacity "
                    f"{self.capacity} B")
            self._open_log()
            assert self._wfd is not None
            off = self._end
            os.write(self._wfd, rec)
            self._end = off + len(rec)
            self._files[key] = (off, n, spec)
            self.write_bytes += n
            self.resident_bytes += n - prev
            self.peak_resident_bytes = max(self.peak_resident_bytes,
                                           self.resident_bytes)
            if prev_entry is not None:   # the old record is now dead space
                self.dead_bytes += self._HDR.size + prev
                self._maybe_compact_locked()
        return n

    def _read_blob(self, entry: tuple[int, int, tuple]):
        """The raw positioned read + frame check (a test seam for
        fault/race injection)."""
        off, n, spec = entry
        rfd = self._rfd
        if rfd is None:
            raise ValueError("spill log is not open")
        hdr = os.pread(rfd, self._HDR.size, off)
        if len(hdr) != self._HDR.size:
            raise ValueError("torn record header")
        magic, length = self._HDR.unpack(hdr)
        if magic != self._MAGIC or length != n:
            raise ValueError("bad record frame")
        buf = os.pread(rfd, n, off + self._HDR.size)
        if len(buf) != n:
            raise ValueError("torn record payload")
        out = {}
        at = 0
        for name, dt, shape, nb in spec:
            count = nb // np.dtype(dt).itemsize
            out[name] = np.frombuffer(buf, dtype=dt, offset=at,
                                      count=count).reshape(shape).copy()
            at += nb
        if set(out) == {self._ARR}:
            return out[self._ARR]
        return out

    def get(self, key, *, count: bool = True):
        """Read ``key``'s blob back. An unknown key raises ``KeyError``; a
        known key whose log record is torn or unreadable raises
        :class:`DiskCorruptionError` immediately (fail fast on the disk
        stream — a LOAD must never hang its consumers on rotten bytes).

        The index entry is resolved under the lock but the record is
        read outside it (so slow I/O never serializes the tier). Records
        are immutable, so a concurrent re-put cannot tear the read — but
        a concurrent :meth:`drop` retires the entry mid-read. That is a
        healthy, legitimately-freed key — not corruption — so the read
        re-checks the entry afterwards and raises ``KeyError`` for the
        dropped-key case instead of returning retired bytes. A
        concurrent *compaction* instead moves the live record to a new
        offset in a rewritten log; the generation counter detects that
        and the read retries against the new index — even when the
        stale-offset read happened to return frame-valid bytes, which
        after a rewrite could be the wrong record's."""
        while True:
            with self._lock:
                entry = self._files[key]
                gen = self._gen
                if count:
                    self.read_bytes += entry[1]
                    count = False      # one logical load, however many tries
            try:
                val = self._read_blob(entry)
            except (OSError, EOFError, ValueError) as e:
                with self._lock:
                    cur = self._files.get(key)
                    cur_gen = self._gen
                if cur_gen != gen:
                    continue           # log rewritten mid-read: retry
                if cur is None or cur[0] != entry[0]:
                    # drop/get race: the key was freed (or freed and
                    # re-put — a re-put always appends at a fresh offset)
                    # while we read the old record. The caller raced a
                    # legitimate release; the tier is healthy: a stale
                    # lookup, not corruption.
                    raise KeyError(key) from None
                raise DiskCorruptionError(
                    f"spill record for {key!r} torn or corrupt at "
                    f"{self._log_path}+{entry[0]}: {e}") from e
            with self._lock:
                cur = self._files.get(key)
                cur_gen = self._gen
            if cur_gen != gen:
                continue               # log rewritten mid-read: retry
            if cur is None or cur[0] != entry[0]:
                raise KeyError(key)
            return val

    def drop(self, key) -> None:
        with self._lock:
            entry = self._files.pop(key, None)
            if entry is None:
                return
            self.resident_bytes -= entry[1]
            self.dead_bytes += self._HDR.size + entry[1]
            self._maybe_compact_locked()

    # ---- log compaction ----------------------------------------------
    def _maybe_compact_locked(self) -> None:
        """Lock held. Kick a compaction when dead bytes dominate the log.
        Compaction is an *optimization*: any failure (I/O error, a torn
        record in a log region we were about to discard anyway) leaves
        the store fully functional on the old log, so errors are
        swallowed here — the put/drop that triggered the pass must not
        fail for it."""
        if (self._wfd is None or self.compact_dead_fraction is None
                or self._end < self.compact_min_bytes
                or self.dead_bytes <
                self.compact_dead_fraction * self._end):
            return
        try:
            self._compact_locked()
        except (OSError, ValueError):
            pass

    def _publish_compaction(self, tmp: pathlib.Path,
                            path: pathlib.Path) -> None:
        """The commit point: atomically swap the rewritten log into
        place. A crash strictly before leaves the old log intact (plus a
        stray tmp file); strictly after, the new log is complete and
        fsynced. Split out as a fault-injection seam for the
        crash-during-compaction tests."""
        os.replace(tmp, path)

    def _compact_locked(self) -> None:
        """Lock held. Stream the live records into a fresh log, fsync,
        atomically publish, and swap the in-memory index to the new
        offsets. The old read handle is retired, not closed: a
        concurrent :meth:`get` may be mid-``pread`` on it (it will see
        intact old-log bytes, notice the generation bump, and retry
        against the new index)."""
        assert self._log_path is not None and self._rfd is not None \
            and self._wfd is not None
        old_rfd, old_wfd, old_end = self._rfd, self._wfd, self._end
        tmp = self._log_path.with_name(self._log_path.name + ".compact")
        entries = sorted(self._files.items(), key=lambda kv: kv[1][0])
        tfd: int | None = os.open(str(tmp),
                                  os.O_WRONLY | os.O_CREAT | os.O_TRUNC,
                                  0o644)
        try:
            new_files: dict[Any, tuple[int, int, tuple]] = {}
            at = 0
            for key, (off, n, spec) in entries:
                hdr = os.pread(old_rfd, self._HDR.size, off)
                if len(hdr) != self._HDR.size:
                    raise ValueError("torn record header")
                magic, length = self._HDR.unpack(hdr)
                if magic != self._MAGIC or length != n:
                    raise ValueError("bad record frame")
                buf = os.pread(old_rfd, n, off + self._HDR.size)
                if len(buf) != n:
                    raise ValueError("torn record payload")
                os.write(tfd, hdr + buf)
                new_files[key] = (at, n, spec)
                at += self._HDR.size + n
            os.fsync(tfd)
            os.close(tfd)
            tfd = None
            self._publish_compaction(tmp, self._log_path)
        except BaseException:
            # abort: the old log (and every handle on it) is untouched
            if tfd is not None:
                os.close(tfd)
            tmp.unlink(missing_ok=True)
            raise
        # committed on disk — swap handles and index. The old fds keep
        # the pre-replace inode alive for any mid-read concurrent get.
        self._wfd = os.open(str(self._log_path),
                            os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            self._rfd = os.open(str(self._log_path), os.O_RDONLY)
        except BaseException:
            os.close(self._wfd)
            self._wfd, self._rfd = old_wfd, old_rfd
            raise
        self._retired_fds += [old_rfd, old_wfd]
        self._files = new_files
        self._end = at
        self.dead_bytes = 0
        self._gen += 1
        self.n_compactions += 1
        self.compacted_reclaimed_bytes += old_end - at

    def close(self) -> None:
        with self._lock:
            self._files.clear()
            self.resident_bytes = 0
            self.dead_bytes = 0
            for fd in (self._wfd, self._rfd, *self._retired_fds):
                if fd is not None:
                    os.close(fd)
            self._wfd = self._rfd = None
            self._retired_fds = []
            self._end = 0
            d, self._dir = self._dir, None
        if d is not None and self._owns_dir:
            shutil.rmtree(d, ignore_errors=True)


class TieredStore(HostStore):
    """Capacity-bounded host tier backed by a disk tier.

    The host arena keeps :class:`HostStore` semantics (and counters); on
    top of it:

    * :meth:`spill` moves a key's bytes host→disk (a no-op write when an
      immutable disk copy already exists — the disk analogue of
      ``reuse_host_copy``), or drops them entirely for dead data;
    * :meth:`load` stages a disk copy back into host RAM (the first hop of
      a ``disk→host→device`` reload chain);
    * :meth:`get_offload` reads through: if only the disk copy exists, it
      is loaded (and its I/O counted) transparently — a racy or
      plan-driven order can therefore never change results, only timing;
    * with ``auto_spill=True`` (standalone use), :meth:`put_offload`
      evicts least-recently-touched keys once ``host_capacity`` would be
      exceeded — the runtime-LRU complement of the compiler's
      Belady-over-the-schedule victim choice.

    Eviction refusal: when the backing :class:`DiskStore` has a
    ``capacity`` and is full, a spill (auto-LRU or plan-driven) surfaces
    the tier's :class:`DiskFullError` to the caller with the hierarchy
    rolled back to its prior state — the victim keeps its host copy, a
    refused :meth:`put_offload` admission is undone — so the tiers never
    silently exceed either budget and no data is ever lost to a refusal.
    """

    def __init__(self, inputs: dict[int, np.ndarray], *,
                 host_capacity: int | None = None,
                 disk: DiskStore | None = None,
                 directory: str | os.PathLike | None = None,
                 disk_capacity: int | None = None,
                 auto_spill: bool = True,
                 lease: Any = None) -> None:
        super().__init__(inputs)
        self.host_capacity = host_capacity
        self.disk = (disk if disk is not None
                     else DiskStore(directory, capacity=disk_capacity))
        self._owns_disk = disk is None
        self.auto_spill = auto_spill
        # a pool Lease (repro.core.pool): occupancy deltas are mirrored
        # into it, and — for auto-LRU stores — the *dynamic* grant is the
        # effective host bound, so an arbiter revoking slack makes the
        # next admission spill down without any inline write on the
        # revoker's thread
        self.lease = lease
        # liveness assumption A1's disk face (DESIGN.md §14): when the
        # owning runtime stamped the plan liveness-certified, every spill
        # was statically proven creditable, so a DiskFullError here means
        # the certifier is unsound — escalate instead of refusing
        self.certified_live = False
        self._lru: dict[Any, int] = {}       # key -> last-touch counter
        self._tick = 0

    # ------------------------------------------------------------- hooks
    def _touch(self, key) -> None:
        self._tick += 1
        self._lru[key] = self._tick

    def _host_limit(self) -> int | None:
        """The effective host bound: the lease's arbitrated grant when the
        store belongs to a pool, else the static ``host_capacity``."""
        if self.lease is not None:
            return self.lease.grant
        return self.host_capacity

    def _account_locked(self, delta: int) -> None:
        if self.lease is not None:
            self.lease.account(delta)

    def _admit_locked(self, key, *, fresh: bool = True) -> None:
        self._touch(key)
        if self.auto_spill and self._host_limit() is not None:
            try:
                # the limit is re-read per victim: under a lease it is the
                # *dynamic* arbitrated grant, and each spill's accounting
                # can move it (a demand arbiter re-splits as our occupancy
                # drops)
                while (self.resident_bytes > (self._host_limit() or 0)
                       and len(self.offloaded) > 1):
                    victim = min((k for k in self.offloaded if k != key),
                                 key=lambda k: self._lru.get(k, 0),
                                 default=None)
                    if victim is None:
                        break
                    self._spill_locked(victim)
            except DiskFullError:
                # the cascaded spill could not make room: refuse the
                # admission itself, or the host tier would exceed the
                # bound by one refused value per retry. The victim's bytes
                # were already restored by _spill_locked; dropping the
                # admitted key returns the hierarchy to its pre-put state
                # before the error surfaces — including the key's old disk
                # twin, which is only superseded below once the admission
                # stands (a refusal must never lose the last copy).
                val = self.offloaded.pop(key, None)
                if val is not None:
                    self.resident_bytes -= _nbytes(val)
                    self._account_locked(-_nbytes(val))
                self._lru.pop(key, None)
                if fresh:
                    raise
                # staged admission (disk→host load): the disk copy is
                # still authoritative, so nothing is lost — the read is
                # served without admitting the bytes, and no error
                # surfaces for a read that used to succeed
                return
        if fresh:
            # the admitted write supersedes any disk twin: the blob holds
            # the *old* bytes, and leaving it would make a later spill
            # dedup ("immutable disk copy already exists") resurrect them
            # on read-through — silent data corruption
            self.disk.drop(key)

    # ------------------------------------------------------------- tiers
    def _spill_locked(self, key, *, drop: bool = False) -> int:
        val = self.offloaded.pop(key, None)
        if val is not None:
            self.resident_bytes -= _nbytes(val)
            self._account_locked(-_nbytes(val))
        self._lru.pop(key, None)
        if drop:
            self.disk.drop(key)
            return 0
        if val is not None and key not in self.disk:
            try:
                return self.disk.put(key, val)
            except DiskFullError:
                # refusal must not lose data: the bytes' only copy goes
                # back where it was, and the typed error surfaces to the
                # caller with the hierarchy unchanged
                self.offloaded[key] = val
                self.resident_bytes += _nbytes(val)
                self._account_locked(_nbytes(val))
                self._touch(key)
                raise
        return 0

    def spill(self, key, *, drop: bool = False) -> int:
        """Evict ``key``'s bytes from the host arena; returns the bytes
        actually written to disk. ``drop=True`` means the data is dead:
        release every copy without any disk write. When an immutable disk
        copy already exists the host bytes are simply released (no second
        write, 0 returned). No-op (0) when the key is not host-resident."""
        try:
            with self._lock:
                return self._spill_locked(key, drop=drop)
        except DiskFullError as e:
            if self.certified_live:
                from .liveness import LivenessModelError
                raise LivenessModelError(
                    f"{e} [plan was liveness-certified: every disk "
                    f"admission was proven creditable in all orders, so "
                    f"this refusal means the certifier is unsound or the "
                    f"runtime diverged from the plan — DESIGN.md §14]"
                ) from e
            raise

    def load(self, key):
        """Stage ``key``'s disk copy back into host RAM (disk-read traffic
        counted; the disk copy stays valid). Idempotent when the bytes are
        already host-resident.

        Staging is an *admission*: it runs through the same eviction path
        as :meth:`put_offload` (``fresh=False`` — the disk twin stays
        authoritative), so a burst of read-throughs under ``auto_spill``
        evicts LRU victims instead of silently pushing ``resident_bytes``
        past the host bound. If eviction cannot make room (disk full),
        the bytes are served without being admitted — the read succeeds
        and the budget holds."""
        with self._lock:
            if key in self.offloaded:
                self._touch(key)
                return self.offloaded[key]
        val = self.disk.get(key)
        with self._lock:
            if key not in self.offloaded:
                self.offloaded[key] = val
                self.resident_bytes += _nbytes(val)
                self.peak_resident_bytes = max(self.peak_resident_bytes,
                                               self.resident_bytes)
                self._account_locked(_nbytes(val))
                self._admit_locked(key, fresh=False)
            else:
                self._touch(key)
            return self.offloaded.get(key, val)

    # --------------------------------------------------- HostStore surface
    def get_offload(self, key):
        with self._lock:
            val = self.offloaded.get(key)
            if val is not None:
                self.reload_bytes += _nbytes(val)
                self._touch(key)
                return val
        # read-through: two-hop reload (disk→host staging, then h2d)
        val = self.load(key)
        with self._lock:
            self.reload_bytes += _nbytes(val)
        return val

    def pop_offload(self, key) -> None:
        super().pop_offload(key)
        with self._lock:
            self._lru.pop(key, None)
        self.disk.drop(key)

    def peek_offload(self, key):
        with self._lock:
            if key in self.offloaded:
                return self.offloaded[key]
        if key in self.disk:
            try:
                return self.disk.get(key, count=False)
            except KeyError:        # dropped between the check and the read
                return None
        return None

    def tier_of(self, key) -> str | None:
        with self._lock:
            if key in self.offloaded:
                return "host"
        return "disk" if key in self.disk else None

    def lru_keys(self) -> list:
        """Host-resident keys, least-recently-touched first — the serving
        engine's spill-candidate order."""
        with self._lock:
            return sorted(self.offloaded, key=lambda k: self._lru.get(k, 0))

    def close(self) -> None:
        if self.lease is not None:
            # the arena is being released: give the pool its bytes back
            # even if values are still readable by a holder of the store
            with self._lock:
                self._account_locked(-self.resident_bytes)
                self.lease = None
        if self._owns_disk:
            self.disk.close()
