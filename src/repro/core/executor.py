"""The unified executor core (DESIGN.md §17; ROADMAP item 5).

One scheduling kernel, three interchangeable backends. TURNIP's thesis is
that the runtime keeps *order freedom* exactly where transfer timing is
unknowable — but freedom must not be priced in OS wakeups: a 36-vertex
nondet seam should not pay a thread fleet's condition-variable round
trips when one caller thread could schedule the whole window. So the
scheduling state machine (ready sets, dependency counts, the
:class:`~repro.core.dispatch.DispatchPolicy` choice among simultaneously
ready vertices) lives in ONE place — :class:`ReadyKernel` — and the
*threading model* is chosen per region, the way dispatch policies are
already chosen per plan:

* :class:`StaticExecutor` — the straight-line walker for certified
  STATIC regions of a :class:`~repro.core.compile.CompiledPlan`: no heap,
  no locks; ``ready_tick <= pos`` was proved at lowering time, so
  position order *is* dependency order (DESIGN.md §15).
* :class:`ThreadedExecutor` — the persistent engine-stream worker fleet
  for large nondet windows: real threads per (device, engine-class)
  stream, condition-variable wakeups on completion events — the paper's
  event-driven runtime.
* :class:`InlineExecutor` — a thread-free ready-heap executor for small
  nondet seams: the same kernel, the same policy choice among ready
  vertices, the same RaceError/tier semantics, scheduled entirely on the
  calling thread. Completion events are drained by non-blocking polls of
  the kernel (in this CPU-model runtime an op's completion is its
  return, so ``complete()`` *is* the drained event queue) — zero thread
  wakeups, zero lock round-trips. Soundness of running a seam on the
  caller is a *certified* property (``liveness.inline_seam_certified``,
  §14/§17): the compiler only stamps a region ``inline`` when no vertex
  in it can block the calling thread on a pool/disk admission.

Nondeterminism semantics are unchanged end-to-end: any backend executes
some dependency-respecting order the policy could have chosen, and the
plan certifier (§13) proved every such order byte-exact.

The kernel itself is not locked: the inline executor drives it from one
thread, and the threaded executor wraps every kernel call in its
scheduler lock (a :func:`lockcheck.make_lock` sanitized lock, so the
lock-order sanitizer audits it with the store/pool locks).

:func:`select_best` is the kernel's dispatch primitive — "among the
simultaneously-ready candidates, take the policy minimum" — shared with
the serving engine's DMA streams (``serve/engine.py``): a serve reload
policy's pop-time choice among pending transfers routes through the same
primitive as a MEMGRAPH seam's choice among ready vertices.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Any, Callable, Sequence, TypeVar

import numpy as np

from . import lockcheck
from .dispatch import COMPUTE, DispatchPolicy, engine_of
from .memgraph import MemGraph, MemOp, MemVertex
from .ops import get_op
from .stores import HostStore
from .taskgraph import TaskGraph

__all__ = ["ExecContext", "ReadyKernel", "InlineExecutor",
           "ThreadedExecutor", "StaticExecutor", "select_best",
           "run_vertex", "INLINE", "THREADED"]

# nondet-region backend hints (compile.Region.backend / RunResult counters)
INLINE = "inline"
THREADED = "threaded"

_T = TypeVar("_T")


def select_best(candidates: Sequence[_T],
                rank: Callable[[_T], Any]) -> int:
    """The kernel's dispatch choice, as a primitive: the index of the
    minimum-``rank`` candidate among the simultaneously-ready set.
    ``rank`` is evaluated at pop time, so callers with *dynamic*
    priorities (the serving engine's reload policies) share the exact
    selection rule the static-priority heaps implement."""
    best = 0
    best_rank = rank(candidates[0])
    for i in range(1, len(candidates)):
        r = rank(candidates[i])
        if r < best_rank:
            best, best_rank = i, r
    return best


# --------------------------------------------------------------------------
# vertex execution (shared by every backend and the reference interpreter)
# --------------------------------------------------------------------------
def _exec_vertex(v: MemVertex, mg: MemGraph, tg: TaskGraph, mem: Any,
                 host: HostStore) -> None:
    if v.op == MemOp.INPUT:
        mem.write(v.loc, host.inputs[v.src_tid])
    elif v.op in (MemOp.COMPUTE, MemOp.TRANSFER):
        vals = [mem.read(mg.vertices[m].loc) for m in v.operands]
        fn = get_op(v.op_name or ("copy" if v.op == MemOp.TRANSFER else ""))
        out = fn(*vals, **v.params)
        mem.write(v.loc, np.asarray(out))
    elif v.op == MemOp.OFFLOAD:
        val = mem.read(mg.vertices[v.operands[0]].loc)
        host.put_offload(v.mid, np.array(val, copy=True))
    elif v.op == MemOp.RELOAD:
        mem.write(v.loc, host.get_for_reload(v))
    elif v.op == MemOp.SPILL:
        # second hop of a tiered eviction (host→disk) — or a free release
        # of dead bytes. operands[0] is the host-store key.
        host.spill(v.operands[0], drop=bool(v.params.get("drop")))
    elif v.op == MemOp.LOAD:
        host.load(v.operands[0])   # first hop of a two-hop reload
    elif v.op == MemOp.ALLOC0:
        spec = tg.vertices[v.src_tid].out
        mem.write(v.loc, np.zeros(spec.shape, spec.np_dtype))
    elif v.op == MemOp.ADD_INTO:
        acc = mem.read(v.loc)
        val = mem.read(mg.vertices[v.operands[0]].loc)
        mem.write(v.loc, acc + val)
    elif v.op == MemOp.JOIN:
        pass  # completion marker: the accumulator already holds the value
    else:  # pragma: no cover
        raise AssertionError(f"unknown op {v.op}")


@dataclasses.dataclass
class ExecContext:
    """Everything a backend needs to execute vertices of one run: the
    plan's graphs, the shared memory/store tiers, the dispatch policy,
    and the run-wide timeline/span accumulators. One context is shared by
    every backend of a run, so ByteArena extents, TieredStore tier moves,
    and HostPool lease accounting are exactly the invariants the
    certifiers assumed — regardless of which backend touches them."""

    mg: MemGraph
    tg: TaskGraph
    mem: Any
    host: HostStore
    policy: DispatchPolicy
    mode: str                                    # "nondet" | "fixed"
    latency: Callable[[MemVertex], float] | None
    timeline: list[tuple[float, float, int, str, str]]
    spans: dict[int, tuple[float, float]]
    t0: float
    # §B write-protected sum-into: one lock per ADD_INTO lock group
    locks: dict[tuple[int, int], threading.Lock] = dataclasses.field(
        default_factory=dict)

    @staticmethod
    def make(mg: MemGraph, tg: TaskGraph, mem: Any, host: HostStore,
             policy: DispatchPolicy, mode: str,
             latency: Callable[[MemVertex], float] | None,
             t0: float, members: Sequence[int]) -> "ExecContext":
        locks: dict[tuple[int, int], threading.Lock] = {}
        for m in members:
            g = mg.vertices[m].lock_group
            if g is not None:
                locks.setdefault(g, threading.Lock())
        return ExecContext(mg=mg, tg=tg, mem=mem, host=host, policy=policy,
                           mode=mode, latency=latency, timeline=[],
                           spans={}, t0=t0, locks=locks)


def run_vertex(ctx: ExecContext, m: int) -> None:
    """Execute one vertex with the run's latency model and ADD_INTO lock
    discipline, recording its timeline interval. Shared by the inline and
    threaded backends (the straight-line walker inlines its own cheaper
    variant: regions execute strictly sequentially, so no lock-group lock
    is ever needed there)."""
    v = ctx.mg.vertices[m]
    t_start = time.perf_counter() - ctx.t0
    if ctx.latency is not None:
        d = ctx.latency(v)
        if d > 0:
            time.sleep(d)
    lk = ctx.locks.get(v.lock_group) if v.lock_group is not None else None
    if lk is not None and v.op == MemOp.ADD_INTO:
        with lk:   # §B: write-protected sum-into
            _exec_vertex(v, ctx.mg, ctx.tg, ctx.mem, ctx.host)
    else:
        _exec_vertex(v, ctx.mg, ctx.tg, ctx.mem, ctx.host)
    t_end = time.perf_counter() - ctx.t0
    ctx.timeline.append((t_start, t_end, v.device, engine_of(v),
                         v.name or str(m)))
    ctx.spans[m] = (t_start, t_end)


# --------------------------------------------------------------------------
# the shared scheduling kernel
# --------------------------------------------------------------------------
class ReadyKernel:
    """The ready-set/dispatch state machine every backend schedules with.

    State: per-vertex remaining-dependency counts, one priority heap per
    (device, engine-class) key ordered by ``(policy.priority, seq, mid)``,
    and — in ``mode='fixed'`` — the strict compile-time issue order with
    head-of-line blocking. The kernel carries NO locking: the inline
    executor drives it from a single thread; the threaded executor holds
    its scheduler lock around every call.

    A job is any subset of ``members``; predecessors outside the job are
    treated as already complete (sound for the compiled backend: the
    linearization is topological, so cross-region deps point backward).
    """

    def __init__(self, mg: MemGraph, members: Sequence[int],
                 policy: DispatchPolicy, mode: str) -> None:
        self.mg = mg
        self.verts = mg.vertices
        self.policy = policy
        self.mode = mode
        keys = {(self.verts[m].device, engine_of(self.verts[m]))
                for m in members}
        self.engine_keys: list[tuple[int, str]] = sorted(keys)
        self.heaps: dict[tuple[int, str], list[tuple[float, int, int]]] = \
            {k: [] for k in self.engine_keys}
        # fixed mode: seq -> mid of dep-complete vertices + the issue order
        self.ready_fixed: dict[int, int] = {}
        self.seq_order: list[int] = []
        self.next_i = 0
        # per-job state
        self.remaining: dict[int, int] = {}
        self.n_done = 0
        self.total = 0

    # ---- job lifecycle ------------------------------------------------
    def load(self, mids: Sequence[int]) -> list[int]:
        """Begin a job over ``mids``: reset counts and return the
        initially dep-complete vertices (NOT yet published — the caller
        publishes, so the threaded backend can pair each publish with its
        engine wakeup)."""
        subset = set(mids)
        self.remaining = {m: sum(1 for p in self.mg.preds[m] if p in subset)
                          for m in mids}
        self.n_done = 0
        self.total = len(mids)
        if self.mode == "fixed":
            self.seq_order = sorted(self.verts[m].seq for m in mids)
            self.next_i = 0
        return [m for m, r in self.remaining.items() if r == 0]

    @property
    def done(self) -> bool:
        return self.n_done >= self.total

    # ---- ready-set operations ----------------------------------------
    def publish(self, m: int) -> tuple[int, str] | None:
        """Make a dep-complete vertex poppable. Returns the engine key
        whose ready set grew (``None`` in fixed mode — the head-of-line
        queue is global)."""
        v = self.verts[m]
        if self.mode == "fixed":
            self.ready_fixed[v.seq] = m
            return None
        key = (v.device, engine_of(v))
        heapq.heappush(self.heaps[key],
                       (self.policy.priority(m), v.seq, m))
        return key

    def pop(self, key: tuple[int, str]) -> int | None:
        """Pop the policy-best ready vertex of one engine key (a threaded
        worker's view: each stream races only within its engine class)."""
        heap = self.heaps[key]
        if not heap:
            return None
        return heapq.heappop(heap)[2]

    def pop_fixed(self, key: tuple[int, str] | None = None) -> int | None:
        """Fixed-mode head-of-line issue: the next vertex of the strict
        seq order, if dep-complete (and on ``key``'s engine when given).
        ``None`` = the head is not ready / not ours — wait."""
        if self.next_i >= len(self.seq_order):
            return None
        m = self.ready_fixed.get(self.seq_order[self.next_i])
        if m is None:
            return None
        if key is not None:
            v = self.verts[m]
            if (v.device, engine_of(v)) != key:
                return None
        del self.ready_fixed[self.seq_order[self.next_i]]
        self.next_i += 1
        return m

    def pop_best(self) -> int | None:
        """Inline dispatch: the policy-best vertex across EVERY engine's
        ready set — the choice one caller thread makes when it is all the
        engines at once. Same ``(priority, seq)`` ordering as the
        per-engine heaps, so the policy's preference structure is
        identical between backends."""
        if self.mode == "fixed":
            return self.pop_fixed()
        keys = [k for k in self.engine_keys if self.heaps[k]]
        if not keys:
            return None
        best = keys[select_best(keys, lambda k: self.heaps[k][0])]
        return heapq.heappop(self.heaps[best])[2]

    def ready_view(self) -> dict[tuple[int, str], list[int]]:
        """Snapshot of the ready sets (tests: backend equivalence)."""
        if self.mode == "fixed":
            out: dict[tuple[int, str], list[int]] = {}
            for m in self.ready_fixed.values():
                v = self.verts[m]
                out.setdefault((v.device, engine_of(v)), []).append(m)
            return {k: sorted(v) for k, v in out.items()}
        return {k: sorted(t[2] for t in h)
                for k, h in self.heaps.items() if h}

    def complete(self, m: int) -> list[int]:
        """Record a completion event (the non-blocking poll: by the time
        a backend calls this the op has returned, so there is nothing to
        wait on) and return the vertices it made dep-complete."""
        self.n_done += 1
        out: list[int] = []
        for s in self.mg.succs[m]:
            if s in self.remaining:
                self.remaining[s] -= 1
                if self.remaining[s] == 0:
                    out.append(s)
        return out

    def clear_ready(self) -> None:
        """Error path: nothing more launches."""
        for heap in self.heaps.values():
            heap.clear()
        self.ready_fixed.clear()


# --------------------------------------------------------------------------
# backend 3: the thread-free inline executor (small nondet seams)
# --------------------------------------------------------------------------
class InlineExecutor:
    """Run a nondet seam entirely on the calling thread.

    Same kernel, same policy choice among simultaneously-ready vertices,
    same RaceError/tier semantics — zero thread wakeups. The loop is the
    event-driven scheduler collapsed to one thread: pop the policy-best
    ready vertex, execute it, drain its completion through the kernel
    (non-blocking — the op already returned), publish the newly-ready.
    Legal because any dependency-respecting order is certified byte-exact
    (§13); *stall-free on the caller* because the compiler only routes a
    seam here when ``inline_seam_certified`` holds (§14/§17)."""

    def __init__(self, ctx: ExecContext, members: Sequence[int]) -> None:
        self.ctx = ctx
        self.kernel = ReadyKernel(ctx.mg, members, ctx.policy, ctx.mode)

    def run_subset(self, mids: Sequence[int]) -> None:
        """Execute one job to completion on the calling thread. Errors
        propagate directly — there is no worker to surface them from."""
        k = self.kernel
        for m in k.load(mids):
            k.publish(m)
        while not k.done:
            m = k.pop_best()
            assert m is not None, \
                "ready set drained before the job completed (cyclic deps?)"
            run_vertex(self.ctx, m)
            for s in k.complete(m):
                k.publish(s)


# --------------------------------------------------------------------------
# backend 2: the threaded engine-stream fleet (large nondet windows)
# --------------------------------------------------------------------------
class _Engine:
    """One engine class of one device: its kernel ready-heap key + a
    wakeup condition. All engines share the scheduler's single sanitized
    lock; each carries its own condition variable so a completion event
    wakes only streams that gained work."""

    __slots__ = ("key", "cond")

    def __init__(self, key: tuple[int, str],
                 lock: lockcheck.SanitizedLock) -> None:
        self.key = key
        self.cond = threading.Condition(lock)


class ThreadedExecutor:
    """A persistent pool of engine-stream worker threads executing
    dependency-complete vertices — the paper's event-driven runtime.

    Thread start-up is paid ONCE per run: the interpreted backend submits
    the whole graph as a single job; the compiled backend submits one job
    per threaded nondet region, so large seams share one fleet instead of
    each spinning threads up and back down (small seams skip the fleet
    entirely via :class:`InlineExecutor`).

    ``members`` sizes the engines: only (device, engine-class) pairs
    actually present get streams. The scheduler lock is a
    :func:`lockcheck.make_lock` sanitized lock — the lock-order sanitizer
    audits its acquisition pairs along with the store/pool locks (it must
    stay a leaf: no other sanitized lock is ever taken under it)."""

    def __init__(self, ctx: ExecContext, members: Sequence[int], *,
                 n_streams: int = 5, n_transfer_streams: int = 1) -> None:
        self.ctx = ctx
        per_key: dict[tuple[int, str], int] = {}
        verts = ctx.mg.vertices
        for m in members:
            key = (verts[m].device, engine_of(verts[m]))
            per_key[key] = per_key.get(key, 0) + 1

        # ---- scheduler state (all guarded by `lock`) ------------------
        self.lock = lockcheck.make_lock("ExecutorScheduler")
        self.kernel = ReadyKernel(ctx.mg, members, ctx.policy, ctx.mode)
        self.engines = {key: _Engine(key, self.lock)
                        for key in sorted(per_key)}
        self.main_cond = threading.Condition(self.lock)
        self.fixed_cond = threading.Condition(self.lock)
        self.errors: list[BaseException] = []
        self.shutdown = False

        self.threads: list[threading.Thread] = []
        for (d, kind), eng in self.engines.items():
            width = n_streams if kind == COMPUTE else n_transfer_streams
            width = max(1, min(width, per_key[(d, kind)]))
            for i in range(width):
                if ctx.mode == "fixed":
                    th = threading.Thread(target=self._worker_fixed,
                                          args=((d, kind),),
                                          name=f"turnip-{kind}{d}.{i}")
                else:
                    th = threading.Thread(target=self._worker_nondet,
                                          args=(eng,),
                                          name=f"turnip-{kind}{d}.{i}")
                self.threads.append(th)
        self.started: list[threading.Thread] = []

    def start(self) -> None:
        """Start every stream. On a mid-fleet OS refusal the caller's
        ``close()`` (in its finally) drains the partial fleet."""
        for th in self.threads:
            th.start()
            self.started.append(th)

    def close(self) -> None:
        """Deterministic drain — success, worker error, thread-start
        failure, or KeyboardInterrupt alike: every started stream
        observes ``shutdown`` and exits; no timeout, no leaked threads."""
        with self.lock:
            self.shutdown = True
            for eng in self.engines.values():
                eng.cond.notify_all()
            self.fixed_cond.notify_all()
            self.main_cond.notify_all()
        for th in self.started:
            th.join()

    def run_subset(self, mids: Sequence[int]) -> None:
        """Execute one job: every vertex of ``mids``, any legal order.
        Blocks until the job completes; raises the first worker error."""
        k = self.kernel
        with self.lock:
            if self.errors:
                raise self.errors[0]
            for m in k.load(mids):
                self._publish(m)
            while not k.done and not self.errors:
                self.main_cond.wait()
            if self.errors:
                raise self.errors[0]

    # ---- internals ----------------------------------------------------
    def _publish(self, m: int) -> None:
        """Lock held. Publish a dep-complete vertex + wake its engine."""
        key = self.kernel.publish(m)
        if key is None:                       # fixed mode: global queue
            self.fixed_cond.notify_all()
        else:
            self.engines[key].cond.notify()

    def _worker_nondet(self, eng: _Engine) -> None:
        k = self.kernel
        while True:
            with self.lock:
                m = k.pop(eng.key)
                while m is None and not self.shutdown:
                    eng.cond.wait()
                    m = k.pop(eng.key)
                if m is None:
                    return                    # shutdown
            self._run_vertex(m)

    def _worker_fixed(self, key: tuple[int, str]) -> None:
        k = self.kernel
        while True:
            with self.lock:
                m = k.pop_fixed(key)
                while m is None and not self.shutdown:
                    self.fixed_cond.wait()
                    m = k.pop_fixed(key)
                if m is None:
                    return                    # shutdown
                # the new head may belong to any engine: wake everyone
                self.fixed_cond.notify_all()
            self._run_vertex(m)

    def _run_vertex(self, m: int) -> None:
        try:
            run_vertex(self.ctx, m)
        except BaseException as e:     # surface in run_subset's caller
            with self.lock:
                self.errors.append(e)
                self.kernel.clear_ready()     # nothing more launches
                self.main_cond.notify_all()
            return
        with self.lock:
            for s in self.kernel.complete(m):
                self._publish(s)
            if self.kernel.done:
                self.main_cond.notify_all()


# --------------------------------------------------------------------------
# backend 1: the straight-line walker (certified STATIC regions)
# --------------------------------------------------------------------------
class StaticExecutor:
    """Execute a :class:`~repro.core.compile.CompiledPlan`'s STATIC
    regions straight-line on the calling thread: no heap, no locks, no
    condition variables — the precomputed tick counts proved position
    order is dependency order, so the assert is the entire per-vertex
    dispatch. Fused DMA batches issue as one submission: members execute
    back-to-back, one completion wait for the whole span."""

    def __init__(self, ctx: ExecContext, plan: Any) -> None:
        self.ctx = ctx
        self.plan = plan
        self.heads: dict[int, tuple[int, int]] = plan.batch_heads

    def run_region(self, region: Any) -> int:
        """Run one STATIC region; returns the fused submissions issued."""
        n_fused = 0
        i = region.start
        while i < region.end:
            span = self.heads.get(i)
            if span is not None:
                for j in range(span[0], span[1]):
                    self._exec(j)
                n_fused += 1
                i = span[1]
            else:
                self._exec(i)
                i += 1
        return n_fused

    def _exec(self, i: int) -> None:
        ins = self.plan.instrs[i]
        assert ins.ready_tick <= i, "compiled plan not topological"
        ctx = self.ctx
        v = ctx.mg.vertices[ins.mid]
        t_start = time.perf_counter() - ctx.t0
        if ctx.latency is not None:
            d = ctx.latency(v)
            if d > 0:
                time.sleep(d)
        _exec_vertex(v, ctx.mg, ctx.tg, ctx.mem, ctx.host)
        t_end = time.perf_counter() - ctx.t0
        ctx.timeline.append((t_start, t_end, v.device, ins.engine,
                             v.name or str(ins.mid)))
        ctx.spans[ins.mid] = (t_start, t_end)
