"""Production mesh builders.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False,
                         shape: tuple[int, ...] | None = None):
    """16×16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod
    dry-run. Axes: ('pod',) 'data', 'model'. ``shape`` overrides the
    per-pod (data, model) factorization — e.g. (32, 8) suits archs whose
    head counts divide 8 but not 16 (§Perf iteration A4)."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    elif multi_pod and len(shape) == 2:
        shape = (2, *shape)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    assert len(shape) == len(axes)
    return jax.make_mesh(tuple(shape), axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh():
    """Single-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
