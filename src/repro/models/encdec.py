"""Encoder-decoder backbone for seamless-m4t-large-v2 (text/unit enc-dec).

Assignment rule: the audio frontend (conformer speech encoder) is a STUB —
``input_specs`` provides precomputed frame embeddings [B, S_enc, D]; this
module implements the transformer backbone: a bidirectional encoder over the
frame embeddings and a causal decoder with cross-attention.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.rules import constrain
from . import layers as L
from .lm import _norm, _norm_init, _with_prefix

Array = jax.Array


class EncDec:
    def __init__(self, cfg: ArchConfig, *, block_kv: int = 1024,
                 remat: str | None = None) -> None:
        assert cfg.family == "encdec"
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.block_kv = block_kv
        self.remat = remat

    def _wrap_remat(self, body):
        if self.remat is None:
            return body
        if self.remat == "offload":
            pol = jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[],
                names_which_can_be_offloaded=["residual"],
                offload_src="device", offload_dst="pinned_host")
            return jax.checkpoint(body, policy=pol)
        return jax.checkpoint(body)

    # ------------------------------------------------------------- params
    def _enc_layer_init(self, key: Array) -> dict:
        cfg, dt = self.cfg, self.dtype
        k1, k2 = jax.random.split(key)
        spec = L.AttnParamsSpec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.d_head, cfg.qkv_bias)
        p = {"attn": spec.init(k1, dt),
             "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dt,
                               bias=(cfg.mlp == "gelu"))}
        p.update(_with_prefix("ln1", _norm_init(cfg, cfg.d_model, dt)))
        p.update(_with_prefix("ln2", _norm_init(cfg, cfg.d_model, dt)))
        return p

    def _dec_layer_init(self, key: Array) -> dict:
        cfg, dt = self.cfg, self.dtype
        k1, k2, k3 = jax.random.split(key, 3)
        spec = L.AttnParamsSpec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.d_head, cfg.qkv_bias)
        p = {"self_attn": spec.init(k1, dt), "cross_attn": spec.init(k2, dt),
             "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp, dt,
                               bias=(cfg.mlp == "gelu"))}
        for nm in ("ln1", "ln2", "ln3"):
            p.update(_with_prefix(nm, _norm_init(cfg, cfg.d_model, dt)))
        return p

    def init(self, key: Array) -> dict:
        cfg, dt = self.cfg, self.dtype
        kE, kEnc, kDec, kF = jax.random.split(key, 4)
        Vp, D = cfg.padded_vocab, cfg.d_model
        params: dict[str, Any] = {
            "embed": jax.random.normal(kE, (Vp, D), dt) * 0.02,
            "unembed": jax.random.normal(kF, (D, Vp), dt) / math.sqrt(D),
            "enc_layers": jax.vmap(self._enc_layer_init)(
                jax.random.split(kEnc, cfg.n_layers)),
            "dec_layers": jax.vmap(self._dec_layer_init)(
                jax.random.split(kDec, cfg.n_decoder_layers)),
        }
        params.update(_with_prefix("ln_enc", _norm_init(cfg, D, dt)))
        params.update(_with_prefix("ln_f", _norm_init(cfg, D, dt)))
        return params

    # -------------------------------------------------------------- apply
    def encode(self, params: dict, encoder_embeds: Array) -> Array:
        cfg = self.cfg
        h = encoder_embeds.astype(self.dtype)
        B, S, _ = h.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(hh, lp):
            x = _norm(cfg, lp, "ln1", hh)
            hh = hh + L.attention_block(
                lp["attn"], x, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head, positions=pos,
                causal=False, rope_theta=cfg.rope_theta,
                block_kv=self.block_kv)
            x = _norm(cfg, lp, "ln2", hh)
            hh = hh + (L.swiglu_mlp(lp["mlp"], x) if cfg.mlp == "swiglu"
                       else L.gelu_mlp(lp["mlp"], x))
            hh = constrain(hh, ("pod", "data"), "model", None)  # SP
            hh = jax.ad_checkpoint.checkpoint_name(hh, "residual")
            return hh, None

        h, _ = jax.lax.scan(self._wrap_remat(body), h, params["enc_layers"])
        return _norm(cfg, params, "ln_enc", h)

    def decode(self, params: dict, enc_out: Array, tokens: Array) -> Array:
        cfg = self.cfg
        h = jnp.take(params["embed"], tokens, axis=0)
        B, S, _ = h.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(hh, lp):
            x = _norm(cfg, lp, "ln1", hh)
            hh = hh + L.attention_block(
                lp["self_attn"], x, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head, positions=pos,
                causal=True, rope_theta=cfg.rope_theta,
                block_kv=self.block_kv)
            x = _norm(cfg, lp, "ln2", hh)
            hh = hh + L.attention_block(
                lp["cross_attn"], x, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head, positions=pos,
                causal=False, rope_theta=0.0, kv=enc_out,
                block_kv=self.block_kv)
            x = _norm(cfg, lp, "ln3", hh)
            hh = hh + (L.swiglu_mlp(lp["mlp"], x) if cfg.mlp == "swiglu"
                       else L.gelu_mlp(lp["mlp"], x))
            hh = constrain(hh, ("pod", "data"), "model", None)  # SP
            hh = jax.ad_checkpoint.checkpoint_name(hh, "residual")
            return hh, None

        h, _ = jax.lax.scan(self._wrap_remat(body), h, params["dec_layers"])
        h = _norm(cfg, params, "ln_f", h)
        logits = h @ params["unembed"]
        return constrain(logits, ("pod", "data"), None, "model")

    def apply(self, params: dict, tokens: Array, *,
              encoder_embeds: Array) -> Array:
        enc = self.encode(params, encoder_embeds)
        return self.decode(params, enc, tokens)

    def loss(self, params: dict, batch: dict) -> Array:
        cfg = self.cfg
        logits = self.apply(params, batch["tokens"],
                            encoder_embeds=batch["encoder_embeds"])
        logits = logits.astype(jnp.float32)
        iota = jax.lax.broadcasted_iota(jnp.int32, (cfg.padded_vocab,), 0)
        logits = logits + jnp.where(iota < cfg.vocab_size, 0.0, -1e30)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
        return jnp.mean(nll)

    # ------------------------------------------------------------- decode
    def init_cache(self, batch: int, max_len: int, enc_len: int) -> dict:
        cfg, dt = self.cfg, self.dtype
        K, Dh = cfg.n_kv_heads, cfg.d_head
        nd = cfg.n_decoder_layers
        return {
            "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), dt),
            "k": jnp.zeros((nd, batch, max_len, K, Dh), dt),
            "v": jnp.zeros((nd, batch, max_len, K, Dh), dt),
        }

    def decode_step(self, params: dict, cache: dict, token: Array,
                    cache_len: Array) -> tuple[Array, dict]:
        cfg = self.cfg
        B = token.shape[0]
        h = jnp.take(params["embed"], token, axis=0)
        enc_out = cache["enc_out"]
        pos = jnp.full((B, 1), cache_len, jnp.int32)

        def body(hh, xs):
            lp, kc, vc = xs
            x = _norm(cfg, lp, "ln1", hh)
            pa = lp["self_attn"]
            q = (x @ pa["wq"] + pa.get("bq", 0)).reshape(
                B, 1, cfg.n_heads, cfg.d_head)
            k = (x @ pa["wk"] + pa.get("bk", 0)).reshape(
                B, 1, cfg.n_kv_heads, cfg.d_head)
            v = (x @ pa["wv"] + pa.get("bv", 0)).reshape(
                B, 1, cfg.n_kv_heads, cfg.d_head)
            if cfg.rope_theta:
                q = L.rope(q, pos, cfg.rope_theta)
                k = L.rope(k, pos, cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice(kc, k, (0, cache_len, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, cache_len, 0, 0))
            o = L.decode_attention(q, kc, vc, cache_len + 1)
            hh = hh + o.reshape(B, 1, -1) @ pa["wo"]
            x = _norm(cfg, lp, "ln2", hh)
            hh = hh + L.attention_block(
                lp["cross_attn"], x, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
                positions=pos, causal=False, rope_theta=0.0, kv=enc_out,
                block_kv=self.block_kv)
            x = _norm(cfg, lp, "ln3", hh)
            hh = hh + (L.swiglu_mlp(lp["mlp"], x) if cfg.mlp == "swiglu"
                       else L.gelu_mlp(lp["mlp"], x))
            return hh, (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            body, h, (params["dec_layers"], cache["k"], cache["v"]))
        h = _norm(cfg, params, "ln_f", h)
        logits = (h @ params["unembed"])[:, 0]
        return logits, {"enc_out": enc_out, "k": ks, "v": vs}
