"""Substrate tests: data determinism, checkpoint integrity + restart
supervision, LoRA adapters, grad accumulation, sharding rules, serving."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.ckpt.store import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.ft.supervisor import Heartbeat, Supervisor, speculative_redispatch
from repro.models import build_model
from repro.models.lora import lora_init, lora_apply, make_lora_loss
from repro.train.optim import AdamW, Lion, apply_updates
from repro.train.step import init_train_state, make_train_step


# ------------------------------------------------------------------- data
class TestData:
    def test_deterministic(self):
        s = SyntheticLMStream(DataConfig(64, 16, 8, seed=1))
        a = s.batch(5)
        b = s.batch(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_topology_independent(self):
        """Same step → same global batch regardless of worker count
        (elastic-rescale invariant)."""
        s = SyntheticLMStream(DataConfig(64, 16, 8, seed=1))
        whole = s.batch(3)["tokens"]
        parts = [s.batch(3, shard=i, n_shards=4)["tokens"] for i in range(4)]
        np.testing.assert_array_equal(whole, np.concatenate(parts, 0))

    def test_labels_shifted(self):
        s = SyntheticLMStream(DataConfig(64, 16, 4))
        b = s.batch(0)
        assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
        assert not np.array_equal(b["tokens"], b["labels"])

    def test_learnable(self):
        """A real model reduces loss on the synthetic stream (it is a
        next-token task, not noise)."""
        cfg = reduced(get_arch("olmo-1b"))
        model = build_model(cfg)
        stream = SyntheticLMStream(DataConfig(cfg.vocab_size, 32, 8))
        state = init_train_state(model, jax.random.PRNGKey(0), AdamW(lr=3e-3))
        step = jax.jit(make_train_step(model, AdamW(lr=3e-3)))
        losses = []
        for i in range(8):
            state, m = step(state, stream.batch(i))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]


# ------------------------------------------------------------------- ckpt
class TestCheckpoint:
    def _tree(self):
        return {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
                "step": np.int32(7)}

    def test_roundtrip(self, tmp_path):
        t = self._tree()
        save_checkpoint(tmp_path, 7, t)
        got, step = restore_checkpoint(tmp_path, t)
        assert step == 7
        np.testing.assert_array_equal(got["params"]["w"], t["params"]["w"])

    def test_corruption_detected(self, tmp_path):
        t = self._tree()
        p = save_checkpoint(tmp_path, 7, t)
        f = p / "shard_0.npz"
        data = bytearray(f.read_bytes())
        data[len(data) // 2] ^= 0xFF
        f.write_bytes(bytes(data))
        with pytest.raises(IOError, match="corruption"):
            restore_checkpoint(tmp_path, t)

    def test_retention(self, tmp_path):
        t = self._tree()
        for s in range(6):
            save_checkpoint(tmp_path, s, t, max_keep=3)
        kept = [p.name for p in sorted(pathlib.Path(tmp_path).iterdir())]
        assert len(kept) == 3 and kept[-1] == "step_0000000005"

    def test_latest_step(self, tmp_path):
        assert latest_step(tmp_path) is None
        save_checkpoint(tmp_path, 3, self._tree())
        save_checkpoint(tmp_path, 9, self._tree())
        assert latest_step(tmp_path) == 9

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(tmp_path, 1, self._tree())
        bad = {"params": {"w": np.zeros((3, 3), np.float32)},
               "step": np.int32(0)}
        with pytest.raises(ValueError):
            restore_checkpoint(tmp_path, bad)


# --------------------------------------------------------------------- ft
class TestFaultTolerance:
    def test_supervisor_restarts_from_checkpoint(self, tmp_path):
        """Induced failure mid-training → restore + resume to completion."""
        state = {"x": np.zeros((), np.float32)}
        crashes = {"left": 2}

        def step_fn(state, batch):
            if state["x"] == 7 and crashes["left"]:
                crashes["left"] -= 1
                raise RuntimeError("injected node failure")
            return {"x": state["x"] + 1}, {}

        sup = Supervisor(ckpt_dir=str(tmp_path), save_every=2)
        state, report = sup.run(state, step_fn, lambda s: None, 12)
        assert report.final_step == 12
        assert report.restarts == 2
        assert float(state["x"]) == 12
        assert any(h.startswith("restored@") for h in report.history)

    def test_heartbeat(self):
        hb = Heartbeat(timeout_s=10.0)
        hb.beat("w0", now=100.0)
        hb.beat("w1", now=105.0)
        assert hb.dead_workers(now=112.0) == ["w0"]

    def test_supervisor_restart_under_lock_sanitizer(self, tmp_path):
        """Satellite: training-side locks (Supervisor, Heartbeat,
        CkptStore) join the suite-wide acquisition-order audit. A restart
        run with concurrent worker heartbeats and a status-polling
        monitor must record the documented Supervisor -> Heartbeat
        nesting and stay acyclic (the autouse sanitizer re-asserts at
        teardown)."""
        import threading

        from repro.core import lockcheck

        state = {"x": np.zeros((), np.float32)}
        crashes = {"left": 1}

        def step_fn(state, batch):
            if state["x"] == 5 and crashes["left"]:
                crashes["left"] -= 1
                raise RuntimeError("injected node failure")
            return {"x": state["x"] + 1}, {}

        sup = Supervisor(ckpt_dir=str(tmp_path), save_every=2)
        stop = threading.Event()

        def monitor():
            while not stop.is_set():
                sup.heartbeat.beat("w0")
                sup.status()
                sup.heartbeat.dead_workers()

        t = threading.Thread(target=monitor)
        t.start()
        try:
            state, report = sup.run(state, step_fn, lambda s: None, 10)
        finally:
            stop.set()
            t.join(timeout=5)
        assert report.final_step == 10
        assert report.restarts == 1
        assert float(state["x"]) == 10
        g = lockcheck.edges()
        # run() beats the heartbeat under the supervisor lock: the
        # documented nesting must be recorded, never its inversion
        assert "Heartbeat" in g.get("Supervisor", set()), g
        assert "Supervisor" not in g.get("Heartbeat", set()), g
        # checkpoint publishes ride an audited leaf (no nesting, so no
        # edge — but the lock class is instrumented)
        from repro.ckpt import store as ckpt_store
        assert isinstance(ckpt_store._publish_lock, lockcheck.SanitizedLock)
        assert ckpt_store._publish_lock.lock_class == "CkptStore"
        lockcheck.assert_acyclic()

    def test_straggler_policy(self):
        out = speculative_redispatch(
            durations={1: 10.0, 2: 0.5},
            op_medians={"matmul": 1.0},
            vertex_ops={1: "matmul", 2: "matmul"}, factor=3.0)
        assert out == [1]


# ------------------------------------------------------------------- lora
class TestLoRA:
    def test_adapters_cover_targets_and_start_identity(self):
        cfg = reduced(get_arch("qwen2.5-3b"))
        model = build_model(cfg)
        base = model.init(jax.random.PRNGKey(0))
        ad = lora_init(jax.random.PRNGKey(1), base, rank=4)
        assert any("wq" in k for k in ad)
        eff = lora_apply(base, ad, rank=4)
        # B is zero-init → merged params == base params
        for (p1, a), (p2, b) in zip(
                jax.tree_util.tree_flatten_with_path(base)[0],
                jax.tree_util.tree_flatten_with_path(eff)[0]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_lora_training_reduces_loss(self):
        cfg = reduced(get_arch("olmo-1b"))
        model = build_model(cfg)
        base = model.init(jax.random.PRNGKey(0))
        ad = lora_init(jax.random.PRNGKey(1), base, rank=4)
        loss_fn = make_lora_loss(model, base)
        opt = AdamW(lr=1e-2)
        state = {"params": ad, "opt": opt.init(ad),
                 "step": jnp.zeros((), jnp.int32)}
        step = jax.jit(make_train_step(model, opt, loss_fn=loss_fn))
        stream = SyntheticLMStream(DataConfig(cfg.vocab_size, 32, 4))
        losses = []
        for i in range(6):
            state, m = step(state, stream.batch(i))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]


# ------------------------------------------------------------------ optim
class TestOptim:
    def test_grad_accum_matches_full_batch(self):
        cfg = reduced(get_arch("olmo-1b"))
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        state1 = init_train_state(model, key, AdamW())
        state2 = jax.tree.map(lambda x: x, state1)
        batch = SyntheticLMStream(DataConfig(cfg.vocab_size, 16, 8)).batch(0)
        s1, m1 = jax.jit(make_train_step(model, AdamW()))(state1, batch)
        s2, m2 = jax.jit(make_train_step(model, AdamW(),
                                         grad_accum=2))(state2, batch)
        # microbatched loss is the mean over microbatches == full-batch loss
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)

    def test_lion(self):
        p = {"w": jnp.ones((3,))}
        opt = Lion(lr=0.1)
        st = opt.init(p)
        upd, st = opt.update({"w": jnp.ones((3,))}, st, p)
        assert float(jnp.abs(upd["w"]).sum()) > 0


# ------------------------------------------------------------------ serve
class TestServe:
    def test_greedy_generation_consistent(self):
        from repro.serve.engine import Engine, ServeConfig
        cfg = reduced(get_arch("olmo-1b"))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, ServeConfig(max_len=64,
                                                batch_buckets=(1, 2, 4)))
        out = eng.generate([[1, 2, 3], [4, 5]], max_new=5)
        assert len(out) == 2 and all(len(o) == 5 for o in out)
        # batched result equals single-request result (bucketing is inert)
        solo = eng.generate([[1, 2, 3]], max_new=5)
        assert out[0] == solo[0]
