"""llama-7b: the paper's own evaluation model (§8). [arXiv:2302.13971]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=32000,
    norm="rmsnorm", mlp="swiglu", rope_theta=1e4,
    source="arXiv:2302.13971 (paper §8)",
)
