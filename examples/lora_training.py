"""End-to-end driver (deliverable b): train a ~100M-param model with LoRA
adapters for a few hundred steps on the synthetic stream, with checkpointing
and an injected failure to demonstrate restart (paper task 2 at framework
level — the TASKGRAPH-level LoRA workload lives in benchmarks/fig11).

    PYTHONPATH=src python examples/lora_training.py [--steps 200]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.ft.supervisor import Supervisor
from repro.models import build_model
from repro.models.lora import lora_init, make_lora_loss
from repro.train.optim import AdamW
from repro.train.step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    # ~100M params: 12L × d512 (demo scale for the CPU container)
    cfg = ArchConfig(name="demo-100m", family="dense", n_layers=8,
                     d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
                     vocab_size=8192, dtype="float32")
    model = build_model(cfg)
    base = model.init(jax.random.PRNGKey(0))
    n_base = sum(x.size for x in jax.tree.leaves(base))
    adapters = lora_init(jax.random.PRNGKey(1), base, rank=8)
    n_ad = sum(x.size for x in jax.tree.leaves(adapters))
    print(f"base params: {n_base/1e6:.1f}M; LoRA params: {n_ad/1e6:.2f}M")

    opt = AdamW(lr=1e-3)
    loss_fn = make_lora_loss(model, base, rank=8)
    state = {"params": adapters, "opt": opt.init(adapters),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(make_train_step(model, opt, loss_fn=loss_fn))
    stream = SyntheticLMStream(DataConfig(cfg.vocab_size, 64, 8))

    crashes = {"armed": args.inject_failure}

    def step_fn(state, batch):
        s = int(state["step"])
        if crashes["armed"] and s == args.steps // 2:
            crashes["armed"] = False
            raise RuntimeError("injected node failure")
        state, m = step(state, batch)
        if s % 20 == 0:
            print(f"step {s:4d}: loss {float(m['loss']):.4f}")
        return state, m

    ckpt_dir = tempfile.mkdtemp(prefix="lora_ckpt_")
    sup = Supervisor(ckpt_dir=ckpt_dir, save_every=25)
    state, report = sup.run(state, step_fn, lambda s: stream.batch(s),
                            args.steps)
    print(f"finished at step {report.final_step} with "
          f"{report.restarts} restart(s); history={report.history}")


if __name__ == "__main__":
    main()
