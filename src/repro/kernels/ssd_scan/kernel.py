"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid (B, H, n_chunks): chunks innermost (sequential on TPU), per-(batch,
head) SSM state [P, N] carried in VMEM scratch across chunks; each grid step
computes the intra-chunk quadratic term plus the incoming-state contribution
and updates the state — the same math as the pure-jnp oracle
(:mod:`repro.models.ssm._ssd_chunked`), tiled so the [c, c] decay matrix
lives entirely in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, h_scr, *,
                chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)            # [c, P]
    dt = dt_ref[0, 0].astype(jnp.float32)          # [c, 1] (lane-padded)
    a = A_ref[0].astype(jnp.float32)               # scalar decay rate
    Bm = B_ref[0].astype(jnp.float32)              # [c, N]
    Cm = C_ref[0].astype(jnp.float32)              # [c, N]

    dA = dt[:, 0] * a                               # [c]  (negative)
    seg = jnp.cumsum(dA)                            # [c]
    # intra-chunk: y[t] = Σ_{s<=t} C_t·B_s dt_s e^{seg_t - seg_s} x_s
    diff = seg[:, None] - seg[None, :]              # [c, c]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(tri, diff, -1e30))
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [c, c]
    w = cb * decay * dt[None, :, 0]                 # [t, s]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [c, P]
    # incoming state: y += (C_t e^{seg_t}) · h^T   (h: [P, N])
    y = y + jax.lax.dot_general(
        Cm * jnp.exp(seg)[:, None], h_scr[...],
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: h' = e^{seg_c} h + Σ_s e^{seg_c - seg_s} dt_s x_s B_s^T
    tail = jnp.exp(seg[-1] - seg) * dt[:, 0]        # [c]
    upd = jax.lax.dot_general(x, Bm * tail[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P, N]
    h_scr[...] = jnp.exp(seg[-1]) * h_scr[...] + upd


def ssd_scan_kernel(xh, dt, A, Bm, Cm, *, chunk: int = 128,
                    interpret: bool = False):
    """xh: [B, S, H, P]; dt: [B, S, H] (softplus'ed); A: [H] (negative);
    Bm/Cm: [B, S, N]. Returns y: [B, S, H, P]. S must be chunk-padded by the
    wrapper."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    from jax.experimental.pallas import tpu as pltpu
    xT = xh.transpose(0, 2, 1, 3)                   # [B, H, S, P]
    dtT = dt.transpose(0, 2, 1)[..., None]          # [B, H, S, 1]
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ic: (b, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P),
                               lambda b, h, ic: (b, h, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xT, dtT, A, Bm, Cm)
    return y.transpose(0, 2, 1, 3)
