"""Simulated device arenas + the paper's "unobtrusiveness" policies (§C).

The BUILDMEMGRAPH compiler never allocates real memory: it maintains, per
device, an :class:`Arena` — an interval map of ``[0, capacity)`` in abstract
units — through special malloc/free variants (paper Fig. 9). The arena tracks,
for every byte range, who owns it now and who wrote it last, so the builder
can emit the safe-overwrite memory dependencies.

Two policy hooks (paper §C):

* **placement** — among free regions able to hold an allocation, prefer the
  one whose last use is furthest in the past (maximizes the chance that the
  safe-overwrite dependencies are already satisfied when the runtime wants to
  dispatch the new writer);
* **eviction** — among candidate regions requiring eviction, prefer the one
  maximizing the *minimum* next-use distance of any evicted tensor (Belady;
  the paper's generalization to variable-size tensors). ``lru`` and ``random``
  victims are provided for the §C ablation.

:class:`HostPlan` extends the same discipline one tier down (beyond-paper,
DESIGN.md §10): the host arena itself is an :class:`Arena` of
``host_capacity`` units shared by every device, whose tenants are the host
copies created by OFFLOAD (and restaged by LOAD) vertices. When an
admission cannot be placed, the plan picks the host copy whose next
schedule-known use is furthest away (Belady over the serialized vertex
list; copies backed by a live device tensor or terminal outputs count as
"never needed" and spill first) and asks the builder to emit the SPILL
vertex that frees its extent.

:class:`PrefetchPlan` (DESIGN.md §11) closes the remaining reactive gap:
pass 1 of the build emits disk→host LOADs at force-reload time — exactly
the stall the paper says the compiler's whole-future knowledge should
hide. The plan walks pass 1's schedule *backward*: for every reactive
LOAD it finds the earliest execution point from which the restaged bytes
fit in the host tier through every intervening window (capped by
``prefetch_distance`` and by the point the disk blob comes into
existence), charging each committed hoist against the occupancy profile
so simultaneous prefetches stay jointly feasible. Pass 2 replays the
build and emits the hoisted LOADs at those points — pipelined
``disk→host→device`` chains that start ahead of the consumer's horizon.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Callable, Iterable

__all__ = ["Extent", "Arena", "PlacementDecision", "EvictionDecision",
           "HostEntry", "HostPlan", "PrefetchPlan", "PrefetchRecord", "INF"]

INF = float("inf")


@dataclasses.dataclass
class Extent:
    """A maximal run of bytes with uniform ownership state."""

    offset: int
    size: int
    owner: int | None = None          # memgraph vertex occupying it; None = free
    last_writers: set[int] = dataclasses.field(default_factory=set)
    last_use: int = -1                # seq when last freed/read (free extents)
    pinned: int = 0                   # pin refcount (eviction-exempt)
    # Writers/direct-deps of these bytes *before* the current owner. If the
    # owner's reservation is cancelled before it ever writes, these (not the
    # owner!) are what the next tenant must order against.
    carried_writers: set[int] = dataclasses.field(default_factory=set)
    carried_direct: set[int] = dataclasses.field(default_factory=set)
    # for FREE extents: non-writer ordering obligations (e.g. a pending
    # offload still reading the stale bytes) inherited by the next tenant
    last_direct: set[int] = dataclasses.field(default_factory=set)

    @property
    def end(self) -> int:
        return self.offset + self.size

    @property
    def free(self) -> bool:
        return self.owner is None


@dataclasses.dataclass
class PlacementDecision:
    offset: int
    size: int
    prev_writers: set[int]            # real byte-writers: expand to their readers
    direct_deps: set[int] = dataclasses.field(default_factory=set)  # no expansion


@dataclasses.dataclass
class EvictionDecision:
    offset: int
    size: int
    prev_writers: set[int]            # writers of covered *free* bytes
    victims: list[int]                # owner mids to offload (executed)
    cancelled: list[int]              # owner mids whose reservation is cancelled


class Arena:
    """Interval map over ``[0, capacity)`` for one device."""

    def __init__(self, device: int, capacity: int) -> None:
        if capacity <= 0:
            raise ValueError("arena capacity must be positive")
        self.device = device
        self.capacity = capacity
        self.extents: list[Extent] = [Extent(0, capacity)]
        self._by_owner: dict[int, Extent] = {}
        self.peak_used = 0
        self._used = 0

    # -- bookkeeping --------------------------------------------------------
    def _coalesce(self) -> None:
        out: list[Extent] = []
        for e in self.extents:
            if out and out[-1].free and e.free:
                prev = out[-1]
                prev.size += e.size
                prev.last_writers |= e.last_writers
                prev.last_direct |= e.last_direct
                prev.last_use = max(prev.last_use, e.last_use)
            else:
                out.append(e)
        self.extents = out

    def owner_extent(self, mid: int) -> Extent:
        return self._by_owner[mid]

    def used(self) -> int:
        return self._used

    def pin(self, mid: int) -> None:
        self._by_owner[mid].pinned += 1

    def unpin(self, mid: int) -> None:
        e = self._by_owner[mid]
        if e.pinned <= 0:
            raise AssertionError(f"unbalanced unpin of {mid}")
        e.pinned -= 1

    def set_owner(self, old_mid: int, new_mid: int) -> None:
        """Transfer ownership (e.g. streaming-reduce JOIN takes over)."""
        e = self._by_owner.pop(old_mid)
        e.owner = new_mid
        self._by_owner[new_mid] = e

    # -- free ---------------------------------------------------------------
    def free(self, mid: int, seq: int, *, wrote: bool = True) -> None:
        """Return an extent. ``wrote=False`` releases a reservation that never
        produced data: the bytes' true last writers are the carried-forward
        ones, not the (cancelled) owner."""
        e = self._by_owner.pop(mid)
        if e.pinned:
            raise AssertionError(f"freeing pinned extent of {mid}")
        e.owner = None
        e.last_writers = {mid} if wrote else set(e.carried_writers)
        e.last_direct = set() if wrote else set(e.carried_direct)
        e.carried_writers = set()
        e.carried_direct = set()
        e.last_use = seq
        self._used -= e.size
        self._coalesce()

    # -- allocation from free space only (simMalloc) -------------------------
    def place_free(self, size: int) -> PlacementDecision | None:
        """Place in free space only (may span several adjacent free extents).
        §C policy: prefer the window whose last use is furthest in the past."""
        if size > self.capacity:
            return None
        best: tuple[tuple, int] | None = None  # (score, start extent index)
        n = len(self.extents)
        i = 0
        while i < n:
            if not self.extents[i].free:
                i += 1
                continue
            # maximal free run starting at i
            run = 0
            last_use = -1
            j = i
            while j < n and self.extents[j].free:
                run += self.extents[j].size
                j += 1
            if run >= size:
                # recency of the covered window only
                cov = 0
                k = i
                while cov < size:
                    last_use = max(last_use, self.extents[k].last_use)
                    cov += self.extents[k].size
                    k += 1
                score = (last_use, self.extents[i].offset)
                if best is None or score < best[0]:
                    best = (score, i)
            i = j
        if best is None:
            return None
        return self._carve(self.extents[best[1]].offset, size)

    # -- allocation with eviction (simMallocOffld) ----------------------------
    def place_evict(
        self,
        size: int,
        next_use: Callable[[int], float],
        *,
        allow_cancel: bool = False,
        victim_policy: str = "belady",
        rng: random.Random | None = None,
    ) -> EvictionDecision | None:
        """Pick a window ``[a, a+size)`` minimizing eviction damage.

        Every extent overlapping the window must be free, or owned by an
        executed+unpinned vertex (→ offload victim), or — when
        ``allow_cancel`` — an unexecuted+unpinned reservation (→ cancel).
        """
        if size > self.capacity:
            return None
        n = len(self.extents)
        best: tuple[tuple, int] | None = None  # (score key, anchor index)
        for i in range(n):
            a = self.extents[i].offset
            if a + size > self.capacity:
                break
            victims, cancels, ok = self._window_victims(i, a, size, allow_cancel)
            if not ok:
                continue
            if victim_policy == "belady":
                # maximize the minimum next use over evicted tensors (§C)
                mn = min((next_use(e.owner) for e in victims + cancels),
                         default=INF)
                score = (-mn,)
            elif victim_policy == "lru":
                mx = max((e.last_use for e in victims + cancels), default=-1)
                score = (mx,)
            elif victim_policy == "random":
                score = ((rng or random).random(),)
            else:
                raise ValueError(f"unknown victim policy {victim_policy!r}")
            evict_bytes = sum(e.size for e in victims + cancels)
            key = (score, len(cancels), evict_bytes, a)
            if best is None or key < best[0]:
                best = (key, i)
        if best is None:
            return None
        i = best[1]
        a = self.extents[i].offset
        victims, cancels, _ = self._window_victims(i, a, size, allow_cancel)
        victim_mids = [e.owner for e in victims]
        cancel_mids = [e.owner for e in cancels]
        return EvictionDecision(a, size, set(), victim_mids, cancel_mids)

    def _window_victims(self, i: int, a: int, size: int, allow_cancel: bool):
        victims: list[Extent] = []
        cancels: list[Extent] = []
        for j in range(i, len(self.extents)):
            e = self.extents[j]
            if e.offset >= a + size:
                break
            if e.free:
                continue
            if e.pinned:
                return [], [], False
            if e.owner in self._executed_set:
                victims.append(e)
            elif allow_cancel:
                cancels.append(e)
            else:
                return [], [], False
        return victims, cancels, True

    # The builder tells the arena which owners are executed (have data) so
    # eviction can distinguish offload victims from cancellable reservations.
    _executed_set: set[int] = set()

    def bind_executed_set(self, executed: set[int]) -> None:
        self._executed_set = executed

    # -- carving --------------------------------------------------------------
    def evict_and_carve(self, dec: EvictionDecision, seq: int) -> PlacementDecision:
        """Free whole victim/cancelled extents, then carve the window."""
        for mid in dec.victims:
            self.free(mid, seq, wrote=True)
        for mid in dec.cancelled:
            self.free(mid, seq, wrote=False)
        return self._carve(dec.offset, dec.size)

    def _carve(self, offset: int, size: int) -> PlacementDecision:
        """Carve ``[offset, offset+size)`` out of free extents (must be free)."""
        writers: set[int] = set()
        direct: set[int] = set()
        i = 0
        while i < len(self.extents):
            e = self.extents[i]
            if e.end <= offset:
                i += 1
                continue
            if e.offset >= offset + size:
                break
            if not e.free:
                raise AssertionError("carve over non-free extent")
            writers |= e.last_writers
            direct |= e.last_direct
            # split head
            if e.offset < offset:
                head = Extent(e.offset, offset - e.offset, None,
                              set(e.last_writers), e.last_use,
                              last_direct=set(e.last_direct))
                e.offset, e.size = offset, e.end - offset
                self.extents.insert(i, head)
                i += 1
                continue
            # split tail
            if e.end > offset + size:
                tail = Extent(offset + size, e.end - (offset + size), None,
                              set(e.last_writers), e.last_use,
                              last_direct=set(e.last_direct))
                e.size = offset + size - e.offset
                self.extents.insert(i + 1, tail)
            # consume e
            i += 1
        # merge the covered free extents into a single placeholder
        covered = [e for e in self.extents
                   if e.offset >= offset and e.end <= offset + size]
        assert covered and covered[0].offset == offset \
            and covered[-1].end == offset + size, "carve window not covered"
        keep = covered[0]
        keep.size = size
        keep.last_writers = set()
        keep.last_direct = set()
        for e in covered[1:]:
            self.extents.remove(e)
        return PlacementDecision(offset, size, writers, direct)

    def commit(self, dec: PlacementDecision, mid: int) -> Extent:
        for e in self.extents:
            if e.offset == dec.offset and e.size == dec.size and e.free:
                e.owner = mid
                e.pinned = 0
                e.carried_writers = set(dec.prev_writers)
                e.carried_direct = set(dec.direct_deps)
                self._by_owner[mid] = e
                self._used += e.size
                self.peak_used = max(self.peak_used, self._used)
                return e
        raise AssertionError("commit target extent not found")


# --------------------------------------------------------------------------
# the host tier (beyond-paper: bounded CPU RAM with disk spill, DESIGN.md §10)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class HostEntry:
    """One logical host copy: the payload of an OFFLOAD (re-staged by LOADs
    after disk spills). ``producer`` is the vertex whose completion makes
    the current host bytes live (the OFFLOAD, or the latest LOAD);
    ``readers`` are the emitted vertices that read those bytes (RELOADs and
    SPILLs), which any later spill of the extent must order after."""

    key: int                      # host-store key = the OFFLOAD vertex mid
    tid: int
    size: int                     # units (same size_fn units as devices)
    nbytes: int
    producer: int
    resident: bool = True         # bytes currently in host RAM
    spill_src: int | None = None  # SPILL vertex owning the immutable disk copy
    readers: set[int] = dataclasses.field(default_factory=set)
    # LOAD vertices that read the disk blob: a drop of the blob (freeing
    # its disk-tier units) must order after every one of them
    disk_readers: set[int] = dataclasses.field(default_factory=set)
    # readers of *retired* residencies (accumulated when a spill or a
    # restage resets ``readers``) and the most recent SPILL: a final drop
    # releases every copy of the bytes, so it must order after anything
    # that ever read them on any tier — per-residency deps alone leave a
    # racy window for readers of earlier residencies
    all_readers: set[int] = dataclasses.field(default_factory=set)
    last_spill: int | None = None


class HostPlan:
    """Compile-time model of the bounded host tier.

    ``capacity=None`` models the unbounded host store (the paper's implicit
    assumption): nothing is tracked beyond the peak-occupancy counter and no
    SPILL/LOAD vertices are ever requested, so existing plans are unchanged.

    With a capacity, host copies become arena tenants. :meth:`admit` carves
    space for a new copy, spilling Belady-chosen victims through the
    builder-supplied callback; the returned mids are ordering obligations
    (MEM deps) the admitted producer must wait on — exactly the
    safe-overwrite discipline of the device arenas, one tier down."""

    def __init__(self, capacity: int | None,
                 next_use: Callable[[HostEntry], float]) -> None:
        self.capacity = capacity
        self.arena = Arena(-1, capacity) if capacity is not None else None
        self.entries: dict[int, HostEntry] = {}
        self.next_use = next_use
        self._occ = 0                 # unbounded-mode occupancy (units)
        self._peak = 0
        # ground-truth residency intervals for the certifier's budget pass
        # (DESIGN.md §13): [key, admit_mid, release_mid|None, size] per
        # host-arena tenancy, in admission order. The certifier recovers
        # the same intervals from the graph alone; tests cross-check.
        self.residency_log: list[list[Any]] = []
        self._open_res: dict[int, int] = {}      # key -> residency_log index

    @property
    def bounded(self) -> bool:
        return self.arena is not None

    @property
    def peak_units(self) -> int:
        return self.arena.peak_used if self.bounded else self._peak

    @property
    def used_units(self) -> int:
        """Current host-tier occupancy (units)."""
        return self.arena.used() if self.bounded else self._occ

    def note_unbounded(self, size: int) -> None:
        """Unbounded mode: track occupancy so callers can size real budgets
        (e.g. ``host_capacity = fraction * unbounded_peak``)."""
        self._occ += size
        self._peak = max(self._peak, self._occ)

    # ---------------------------------------------------------- admission
    def admit(self, key: int, tid: int, size: int, nbytes: int,
              producer: int, seq: int,
              spill_cb: Callable[[HostEntry], int],
              exclude: frozenset = frozenset(),
              allow_spill: bool = True) -> set[int] | None:
        """Place ``producer``'s host copy; returns the MEM-dep mids it must
        order after, or ``None`` when the resident working set cannot be
        spilled down far enough (host OOM). ``spill_cb(entry)`` must emit
        the SPILL vertex for a victim and return its mid.
        ``allow_spill=False`` admits into genuinely free space only (the
        prefetch path: an opportunistic restage must never force other
        copies out) — ``None`` then just means "no room now"."""
        if not self.bounded:
            self.note_unbounded(size)
            return set()
        if size > self.arena.capacity:
            return None
        while True:
            dec = self.arena.place_free(size)
            if dec is not None:
                break
            if not allow_spill:
                return None
            victim = self._pick_victim(exclude)
            if victim is None:
                return None
            self.spilled(victim, spill_cb(victim), seq)
        deps = set(dec.prev_writers) | set(dec.direct_deps)
        self.arena.commit(dec, producer)
        e = self.entries.get(key)
        if e is None:
            self.entries[key] = HostEntry(key, tid, size, nbytes, producer)
        else:                          # re-staged by a LOAD
            e.producer = producer
            e.resident = True
            e.all_readers |= e.readers
            e.readers = set()
        self._open_res[key] = len(self.residency_log)
        self.residency_log.append([key, producer, None, size])
        return deps

    def _pick_victim(self, exclude: frozenset) -> HostEntry | None:
        """Belady over the schedule: spill the resident copy whose next
        known use is furthest; among never-needed copies prefer the largest
        (fewest spill ops per freed unit)."""
        best: tuple[tuple, HostEntry] | None = None
        for e in self.entries.values():
            if not e.resident or e.key in exclude:
                continue
            score = (-self.next_use(e), -e.size, e.key)
            if best is None or score < best[0]:
                best = (score, e)
        return best[1] if best else None

    # --------------------------------------------------------- bookkeeping
    def spilled(self, e: HostEntry, smid: int, seq: int) -> None:
        """Record that ``smid`` (a SPILL vertex) evicted ``e`` from the host
        arena: the freed extent's last writer becomes the spill itself, so
        the next tenant of those units orders after the eviction completes."""
        self.arena.set_owner(e.producer, smid)
        self.arena.free(smid, seq)
        e.resident = False
        e.all_readers |= e.readers
        e.readers = set()
        e.last_spill = smid
        if e.spill_src is None:
            e.spill_src = smid         # first spill owns the disk copy
        idx = self._open_res.pop(e.key, None)
        if idx is not None:
            self.residency_log[idx][2] = smid

    def dropped(self, e: HostEntry, dmid: int, seq: int) -> None:
        """Record a dead host copy's release (drop vertex ``dmid``)."""
        self.arena.set_owner(e.producer, dmid)
        self.arena.free(dmid, seq)
        del self.entries[e.key]
        idx = self._open_res.pop(e.key, None)
        if idx is not None:
            self.residency_log[idx][2] = dmid

    def forget(self, key: int) -> None:
        """Delete a dead, non-resident entry (its disk blob may linger)."""
        self.entries.pop(key, None)


# --------------------------------------------------------------------------
# cross-tier prefetch (beyond-paper: DESIGN.md §11)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PrefetchRecord:
    """One reactive disk→host LOAD observed in pass 1 of the build.

    Positions are *execution windows*: window ``w`` spans from the
    completion of the ``w-1``-th executed task to the completion of the
    ``w``-th. ``spill_pos`` is the window in which the entry's first SPILL
    was emitted (the disk blob exists from then on); ``reload_pos`` the
    window in which pass 1 emitted the reactive LOAD (while executing the
    consumer)."""

    tid: int
    size: int                     # units the restaged copy occupies
    nbytes: int                   # real bytes the disk hop moves
    spill_pos: int
    reload_pos: int


class PrefetchPlan:
    """Backward walk over a completed build's host-occupancy profile.

    ``occ_at[w]`` is the maximum host-tier occupancy (units) observed
    during execution window ``w`` of pass 1. For each reactive LOAD the
    plan scans backward from its consumer: hoisting the LOAD to the
    boundary after window ``p`` keeps the restaged bytes resident through
    windows ``p+1 .. reload_pos-1``, so the earliest feasible ``p`` is the
    smallest one (≥ ``spill_pos``, within ``prefetch_distance``) for which
    every one of those windows still fits under ``capacity``. Committed
    hoists are charged back into the profile, so overlapping prefetches
    remain *jointly* feasible — the plan never schedules a restage that
    would force other host copies out (pass 2 additionally enforces this
    structurally: prefetch admissions use free space only).

    The result is a hint map ``{window p -> [tids to restage there]}``
    consumed by pass 2 of the builder, plus the ``stall_bytes_hidden``
    counter: disk bytes whose transfer was moved off the consumers'
    critical path."""

    def __init__(self, capacity: int, occ_at: list[int],
                 distance: int) -> None:
        self.capacity = capacity
        self.occ = list(occ_at)
        self.distance = max(int(distance), 0)
        self.hints: dict[int, list[int]] = {}
        self.n_hoisted = 0
        self.stall_bytes_hidden = 0

    def hoist(self, rec: PrefetchRecord) -> int | None:
        """Earliest feasible emission window for ``rec``; commits the hoist
        (charging the occupancy profile) and returns the window, or
        ``None`` when no earlier point fits."""
        lo = max(rec.spill_pos, rec.reload_pos - self.distance, 0)
        p = rec.reload_pos
        q = rec.reload_pos - 1
        while q >= lo:
            # window q+1 .. reload_pos-1 must absorb the restaged bytes;
            # moving the boundary one window earlier adds window q+1's
            # constraint (the boundary after q starts window q+1)
            if (q + 1 < rec.reload_pos
                    and self.occ[q + 1] + rec.size > self.capacity):
                break
            p = q
            q -= 1
        if p >= rec.reload_pos:
            return None
        for w in range(p + 1, rec.reload_pos):
            self.occ[w] += rec.size
        self.hints.setdefault(p, []).append(rec.tid)
        self.n_hoisted += 1
        self.stall_bytes_hidden += rec.nbytes
        return p

    def compute(self, records: Iterable[PrefetchRecord]
                ) -> dict[int, list[int]]:
        """Hoist every record (schedule order) and return the hint map."""
        for rec in sorted(records, key=lambda r: (r.reload_pos, r.tid)):
            self.hoist(rec)
        return self.hints
