"""Pure-jnp oracle: the model's own chunked SSD (validated against a naive
per-token recurrence in tests)."""
from repro.models.ssm import _ssd_chunked


def ssd_scan_ref(xh, dt, A, Bm, Cm, chunk: int = 128):
    y, _ = _ssd_chunked(xh, dt, A, Bm, Cm, chunk=chunk)
    return y
