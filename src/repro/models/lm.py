"""Unified decoder LM covering the dense / MoE / RWKV6 / Zamba2-hybrid
families (enc-dec lives in :mod:`repro.models.encdec`).

Design: pure-functional params pytrees, per-layer params stacked along a
leading L axis and consumed by ``lax.scan`` (small HLO even at 81 layers;
remat policy applied by the trainer). Decode keeps KV caches / SSM states as
explicit pytrees so ``serve_step`` is a pure function suitable for pjit.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.rules import constrain
from . import layers as L
from . import rwkv as R
from . import ssm as S

Array = jax.Array


def _norm(cfg: ArchConfig, p: dict, key: str, x: Array) -> Array:
    if cfg.norm == "rmsnorm":
        return L.rmsnorm(x, p[key + "_g"])
    if cfg.norm == "layernorm":
        return L.layernorm(x, p[key + "_g"], p[key + "_b"])
    return L.layernorm(x, None, None)       # layernorm_np (OLMo)


def _norm_init(cfg: ArchConfig, d: int, dtype) -> dict:
    if cfg.norm == "rmsnorm":
        return {"_g": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        return {"_g": jnp.ones((d,), dtype), "_b": jnp.zeros((d,), dtype)}
    return {}


def _with_prefix(prefix: str, d: dict) -> dict:
    return {prefix + k: v for k, v in d.items()}


def _quant_int8(x: Array) -> tuple[Array, Array]:
    """Per-(token, head) symmetric int8 quantization: x [B,1,K,Dh]."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)   # [B,1,K]
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


class LM:
    """Decoder-only LM for families: dense, moe, rwkv, zamba."""

    def __init__(self, cfg: ArchConfig, *, block_kv: int = 1024,
                 use_pallas: bool = False,
                 moe_capacity_factor: float | None = 1.25,
                 remat: str | None = None,
                 kv_cache_dtype: str = "bf16") -> None:
        self.cfg = cfg
        self.block_kv = block_kv
        self.use_pallas = use_pallas
        self.moe_capacity_factor = moe_capacity_factor
        self.remat = remat            # None | 'full' | 'dots' | 'offload'
        self.kv_cache_dtype = kv_cache_dtype    # 'bf16' | 'int8' (KIVI-style)
        self.dtype = jnp.dtype(cfg.dtype)

    def _wrap_remat(self, body):
        """Apply the activation-checkpoint policy to a scan body.
        'offload' realizes the TURNIP idea inside XLA: saved residuals are
        annotated for device→pinned_host offload instead of recompute."""
        if self.remat is None:
            return body
        if self.remat == "full":
            return jax.checkpoint(body)
        if self.remat == "dots":
            return jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        if self.remat == "offload":
            pol = jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[],
                names_which_can_be_offloaded=["residual"],
                offload_src="device", offload_dst="pinned_host")
            return jax.checkpoint(body, policy=pol)
        raise ValueError(f"unknown remat mode {self.remat!r}")

    # ------------------------------------------------------------- params
    def init(self, key: Array) -> dict:
        cfg = self.cfg
        dt = self.dtype
        kE, kL, kS, kF = jax.random.split(key, 4)
        Vp, D = cfg.padded_vocab, cfg.d_model
        params: dict[str, Any] = {
            "embed": (jax.random.normal(kE, (Vp, D), dt) * 0.02),
            "unembed": (jax.random.normal(kF, (D, Vp), dt)
                        / math.sqrt(D)),
        }
        params.update(_with_prefix("ln_f", _norm_init(cfg, D, dt)))
        if cfg.family in ("dense", "moe"):
            keys = jax.random.split(kL, cfg.n_layers)
            params["layers"] = jax.vmap(
                lambda k: self._layer_init(k))(keys)
        elif cfg.family == "rwkv":
            keys = jax.random.split(kL, cfg.n_layers)
            params["layers"] = jax.vmap(
                lambda k: self._rwkv_layer_init(k))(keys)
        elif cfg.family == "zamba":
            ng, grp, tail = self._zamba_split()
            kG, kT, kSh, kAd = jax.random.split(kS, 4)
            gkeys = jax.random.split(kG, ng * grp).reshape(ng, grp, 2)
            params["mamba"] = jax.vmap(jax.vmap(
                lambda k: S.ssd_init(
                    k, cfg.d_model, d_state=cfg.ssm_state,
                    headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                    dtype=dt)))(gkeys)
            if tail:
                tkeys = jax.random.split(kT, tail)
                params["mamba_tail"] = jax.vmap(
                    lambda k: S.ssd_init(
                        k, cfg.d_model, d_state=cfg.ssm_state,
                        headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                        dtype=dt))(tkeys)
            params["shared"] = self._layer_init(kSh)
            # per-invocation adapter: input-norm gains (Zamba2's per-call
            # LoRA simplified to per-call scale; DESIGN.md §7)
            params["shared_adapters"] = jnp.ones((ng, D), dt)
        else:
            raise ValueError(cfg.family)
        return params

    def _layer_init(self, key: Array) -> dict:
        cfg = self.cfg
        dt = self.dtype
        k1, k2 = jax.random.split(key)
        spec = L.AttnParamsSpec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.d_head, cfg.qkv_bias)
        p = {"attn": spec.init(k1, dt)}
        p.update(_with_prefix("ln1", _norm_init(cfg, cfg.d_model, dt)))
        p.update(_with_prefix("ln2", _norm_init(cfg, cfg.d_model, dt)))
        if cfg.family == "moe" :
            p["moe"] = L.moe_init(k2, cfg.d_model, cfg.d_ff, cfg.n_experts, dt)
        else:
            p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dt,
                                  bias=(cfg.mlp == "gelu"))
        return p

    def _rwkv_layer_init(self, key: Array) -> dict:
        cfg = self.cfg
        p = R.rwkv6_init(key, cfg.d_model, headdim=cfg.rwkv_headdim,
                         d_ff=cfg.d_ff, dtype=self.dtype)
        p.update(_with_prefix("ln1", _norm_init(cfg, cfg.d_model, self.dtype)))
        p.update(_with_prefix("ln2", _norm_init(cfg, cfg.d_model, self.dtype)))
        return p

    def _zamba_split(self) -> tuple[int, int, int]:
        grp = self.cfg.zamba_group
        ng = self.cfg.n_layers // grp
        tail = self.cfg.n_layers - ng * grp
        return ng, grp, tail

    # ------------------------------------------------------------ blocks
    def _attn_mlp_block(self, p: dict, h: Array, positions: Array,
                        adapter_g: Array | None = None) -> Array:
        cfg = self.cfg
        x = _norm(cfg, p, "ln1", h)
        if adapter_g is not None:
            x = x * adapter_g
        h = h + L.attention_block(
            p["attn"], x, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head, positions=positions,
            rope_theta=cfg.rope_theta, block_kv=self.block_kv)
        x = _norm(cfg, p, "ln2", h)
        if "moe" in p:
            y, aux = L.moe_block(p["moe"], x, n_experts=cfg.n_experts,
                                 top_k=cfg.top_k,
                                 capacity_factor=self.moe_capacity_factor)
            self._aux = self._aux + aux
        else:
            y = (L.swiglu_mlp(p["mlp"], x) if cfg.mlp == "swiglu"
                 else L.gelu_mlp(p["mlp"], x))
        return h + y

    # ------------------------------------------------------------- apply
    def apply(self, params: dict, tokens: Array, *,
              vision_embeds: Array | None = None) -> Array:
        """Full forward: [B, S_text] (+ optional prepended frontend embeds)
        → logits [B, S, padded_vocab]. Also sets ``self._aux`` (MoE)."""
        cfg = self.cfg
        self._aux = jnp.zeros((), jnp.float32)
        h = jnp.take(params["embed"], tokens, axis=0)
        if vision_embeds is not None:
            h = jnp.concatenate([vision_embeds.astype(h.dtype), h], axis=1)
        h = constrain(h, ("pod", "data"), None, None)
        B, Stot, D = h.shape
        positions = jnp.broadcast_to(jnp.arange(Stot)[None], (B, Stot))

        if cfg.family in ("dense", "moe"):
            def body(carry, lp):
                hh, aux = carry
                self._aux = jnp.zeros((), jnp.float32)
                hh = self._attn_mlp_block(lp, hh, positions)
                hh = constrain(hh, ("pod", "data"), "model", None)  # SP
                hh = jax.ad_checkpoint.checkpoint_name(hh, "residual")
                return (hh, aux + self._aux), None
            (h, aux), _ = jax.lax.scan(self._wrap_remat(body),
                                       (h, self._aux), params["layers"])
            self._aux = aux
        elif cfg.family == "rwkv":
            def body(hh, lp):
                x = _norm(cfg, lp, "ln1", hh)
                hh = hh + R.rwkv6_time_mix(lp, x, headdim=cfg.rwkv_headdim)
                x = _norm(cfg, lp, "ln2", hh)
                hh = hh + R.rwkv6_channel_mix(lp, x)
                hh = constrain(hh, ("pod", "data"), "model", None)  # SP
                hh = jax.ad_checkpoint.checkpoint_name(hh, "residual")
                return hh, None
            h, _ = jax.lax.scan(self._wrap_remat(body), h, params["layers"])
        elif cfg.family == "zamba":
            ng, grp, tail = self._zamba_split()
            def mamba_body(hh, lp):
                hh = hh + S.ssd_block(
                    lp, hh, d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
                    expand=cfg.ssm_expand)
                hh = constrain(hh, ("pod", "data"), "model", None)  # SP
                hh = jax.ad_checkpoint.checkpoint_name(hh, "residual")
                return hh, None
            mamba_body = self._wrap_remat(mamba_body)
            shared_block = self._attn_mlp_block
            if self.remat is not None:
                shared_block = jax.checkpoint(
                    shared_block, static_argnums=())
            for g in range(ng):
                gp = jax.tree.map(lambda a: a[g], params["mamba"])
                h = shared_block(
                    params["shared"], h, positions,
                    adapter_g=params["shared_adapters"][g])
                h, _ = jax.lax.scan(mamba_body, h, gp)
            if tail:
                h, _ = jax.lax.scan(mamba_body, h, params["mamba_tail"])
        else:
            raise ValueError(cfg.family)

        h = _norm(cfg, params, "ln_f", h)
        logits = h @ params["unembed"]
        return constrain(logits, ("pod", "data"), None, "model")

    # -------------------------------------------------------------- loss
    def loss(self, params: dict, batch: dict) -> Array:
        cfg = self.cfg
        logits = self.apply(params, batch["tokens"],
                            vision_embeds=batch.get("vision_embeds"))
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:     # frontend tokens: no loss
            logits = logits[:, logits.shape[1] - labels.shape[1]:]
        logits = logits.astype(jnp.float32)
        # mask the vocab padding so the softmax is over the true vocab
        iota = jax.lax.broadcasted_iota(jnp.int32, (cfg.padded_vocab,), 0)
        logits = logits + jnp.where(iota < cfg.vocab_size, 0.0, -1e30)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
        loss = jnp.mean(nll)
        if cfg.family == "moe":
            loss = loss + 0.01 * self._aux / cfg.n_layers
        return loss

    # ------------------------------------------------------------- decode
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = self.dtype
        Dh, K = cfg.d_head, cfg.n_kv_heads
        if cfg.family in ("dense", "moe"):
            if self.kv_cache_dtype == "int8":
                # per-(token, head) scales — KIVI-style post-RoPE int8 KV;
                # halves the decode memory term (§Perf iteration A2)
                return {
                    "k": jnp.zeros((cfg.n_layers, batch, max_len, K, Dh),
                                   jnp.int8),
                    "v": jnp.zeros((cfg.n_layers, batch, max_len, K, Dh),
                                   jnp.int8),
                    "k_scale": jnp.zeros((cfg.n_layers, batch, max_len, K),
                                         jnp.float32),
                    "v_scale": jnp.zeros((cfg.n_layers, batch, max_len, K),
                                         jnp.float32),
                }
            return {
                "k": jnp.zeros((cfg.n_layers, batch, max_len, K, Dh), dt),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, K, Dh), dt),
            }
        if cfg.family == "rwkv":
            H = cfg.d_model // cfg.rwkv_headdim
            P = cfg.rwkv_headdim
            Lh = cfg.n_layers
            return {
                "tm_shift": jnp.zeros((Lh, batch, 1, cfg.d_model), dt),
                "cm_shift": jnp.zeros((Lh, batch, 1, cfg.d_model), dt),
                "wkv": jnp.zeros((Lh, batch, H, P, P), jnp.float32),
            }
        if cfg.family == "zamba":
            ng, grp, tail = self._zamba_split()
            di = cfg.ssm_expand * cfg.d_model
            H = di // cfg.ssm_headdim
            convdim = di + 2 * cfg.ssm_state
            cache = {
                "ssm": jnp.zeros((ng, grp, batch, H, cfg.ssm_headdim,
                                  cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((ng, grp, batch, 3, convdim), dt),
                "k": jnp.zeros((ng, batch, max_len, K, Dh), dt),
                "v": jnp.zeros((ng, batch, max_len, K, Dh), dt),
            }
            if tail:
                cache["ssm_tail"] = jnp.zeros(
                    (tail, batch, H, cfg.ssm_headdim, cfg.ssm_state),
                    jnp.float32)
                cache["conv_tail"] = jnp.zeros((tail, batch, 3, convdim), dt)
            return cache
        raise ValueError(cfg.family)

    def prefill(self, params: dict, tokens: Array, lengths: Array
                ) -> tuple[Array, dict]:
        """Batched prompt ingestion: ONE forward over [B, S] instead of
        token-by-token teacher forcing. Returns ``(last_logits, kv)`` where
        ``last_logits`` [B, padded_vocab] are the logits at each row's last
        prompt token (position ``lengths - 1``) and ``kv``'s leaves are
        stacked [L, B, S, ...] in ``init_cache`` layout over the token
        slice [0, S) — the serving engine scatters them into its paged
        cache. Rows may be ragged: positions past a row's length produce
        junk K/V that later per-row ``cache_len`` masking never attends.

        Attention families only (dense / moe): recurrent families carry
        per-step state, so their prompt pass *is* the decode loop."""
        cfg = self.cfg
        if cfg.family not in ("dense", "moe"):
            raise ValueError("prefill supports attention families only "
                             f"(got {cfg.family!r})")
        B, S = tokens.shape
        h = jnp.take(params["embed"], tokens, axis=0)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(hh, lp):
            x = _norm(cfg, lp, "ln1", hh)
            pa = lp["attn"]
            q = (x @ pa["wq"] + pa.get("bq", 0)).reshape(
                B, S, cfg.n_heads, cfg.d_head)
            k = (x @ pa["wk"] + pa.get("bk", 0)).reshape(
                B, S, cfg.n_kv_heads, cfg.d_head)
            v = (x @ pa["wv"] + pa.get("bv", 0)).reshape(
                B, S, cfg.n_kv_heads, cfg.d_head)
            if cfg.rope_theta:
                q = L.rope(q, positions, cfg.rope_theta)
                k = L.rope(k, positions, cfg.rope_theta)
            o = L.blockwise_attention(q, k, v, causal=True,
                                      block_kv=self.block_kv)
            hh = hh + o.reshape(B, S, cfg.n_heads * cfg.d_head) @ pa["wo"]
            x = _norm(cfg, lp, "ln2", hh)
            if "moe" in lp:
                y, _ = L.moe_block(lp["moe"], x, n_experts=cfg.n_experts,
                                   top_k=cfg.top_k, capacity_factor=None)
            else:
                y = (L.swiglu_mlp(lp["mlp"], x) if cfg.mlp == "swiglu"
                     else L.gelu_mlp(lp["mlp"], x))
            return hh + y, (k, v)

        h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
        h = _norm(cfg, params, "ln_f", h)
        last = h[jnp.arange(B), jnp.maximum(lengths - 1, 0)]      # [B, D]
        logits = last @ params["unembed"]
        if self.kv_cache_dtype == "int8":
            kq, ksc = _quant_int8(ks)
            vq, vsc = _quant_int8(vs)
            kv = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
        else:
            kv = {"k": ks, "v": vs}
        return logits, kv

    def _attn_decode_block(self, p: dict, h: Array, kc: Array, vc: Array,
                           lens: Array, adapter_g: Array | None = None,
                           k_sc: Array | None = None,
                           v_sc: Array | None = None,
                           active: Array | None = None):
        """One-token attention + MLP. ``lens`` is the per-row cache length
        [B] (each row writes this token at its own position — a serving
        batch is ragged). ``active`` [B] bool: rows that are False leave
        their cache extent untouched (inert padding / swapped-out slots)."""
        cfg = self.cfg
        B = h.shape[0]
        rows = jnp.arange(B)
        x = _norm(cfg, p, "ln1", h)
        if adapter_g is not None:
            x = x * adapter_g
        pa = p["attn"]
        q = (x @ pa["wq"] + pa.get("bq", 0)).reshape(
            B, 1, cfg.n_heads, cfg.d_head)
        k = (x @ pa["wk"] + pa.get("bk", 0)).reshape(
            B, 1, cfg.n_kv_heads, cfg.d_head)
        v = (x @ pa["wv"] + pa.get("bv", 0)).reshape(
            B, 1, cfg.n_kv_heads, cfg.d_head)
        pos = lens[:, None]
        if cfg.rope_theta:
            q = L.rope(q, pos, cfg.rope_theta)
            k = L.rope(k, pos, cfg.rope_theta)

        def put(buf: Array, upd: Array) -> Array:
            """Scatter ``upd`` [B, 1, ...] at each row's own position;
            inert rows rewrite their previous cell (a no-op by value)."""
            u = upd[:, 0]
            if active is not None:
                old = buf[rows, lens]
                u = jnp.where(
                    active.reshape((B,) + (1,) * (u.ndim - 1)), u, old)
            return buf.at[rows, lens].set(u)

        if k_sc is not None:
            kq, ks = _quant_int8(k)
            vq, vs = _quant_int8(v)
            kc = put(kc, kq)
            vc = put(vc, vq)
            k_sc = put(k_sc, ks)
            v_sc = put(v_sc, vs)
            o = L.decode_attention_q8(q, kc, vc, k_sc, v_sc, lens + 1)
            h = h + o.reshape(B, 1, cfg.n_heads * cfg.d_head) @ pa["wo"]
            x = _norm(cfg, p, "ln2", h)
            if "moe" in p:
                y, _ = L.moe_block(p["moe"], x, n_experts=cfg.n_experts,
                                   top_k=cfg.top_k, capacity_factor=None)
            else:
                y = (L.swiglu_mlp(p["mlp"], x) if cfg.mlp == "swiglu"
                     else L.gelu_mlp(p["mlp"], x))
            return h + y, kc, vc, k_sc, v_sc
        kc = put(kc, k)
        vc = put(vc, v)
        o = L.decode_attention(q, kc, vc, lens + 1)
        h = h + o.reshape(B, 1, cfg.n_heads * cfg.d_head) @ pa["wo"]
        x = _norm(cfg, p, "ln2", h)
        if "moe" in p:
            y, _ = L.moe_block(p["moe"], x, n_experts=cfg.n_experts,
                               top_k=cfg.top_k, capacity_factor=None)
        else:
            y = (L.swiglu_mlp(p["mlp"], x) if cfg.mlp == "swiglu"
                 else L.gelu_mlp(p["mlp"], x))
        return h + y, kc, vc

    def decode_step(self, params: dict, cache: dict, token: Array,
                    cache_len: Array, active: Array | None = None
                    ) -> tuple[Array, dict]:
        """One-token decode. token: [B, 1] → logits [B, padded_vocab].

        ``cache_len`` may be a scalar (all rows at the same depth — the
        simple generate loop) or per-row [B] (a ragged continuous-batching
        step). ``active`` is an optional [B] bool mask: rows that are False
        write nothing into the cache, so padding / swapped-out slots cannot
        perturb live rows; their logits are garbage and the caller must
        ignore them. The mask is only supported for the attention families
        — recurrent state (rwkv / zamba SSM) advances unconditionally."""
        cfg = self.cfg
        B = token.shape[0]
        lens = jnp.broadcast_to(
            jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))
        if active is not None and cfg.family not in ("dense", "moe"):
            raise ValueError(
                "active-row masking requires a KV-cache family (dense/moe)")
        h = jnp.take(params["embed"], token, axis=0)       # [B,1,D]

        if cfg.family in ("dense", "moe"):
            if self.kv_cache_dtype == "int8":
                def body8(carry, xs):
                    hh = carry
                    lp, kc, vc, ksc, vsc = xs
                    hh, kc, vc, ksc, vsc = self._attn_decode_block(
                        lp, hh, kc, vc, lens, k_sc=ksc, v_sc=vsc,
                        active=active)
                    return hh, (kc, vc, ksc, vsc)
                h, (ks, vs, kss, vss) = jax.lax.scan(
                    body8, h, (params["layers"], cache["k"], cache["v"],
                               cache["k_scale"], cache["v_scale"]))
                cache = {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
            else:
                def body(carry, xs):
                    hh = carry
                    lp, kc, vc = xs
                    hh, kc, vc = self._attn_decode_block(lp, hh, kc, vc,
                                                         lens, active=active)
                    return hh, (kc, vc)
                h, (ks, vs) = jax.lax.scan(
                    body, h, (params["layers"], cache["k"], cache["v"]))
                cache = {"k": ks, "v": vs}
        elif cfg.family == "rwkv":
            def body(hh, xs):
                lp, tms, cms, wkv = xs
                x = _norm(cfg, lp, "ln1", hh)
                o, (tms2, wkv2) = R.rwkv6_time_mix(
                    lp, x, headdim=cfg.rwkv_headdim, state=(tms, wkv))
                hh = hh + o
                x = _norm(cfg, lp, "ln2", hh)
                o, cms2 = R.rwkv6_channel_mix(lp, x, state=cms)
                hh = hh + o
                return hh, (tms2, cms2, wkv2)
            h, (tms, cms, wkv) = jax.lax.scan(
                body, h, (params["layers"], cache["tm_shift"],
                          cache["cm_shift"], cache["wkv"]))
            cache = {"tm_shift": tms, "cm_shift": cms, "wkv": wkv}
        elif cfg.family == "zamba":
            ng, grp, tail = self._zamba_split()

            def mamba_scan_body(hh, xs):
                lp, st, cs = xs
                o, (st2, cs2) = S.ssd_block(
                    lp, hh, d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
                    expand=cfg.ssm_expand, state=st, conv_state=cs)
                return hh + o, (st2, cs2)

            def group_body(carry, xs):
                hh = carry
                gp, adapters, kc, vc, sst, cst = xs
                hh, kc, vc = self._attn_decode_block(
                    params["shared"], hh, kc, vc, lens,
                    adapter_g=adapters)
                hh, (sst2, cst2) = jax.lax.scan(
                    mamba_scan_body, hh, (gp, sst, cst))
                return hh, (kc, vc, sst2, cst2)

            h, (ks, vs, sss, css) = jax.lax.scan(
                group_body, h,
                (params["mamba"], params["shared_adapters"],
                 cache["k"], cache["v"], cache["ssm"], cache["conv"]))
            new_cache = {"ssm": sss, "conv": css, "k": ks, "v": vs}
            if tail:
                h, (sst, cst) = jax.lax.scan(
                    mamba_scan_body, h,
                    (params["mamba_tail"], cache["ssm_tail"],
                     cache["conv_tail"]))
                new_cache["ssm_tail"] = sst
                new_cache["conv_tail"] = cst
            cache = new_cache
        else:
            raise ValueError(cfg.family)

        h = _norm(cfg, params, "ln_f", h)
        logits = (h @ params["unembed"])[:, 0]
        return logits, cache
