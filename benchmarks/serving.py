"""Serving benchmark: continuous-batching decode under KV-cache CPU offload.

Two questions, matching the paper's claims transplanted to online decode:

1. **Do transfers overlap?** Decode throughput with cold-block offload
   enabled (mirroring on the dedicated d2h stream) must stay within ~1.3×
   of the no-offload engine even when ≥ 50% of KV bytes move to host RAM —
   transfers ride their own engine class and never block a step (§5).
2. **Does reload order matter?** With preemption forcing swap/reload
   cycles, the ``fixed`` (block-creation-order) reload schedule suffers
   head-of-line blocking, while runtime-chosen orders (``random``,
   ``critical-path``) resume requests sooner (§8's ablation, serving
   edition). Wire time is simulated on the DMA threads (slow-link profile)
   exactly like the threaded-runtime benchmark's injected latencies.

CSV contract: ``name,us_per_call,derived`` via :func:`benchmarks.common.emit`.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                     # noqa: E402
import numpy as np                                             # noqa: E402

from repro.configs.base import ArchConfig                      # noqa: E402
from repro.models import build_model                           # noqa: E402
from repro.serve import (Engine, RELOAD_POLICY_NAMES,          # noqa: E402
                         ServeConfig)

from .common import emit                                       # noqa: E402

ARCH = ArchConfig(name="serve-demo", family="dense", n_layers=4,
                  d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                  vocab_size=512, dtype="float32")
MAX_LEN = 256
BLOCK = 16


def _workload(rng: np.random.Generator, n: int):
    return [list(rng.integers(1, ARCH.vocab_size, rng.integers(40, 65)))
            for _ in range(n)]


def _run(model, params, prompts, cfg: ServeConfig, max_new: int):
    from repro.serve import ServeStats
    eng = Engine(model, params, cfg)
    # warm the per-engine jit caches (prefill shapes + decode bucket) so
    # measured time is steady-state serving, not XLA tracing
    eng.generate(prompts, max_new=2)
    eng.stats = ServeStats()
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=max_new)
    wall = time.perf_counter() - t0
    return out, eng.stats, wall


def run(quick: bool = True) -> None:
    rng = np.random.default_rng(0)
    model = build_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    n_req, max_new = (6, 24) if quick else (16, 48)
    prompts = _workload(rng, n_req)

    # ---- 1. throughput vs offload fraction (no preemption: pure overlap).
    # Configs are interleaved and best-of-N per config: wall-clock decode
    # on a shared CPU drifts, and the signal is the *ratio*.
    def offload_cfg(frac):
        return ServeConfig(max_len=MAX_LEN, batch_buckets=(1, 2, 4),
                           block_size=BLOCK, offload=True, hot_window=0,
                           offload_fraction=frac)
    grid: dict[str, ServeConfig] = {
        "no_offload": ServeConfig(max_len=MAX_LEN, batch_buckets=(1, 2, 4),
                                  block_size=BLOCK),
        "offload_frac0.6": offload_cfg(0.6),
        "offload_frac1": offload_cfg(1.0),
    }
    best: dict[str, tuple] = {}
    for _ in range(2 if quick else 3):
        for name, cfg in grid.items():
            out, st, _ = _run(model, params, prompts, cfg, max_new)
            if name not in best or st.decode_tok_s > best[name][1].decode_tok_s:
                best[name] = (out, st)
    ref_out, ref_stats = best["no_offload"]
    ref_rate = ref_stats.decode_tok_s
    emit("serving/decode/no_offload",
         1e6 / max(ref_rate, 1e-9), f"tok_s={ref_rate:.1f}")
    for name in ("offload_frac0.6", "offload_frac1"):
        out, st = best[name]
        rate = st.decode_tok_s
        ratio = ref_rate / max(rate, 1e-9)
        emit(f"serving/decode/{name}",
             1e6 / max(rate, 1e-9),
             f"tok_s={rate:.1f};kv_frac={st.offloaded_fraction:.2f};"
             f"slowdown_x{ratio:.2f};exact={out == ref_out}")

    # ---- 2. reload-order policy sweep (preemption forces swap/reloads;
    #         slow simulated link makes ordering consequential)
    sweep_kw = dict(max_len=MAX_LEN, batch_buckets=(1, 2), block_size=BLOCK,
                    offload=True, hot_window=BLOCK, preempt_every=4,
                    h2d_bw=60e6, d2h_bw=60e6, dma_latency=200e-6)
    makespans: dict[str, float] = {}
    for policy in RELOAD_POLICY_NAMES:
        best = None
        for _ in range(1 if quick else 3):
            out, st, wall = _run(model, params, prompts,
                                 ServeConfig(reload_policy=policy,
                                             **sweep_kw), max_new)
            if best is None or wall < best[2]:
                best = (out, st, wall)
        out, st, wall = best
        makespans[policy] = wall
        # greedy tokens are engine-config-independent: every policy must
        # reproduce part 1's no-offload output exactly
        emit(f"serving/reload_policy/{policy}", wall * 1e6,
             f"swaps={st.swaps};stall_ms={st.stall_time*1e3:.1f};"
             f"reload_MB={st.reload_bytes/2**20:.1f};"
             f"exact={out == ref_out}")
    nondet = min(makespans["random"], makespans["critical-path"])
    emit("serving/reload_policy/fixed_vs_nondet_x", 0.0,
         f"{makespans['fixed'] / max(nondet, 1e-9):.2f}")


if __name__ == "__main__":
    run(quick=os.environ.get("QUICK", "1") != "0")
