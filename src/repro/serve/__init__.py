"""Online serving: continuous batching + block-paged KV-cache CPU offload."""
from .engine import (Engine, ServeConfig, Request, ServeStats,
                     ReloadPolicy, RELOAD_POLICY_NAMES, get_reload_policy,
                     naive_generate)
from .kv_cache import PagedKVCache

__all__ = ["Engine", "ServeConfig", "Request", "ServeStats", "ReloadPolicy",
           "RELOAD_POLICY_NAMES", "get_reload_policy", "naive_generate",
           "PagedKVCache"]
