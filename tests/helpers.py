"""Shared test fixtures: the paper's Fig. 3 TASKGRAPH and random graphs."""
import numpy as np

from repro.core import TaskGraph


def fig3_taskgraph(shape=(4, 4)):
    """The paper's running example: 3-device matmul decomposition."""
    tg = TaskGraph()
    A = tg.add_input(0, shape, name="A")
    B = tg.add_input(0, shape, name="B")
    C = tg.add_input(1, shape, name="C")
    D = tg.add_input(1, shape, name="D")
    v1 = tg.add_compute(0, (A, B), shape, op="matmul", name="1")
    v2 = tg.add_compute(0, (A, B), shape, op="matmul_t", name="2")
    v5 = tg.add_compute(1, (C, D), shape, op="matmul", name="5")
    v6 = tg.add_compute(1, (C, D), shape, op="matmul_t", name="6")
    t25 = tg.add_transfer(1, v2)
    t61 = tg.add_transfer(0, v6)
    v3 = tg.add_compute(0, (v1, t61), shape, op="add", name="3")
    v7 = tg.add_compute(1, (v5, t25), shape, op="add", name="7")
    t7 = tg.add_transfer(2, v7)
    v4 = tg.add_compute(0, (v3, t61), shape, op="mul", name="4")
    v8 = tg.add_compute(0, (v4, v3), shape, op="mul", name="8")
    return tg


def int_inputs(tg, seed=0, lo=-3, hi=4, dtype=np.float64):
    """Integer-valued inputs → float ops are exact → bitwise order-invariance."""
    rng = np.random.default_rng(seed)
    from repro.core import OpKind
    return {t: rng.integers(lo, hi, v.out.shape).astype(dtype)
            for t, v in tg.vertices.items() if v.kind == OpKind.INPUT}
