"""Online serving at example scale: a request queue drains through the
continuous-batching engine while the KV cache pages cold blocks to host
RAM — the paper's §9 "static graphs only" limitation turned into the
serving design (pre-compiled bucketed decode plans + MEMGRAPH-style static
block extents + transfers on dedicated DMA streams).

    PYTHONPATH=src python examples/continuous_batching.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import build_model
from repro.serve import Engine, ServeConfig, naive_generate


def main() -> None:
    cfg = ArchConfig(name="demo-8m", family="dense", n_layers=4,
                     d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                     vocab_size=512, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [list(rng.integers(1, cfg.vocab_size, rng.integers(8, 40)))
               for _ in range(6)]

    serve_cfg = ServeConfig(
        max_len=128, batch_buckets=(1, 2, 4), block_size=16,
        offload=True, hot_window=16,      # mirror cold KV blocks to host
        preempt_every=6,                  # time-slice so waiters get in
    )
    eng = Engine(model, params, serve_cfg)
    outs = eng.generate(prompts, max_new=16)

    print("request  prompt_len  tokens (first 8)")
    ok = True
    for i, (p, o) in enumerate(zip(prompts, outs)):
        ref = naive_generate(model, params, p, max_new=16, max_len=128,
                             rid=i)
        ok &= o == ref
        print(f"{i:7d} {len(p):11d}  {o[:8]}")

    st = eng.stats
    print(f"\nmatches unbatched oracle: {ok}")
    print(f"decode steps {st.decode_steps}, tokens {st.tokens} "
          f"({st.decode_tok_s:.0f} tok/s), swaps {st.swaps}")
    print(f"d2h offload traffic {st.offload_bytes / 2**20:.2f} MiB "
          f"({st.offloaded_fraction:.0%} of the KV bytes produced — "
          f"swap thrash can push this past 100%), h2d reload traffic "
          f"{st.reload_bytes / 2**20:.2f} MiB — all on DMA streams; "
          f"decode stalled {st.stall_time * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
