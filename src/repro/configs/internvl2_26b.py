"""internvl2-26b [vlm]: InternViT frontend (stubbed) + InternLM2-20B-style
LM backbone. [arXiv:2404.16821; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92553,
    norm="rmsnorm", mlp="swiglu", qkv_bias=False, rope_theta=1e6,
    frontend="vit", n_frontend_tokens=256,
    source="arXiv:2404.16821; hf",
)
