"""The TURNIP execution engine (paper §5, §B).

Executes a compiled MEMGRAPH with a *nondeterministic, event-driven* loop:
whenever a vertex's dependencies are complete and an engine on its device is
free, it may be launched — in any order. Memory management is entirely
static: every vertex reads/writes the extents assigned at compile time; there
are no malloc/free calls during execution (paper §5).

Components:

* storage tiers — :mod:`~repro.core.stores`: :class:`HostStore` (the pinned
  host arena, paper §B ``cudaHostAlloc``) and :class:`TieredStore` (bounded
  host RAM backed by a file-based disk tier, DESIGN.md §10). Plans whose
  compiler emitted SPILL/LOAD vertices automatically execute over a
  :class:`TieredStore`.
* memory backends — :class:`SlotTable` (validating: reads require the exact
  planned extent to hold live data, so *any* race or planning bug surfaces as
  a hard error; used by the property tests) and :class:`ByteArena` (a real
  preallocated byte buffer per device, demonstrating static placement).
* :func:`run_in_order` — single-threaded reference interpreter executing an
  arbitrary caller-supplied topological order (the property-test workhorse:
  every valid order must give identical outputs).
* :class:`TurnipRuntime` — a facade over the unified executor core
  (:mod:`~repro.core.executor`, DESIGN.md §17): ONE ready-set/dispatch
  kernel behind three interchangeable backends. Certified-STATIC regions
  of a compiled plan run straight-line (:class:`StaticExecutor`); large
  nondet windows run on the threaded engine-stream fleet
  (:class:`ThreadedExecutor` — per-device compute pools plus dedicated
  DMA/disk streams, condition-variable wakeups, no polling); small
  nondet seams run thread-free on the calling thread
  (:class:`InlineExecutor` — same dispatch freedom, zero OS wakeups).
  Ready vertices are ranked by a pluggable
  :class:`~repro.core.dispatch.DispatchPolicy`; ``mode='fixed'``
  reproduces the paper's ablation: vertices are *issued* strictly in the
  compile-time simulation order (head-of-line blocking), though still
  asynchronous once issued.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable

import numpy as np

from . import liveness as _lv
from .build import BuildResult
from .dispatch import COMPUTE, DispatchPolicy, TRANSFER_KINDS, get_policy
from .executor import (INLINE, THREADED, ExecContext, InlineExecutor,
                       StaticExecutor, ThreadedExecutor, _exec_vertex)
from .memgraph import Loc, MemGraph, MemOp, MemVertex, RaceError
from .ops import get_op
from .pool import HostPool, Lease
from .stores import DiskStore, HostStore, TieredStore
from .taskgraph import OpKind, TaskGraph

__all__ = ["HostStore", "DiskStore", "TieredStore", "SlotTable", "ByteArena",
           "run_in_order", "TurnipRuntime", "RunResult", "make_store",
           "replay_stall"]


def make_store(mg: MemGraph, inputs: dict[int, np.ndarray], *,
               lease=None) -> HostStore:
    """The store a plan needs: a plain :class:`HostStore`, or — when the
    compiler emitted disk-tier SPILL/LOAD vertices — a :class:`TieredStore`
    whose spills actually hit files. The caller owns ``close()``.

    ``lease``: a :class:`~repro.core.pool.Lease` when the plan's host
    copies live in a shared arbitrated pool (DESIGN.md §12) — occupancy
    is mirrored into the lease so the arbiter sees this consumer's
    pressure. A leased store is always tiered (even for plans with no
    disk vertices) so occupancy accounting rides the same hooks."""
    if lease is not None or any(v.op in (MemOp.SPILL, MemOp.LOAD)
                                for v in mg.vertices.values()):
        # capacity enforcement lives in the plan (auto_spill off): the
        # SPILL/LOAD vertices are the Belady-chosen tier traffic
        return TieredStore(inputs, auto_spill=False, lease=lease)
    return HostStore(inputs)


# --------------------------------------------------------------------------
# memory backends
# --------------------------------------------------------------------------
class SlotTable:
    """Validating memory model: an extent holds a value only between a write
    and the next overlapping write. Reading a missing/clobbered extent raises
    :class:`RaceError` — this is how the tests prove race-freedom."""

    def __init__(self) -> None:
        self._mem: dict[int, dict[tuple[int, int], np.ndarray]] = {}
        self._lock = threading.Lock()

    def write(self, loc: Loc, value: np.ndarray) -> None:
        with self._lock:
            dev = self._mem.setdefault(loc.device, {})
            span = (loc.offset, loc.size)
            for (o, s) in list(dev):
                if o < loc.offset + loc.size and loc.offset < o + s \
                        and (o, s) != span:
                    del dev[(o, s)]
            dev[span] = value

    def read(self, loc: Loc) -> np.ndarray:
        with self._lock:
            dev = self._mem.get(loc.device, {})
            try:
                return dev[(loc.offset, loc.size)]
            except KeyError:
                raise RaceError(
                    f"read of dead/clobbered extent {loc} — racy order or "
                    f"bad memory plan") from None

    def drop(self, loc: Loc) -> None:
        with self._lock:
            self._mem.get(loc.device, {}).pop((loc.offset, loc.size), None)


class ByteArena:
    """Real static placement: one preallocated buffer per device; extents are
    byte ranges (requires the MEMGRAPH to have been built with byte sizes)."""

    def __init__(self, capacities: dict[int, int]) -> None:
        self.bufs = {d: np.zeros(c, np.uint8) for d, c in capacities.items()}
        self.specs: dict[tuple[int, int, int], tuple] = {}
        self._lock = threading.Lock()

    def write(self, loc: Loc, value: np.ndarray) -> None:
        raw = np.ascontiguousarray(value).view(np.uint8).reshape(-1)
        if raw.nbytes > loc.size:
            raise RaceError(f"value of {raw.nbytes}B exceeds extent {loc}")
        # buffer bytes and spec must move together: a reader holding the lock
        # must never see a new spec over stale bytes (or vice versa).
        with self._lock:
            self.bufs[loc.device][loc.offset:loc.offset + raw.nbytes] = raw
            self.specs[(loc.device, loc.offset, loc.size)] = \
                (value.shape, value.dtype, raw.nbytes)

    def read(self, loc: Loc) -> np.ndarray:
        with self._lock:
            try:
                spec = self.specs[(loc.device, loc.offset, loc.size)]
            except KeyError:
                raise RaceError(
                    f"read of unwritten/dropped extent {loc} — racy order "
                    f"or bad memory plan") from None
            shape, dtype, nbytes = spec
            raw = self.bufs[loc.device][loc.offset:loc.offset + nbytes].copy()
        return raw.view(dtype).reshape(shape)

    def drop(self, loc: Loc) -> None:
        # Audit fix: this was a silent no-op, so a dropped extent stayed
        # readable and a use-after-free in a plan could never surface under
        # this backend. Invalidating the spec makes ByteArena match
        # SlotTable's read-validation contract (reads of dead extents raise
        # RaceError); the bytes themselves stay in the arena, as on real
        # hardware.
        with self._lock:
            self.specs.pop((loc.device, loc.offset, loc.size), None)


def _collect_outputs(tg: TaskGraph, res: BuildResult, mem,
                     host: HostStore) -> dict[int, np.ndarray]:
    outs: dict[int, np.ndarray] = {}
    for tid in tg.vertices:
        if not tg.consumers(tid):
            kind, ref = res.final_value_location(tid)
            if kind == "host":
                # peek reads through every tier (a terminal output may have
                # been spilled to disk) without counting reload traffic
                val = host.peek_offload(ref)
                outs[tid] = val if val is not None else host.inputs[tid]
            else:
                outs[tid] = mem.read(res.memgraph.vertices[ref].loc)
    return outs


def eval_taskgraph(tg: TaskGraph,
                   inputs: dict[int, np.ndarray]) -> dict[int, np.ndarray]:
    """Direct dataflow evaluation of a TASKGRAPH (no memory plan) — the
    ground-truth oracle the MEMGRAPH runtime must match in any order."""
    vals: dict[int, np.ndarray] = {}
    for tid in tg.topo_order():
        v = tg.vertices[tid]
        if v.kind == OpKind.INPUT:
            vals[tid] = np.asarray(inputs[tid])
        elif v.kind == OpKind.TRANSFER:
            vals[tid] = vals[v.inputs[0]]
        elif v.kind == OpKind.REDUCE:
            out = vals[v.inputs[0]]
            for i in v.inputs[1:]:
                out = out + vals[i]
            vals[tid] = out
        else:
            vals[tid] = np.asarray(
                get_op(v.op)(*[vals[i] for i in v.inputs], **v.params))
    return {t: vals[t] for t in tg.vertices if not tg.consumers(t)}


def run_in_order(tg: TaskGraph, res: BuildResult,
                 inputs: dict[int, np.ndarray],
                 order: list[int] | None = None) -> dict[int, np.ndarray]:
    """Reference interpreter: execute ``order`` (any topological order of the
    MEMGRAPH; defaults to the compile-time simulation order) sequentially.
    Raises :class:`RaceError` if the order violates the plan's memory safety
    — which, for orders respecting the dependencies, must never happen."""
    mg = res.memgraph
    if order is None:
        order = sorted(mg.vertices, key=lambda m: mg.vertices[m].seq)
    done: set[int] = set()
    for m in order:
        if any(p not in done for p in mg.preds[m]):
            raise ValueError(f"order is not topological at vertex {m}")
        done.add(m)
    host = make_store(mg, inputs)
    try:
        mem = SlotTable()
        for m in order:
            try:
                _exec_vertex(mg.vertices[m], mg, tg, mem, host)
            except RaceError as e:
                _certified_reraise(res, e)
        return _collect_outputs(tg, res, mem, host)
    finally:
        host.close()


def _certified_reraise(res: BuildResult, err: RaceError) -> None:
    """Debug hook (DESIGN.md §13): a plan the certifier proved clean must
    never race at runtime — if one does, either the certifier is unsound
    or an executor diverged from the plan. Surface that loudly instead of
    letting it read like an ordinary plan bug."""
    cert = getattr(res, "certificate", None)
    if cert is not None and getattr(cert, "ok", False):
        raise RaceError(
            f"{err} [plan was certified clean for ALL execution orders: "
            f"this RaceError means the certifier is unsound or the "
            f"runtime diverged from the plan — DESIGN.md §13]") from err
    raise err


# --------------------------------------------------------------------------
# threaded, event-driven runtime
# --------------------------------------------------------------------------
@dataclasses.dataclass
class RunResult:
    outputs: dict[int, np.ndarray]
    makespan: float
    busy: dict[int, float]               # per device: compute-engine seconds
    stall: dict[int, float]              # makespan - busy (per device)
    transfer_time: dict[str, float]      # per DMA/disk channel: busy seconds
    offload_bytes: int
    reload_bytes: int
    timeline: list[tuple[float, float, int, str, str]]  # t0,t1,dev,engine,name
    spans: dict[int, tuple[float, float]]  # mid -> (start, end) wall times
    disk_spill_bytes: int = 0            # host→disk tier traffic
    disk_load_bytes: int = 0             # disk→host tier traffic
    peak_host_bytes: int = 0             # host-tier occupancy high-water mark
    # compiled-backend counters (DESIGN.md §15): vertices executed by the
    # straight-line compiled program vs handed to the interpreter at
    # nondet-region seams, and fused DMA submissions issued. All zero
    # under the interpreted backend except n_interpreted = |V|.
    n_compiled: int = 0
    n_interpreted: int = 0
    fused_dma_batches: int = 0
    # seam-backend split (DESIGN.md §17): of the interpreted vertices,
    # how many ran on the thread-free inline executor vs the threaded
    # fleet. Invariant: n_inline + n_threaded == n_interpreted.
    n_inline: int = 0
    n_threaded: int = 0


class TurnipRuntime:
    """Event-driven nondeterministic executor (paper §5/§B).

    ``mode='nondet'`` — any ready vertex may launch on any free stream of its
    engine class (the paper's design); the *choice* among ready vertices is
    delegated to a :class:`~repro.core.dispatch.DispatchPolicy` (``policy``:
    ``random`` | ``fixed`` | ``critical-path`` | ``transfer-first`` or an
    instance). ``mode='fixed'`` — the ablation: vertices are issued in the
    compile-time simulation order with head-of-line blocking (still
    asynchronous once issued, matching the paper's "mostly removed"
    nondeterminism).

    Each device runs ``n_streams`` compute streams (paper: 5 CUDA streams
    per GPU) plus ``n_transfer_streams`` dedicated DMA streams for each of
    h2d/d2h/d2d — so transfers never occupy, nor wait behind, a compute
    stream.

    ``latency`` — optional ``fn(vertex) -> seconds`` injected as a sleep
    before the op runs; it occupies the vertex's stream for that long, which
    emulates slow PCIe transfers on this CPU-only container so scheduling
    choices have observable timing consequences.

    ``store_factory`` — optional ``fn(inputs) -> HostStore``; by default the
    runtime builds the store the plan needs (:func:`make_store`): a
    :class:`TieredStore` whenever the compiled plan contains disk-tier
    SPILL/LOAD vertices. Pass a factory to share a store or pin the disk
    directory; caller-supplied stores are not closed by the runtime.
    """

    def __init__(self, tg: TaskGraph, res: BuildResult, *,
                 n_streams: int = 5, n_transfer_streams: int = 1,
                 mode: str = "nondet",
                 policy: str | DispatchPolicy | None = None,
                 latency: Callable[[MemVertex], float] | None = None,
                 backend: str = "slots",
                 capacities: dict[int, int] | None = None,
                 store_factory: Callable[[dict], HostStore] | None = None,
                 host_lease=None,
                 seed: int | None = None,
                 exec_backend: str | None = None,
                 seam_backend: str = "auto") -> None:
        if mode not in ("nondet", "fixed"):
            raise ValueError(mode)
        if seam_backend not in ("auto", INLINE, THREADED):
            raise ValueError(f"unknown seam backend {seam_backend!r}")
        if host_lease is not None and store_factory is not None:
            raise ValueError("pass host_lease OR store_factory, not both "
                             "(attach the lease inside the factory instead)")
        self.tg, self.res, self.mg = tg, res, res.memgraph
        self.n_streams = n_streams
        self.n_transfer_streams = n_transfer_streams
        self.mode = mode
        self.policy = get_policy(policy, seed=seed)
        self.latency = latency
        self.backend = backend
        self.capacities = capacities
        self.store_factory = store_factory
        # executor backend (DESIGN.md §15): defaults to the plan's
        # BuildConfig.backend; `exec_backend` overrides per runtime (the
        # benchmarks compare both backends over one BuildResult). Note
        # `backend` above is the *memory* backend (slots|bytes) — a
        # distinct axis.
        self.exec_backend = (exec_backend if exec_backend is not None
                             else getattr(res, "backend", "interpreted"))
        if self.exec_backend not in ("interpreted", "compiled"):
            raise ValueError(f"unknown executor backend "
                             f"{self.exec_backend!r}")
        # seam backend (DESIGN.md §17): which executor runs a compiled
        # plan's NONDET regions. "auto" honours the compiler's per-region
        # hints (inline below BuildConfig.seam_threshold when certified,
        # threaded above); "inline"/"threaded" force one backend for every
        # seam (the differential harness's forced-backend lanes).
        self.seam_backend = seam_backend
        self._compiled = None          # lazily lowered CompiledPlan cache
        # shared-pool mode (DESIGN.md §12): the runtime-owned store joins
        # an arbitrated HostPool under this lease — occupancy is mirrored
        # so serving pressure and MEMGRAPH offload traffic meet one budget
        self.host_lease = host_lease
        # liveness assumption A1 (DESIGN.md §14): the proof bounded this
        # plan's occupancy by the lease's guaranteed share, so the pool
        # enforces it as a checked invariant from here on
        lcert = getattr(res, "liveness_certificate", None)
        if (host_lease is not None and lcert is not None
                and lcert.ok and lcert.pool is not None
                and lcert.pool.plan_lease == host_lease.name
                and lcert.guaranteed_units is not None):
            host_lease.certified_floor = lcert.guaranteed_units

    def run(self, inputs: dict[int, np.ndarray]) -> RunResult:
        mg = self.mg
        if self.backend == "bytes":
            if self.capacities is None:
                raise ValueError("ByteArena backend needs capacities")
            mem: Any = ByteArena(self.capacities)
        else:
            mem = SlotTable()
        owns_store = self.store_factory is None
        host = (make_store(mg, inputs, lease=self.host_lease) if owns_store
                else self.store_factory(inputs))
        # assumption A1's disk face: a liveness-certified plan proved every
        # spill creditable, so a DiskFullError is certifier unsoundness
        lcert = getattr(self.res, "liveness_certificate", None)
        if (owns_store and lcert is not None and lcert.ok
                and isinstance(host, TieredStore)):
            host.certified_live = True
        try:
            if self.exec_backend == "compiled":
                return self._run_compiled(inputs, mem, host)
            return self._run(inputs, mem, host)
        finally:
            # every exit path (success, worker error, collection RaceError,
            # KeyboardInterrupt) releases an owned store's disk temp dir
            if owns_store:
                host.close()

    def _make_ctx(self, mem, host, t0: float, members) -> ExecContext:
        return ExecContext.make(self.mg, self.tg, mem, host, self.policy,
                                self.mode, self.latency, t0, members)

    def _run(self, inputs: dict[int, np.ndarray], mem, host) -> RunResult:
        """Interpreted backend: the whole graph as one threaded job."""
        self.policy.prepare(self.mg)
        t0 = time.perf_counter()
        members = list(self.mg.vertices)
        ctx = self._make_ctx(mem, host, t0, members)
        fleet = ThreadedExecutor(ctx, members,
                                 n_streams=self.n_streams,
                                 n_transfer_streams=self.n_transfer_streams)
        try:
            fleet.start()
            if members:
                fleet.run_subset(members)
        except RaceError as e:
            _certified_reraise(self.res, e)
        finally:
            fleet.close()
        return self._finish(mem, host, ctx, t0,
                            n_interpreted=len(members),
                            n_threaded=len(members))

    def _region_backend(self, region) -> str:
        """The seam backend a NONDET region actually runs on: the
        compiler's stamp, unless this runtime forces one."""
        if self.seam_backend != "auto":
            return self.seam_backend
        return region.backend or THREADED

    def _run_compiled(self, inputs: dict[int, np.ndarray], mem,
                      host) -> RunResult:
        """Compiled backend (DESIGN.md §15/§17): straight-line execution
        of certified-static regions, handing off at nondet-region seams
        to the backend the region is stamped with — the thread-free
        inline executor for small certified seams, the persistent
        threaded fleet for large windows. All executors share one
        :class:`ExecContext` (``mem``, ``host``, the run timeline), so
        ByteArena extents, TieredStore tier moves, and HostPool lease
        accounting are exactly the invariants the certifiers assumed."""
        from .compile import NONDET, lower

        mg = self.mg
        pol = self.policy
        prepared = False
        if self._compiled is None:
            # lower() prepares the policy as part of linearization; that
            # same dispatch state then drives this run's seam executors
            prepared = True
            self._compiled = lower(
                self.res, policy=pol, n_streams=self.n_streams,
                n_transfer_streams=self.n_transfer_streams,
                seam_threshold=getattr(self.res, "seam_threshold", None))
        plan = self._compiled
        t0 = time.perf_counter()
        n_compiled = n_interpreted = n_fused = 0
        n_inline = n_threaded = 0
        # split the seams by effective backend: the fleet is sized to —
        # and threads are spun up for — ONLY the threaded-bound regions
        # (forcing inline gives a zero-thread run); the inline executor's
        # kernel covers the inline-bound ones. One shared context carries
        # the ADD_INTO lock groups of every seam vertex.
        seam_regions = [r for r in plan.regions if r.kind == NONDET]
        threaded_members = [m for r in seam_regions
                            if self._region_backend(r) == THREADED
                            for m in plan.order[r.start:r.end]]
        inline_members = [m for r in seam_regions
                         if self._region_backend(r) == INLINE
                         for m in plan.order[r.start:r.end]]
        ctx = self._make_ctx(mem, host, t0,
                             threaded_members + inline_members)
        if seam_regions and not prepared:
            # dispatch state (priorities, RNG draw) is only consumed by
            # the seam executors — an all-static plan skips it entirely
            pol.prepare(mg)
        fleet = None
        if threaded_members:
            fleet = ThreadedExecutor(
                ctx, threaded_members, n_streams=self.n_streams,
                n_transfer_streams=self.n_transfer_streams)
        inline = InlineExecutor(ctx, inline_members) if inline_members \
            else None
        static = StaticExecutor(ctx, plan)
        try:
            if fleet is not None:
                fleet.start()
            for region in plan.regions:
                if region.kind == NONDET:
                    # seam handoff: the region's vertex subset executes
                    # with full dispatch freedom on its backend. The
                    # linearization is topological, so every cross-region
                    # dependency points backward — already executed.
                    mids = plan.order[region.start:region.end]
                    if self._region_backend(region) == INLINE:
                        inline.run_subset(mids)
                        n_inline += len(region)
                    else:
                        fleet.run_subset(mids)
                        n_threaded += len(region)
                    n_interpreted += len(region)
                else:
                    n_fused += static.run_region(region)
                    n_compiled += len(region)
        except RaceError as e:
            _certified_reraise(self.res, e)
        finally:
            if fleet is not None:
                fleet.close()
        return self._finish(mem, host, ctx, t0,
                            n_compiled=n_compiled,
                            n_interpreted=n_interpreted,
                            fused_dma_batches=n_fused,
                            n_inline=n_inline, n_threaded=n_threaded)

    def _finish(self, mem, host, ctx: ExecContext, t0: float, *,
                n_compiled: int = 0, n_interpreted: int = 0,
                fused_dma_batches: int = 0,
                n_inline: int = 0, n_threaded: int = 0) -> RunResult:
        """Fold a finished execution's timeline into a RunResult (shared
        by both backends)."""
        timeline, spans = ctx.timeline, ctx.spans
        makespan = time.perf_counter() - t0
        devices = sorted({v.device for v in self.mg.vertices.values()})
        busy = {d: 0.0 for d in devices}
        chan = {k: 0.0 for k in TRANSFER_KINDS}
        by_dev: dict[int, list[tuple[float, float]]] = {d: [] for d in devices}
        for (a, b, d, eng_kind, _name) in timeline:
            if eng_kind == COMPUTE:
                by_dev[d].append((a, b))
            else:
                chan[eng_kind] += b - a
        for d, intervals in by_dev.items():   # union of stream intervals
            intervals.sort()
            cur_a, cur_b = None, None
            for a, b in intervals:
                if cur_b is None or a > cur_b:
                    if cur_b is not None:
                        busy[d] += cur_b - cur_a
                    cur_a, cur_b = a, b
                else:
                    cur_b = max(cur_b, b)
            if cur_b is not None:
                busy[d] += cur_b - cur_a
        stall = {d: makespan - busy[d] for d in devices}
        disk = getattr(host, "disk", None)
        return RunResult(
            outputs=_collect_outputs(self.tg, self.res, mem, host),
            makespan=makespan, busy=busy, stall=stall, transfer_time=chan,
            offload_bytes=host.offload_bytes, reload_bytes=host.reload_bytes,
            timeline=sorted(timeline), spans=spans,
            disk_spill_bytes=disk.write_bytes if disk else 0,
            disk_load_bytes=disk.read_bytes if disk else 0,
            peak_host_bytes=host.peak_resident_bytes,
            n_compiled=n_compiled, n_interpreted=n_interpreted,
            fused_dma_batches=fused_dma_batches,
            n_inline=n_inline, n_threaded=n_threaded,
        )


# --------------------------------------------------------------------------
# directed stuck-state scheduler (liveness witness replay, DESIGN.md §14)
# --------------------------------------------------------------------------
class _StallProbe:
    """Shared state between the directed workers and their watchdog."""

    def __init__(self) -> None:
        self.cond = threading.Condition()
        self.stalled: list[Any] = []     # tags whose admission timed out
        self.done = 0                    # workers that finished unstalled
        self.abort = False


class _DiskGate:
    """A bounded disk tier reduced to its admission discipline: a unit
    counter with the same ``try_charge`` surface as a lease, so the same
    blocking-admission loop drives both replays."""

    def __init__(self, capacity: int) -> None:
        self.capacity = int(capacity)
        self.used = 0
        self._lock = threading.Lock()

    def try_charge(self, n: int) -> bool:
        with self._lock:
            if self.used + n > self.capacity:
                return False
            self.used += n
            return True

    def release(self, n: int) -> None:
        with self._lock:
            self.used -= n


def _blocking_charge(target: Any, n: int, tag: Any, probe: _StallProbe,
                     deadline: float, poll_s: float = 0.002) -> bool:
    """The blocking admission discipline the static model assumes of a
    reserving consumer: retry ``try_charge`` until it fits or the bounded
    timeout expires (the threaded analogue of the serve engine's deferred
    admissions). Records the stall on timeout and returns False."""
    while True:
        if target.try_charge(n):
            return True
        with probe.cond:
            if probe.abort or time.monotonic() >= deadline:
                probe.stalled.append(tag)
                probe.cond.notify_all()
                return False
        time.sleep(poll_s)


def _pool_of(cfg: "_lv.PoolConfig") -> tuple[HostPool, dict[str, Lease]]:
    pool = HostPool(cfg.capacity, policy=cfg.policy)
    leases = {s.name: pool.lease(s.name, min_bytes=s.min_bytes,
                                 weight=s.weight, priority=s.priority,
                                 drains_via=s.drains_via)
              for s in cfg.leases}
    return pool, leases


def _occupy_slack(cfg: "_lv.PoolConfig", leases: dict[str, Lease],
                  plan_lease: str, guaranteed: int) -> None:
    """Adversarial co-tenancy: every byte above the plan lease's
    guarantee is held by the others — the configuration a full
    revocation leaves behind, and the one the guarantee is *about*."""
    slack = cfg.capacity - guaranteed
    for s in cfg.leases:
        if s.name != plan_lease and slack > 0:
            leases[s.name].account(slack)
            slack = 0


def _run_directed(workers: list[Callable[[], None]], probe: _StallProbe,
                  timeout_s: float) -> None:
    threads = [threading.Thread(target=w, name=f"turnip-directed{i}")
               for i, w in enumerate(workers)]
    for th in threads:
        th.start()
    n = len(workers)
    with probe.cond:
        probe.cond.wait_for(
            lambda: len(probe.stalled) + probe.done >= n,
            timeout=timeout_s * 8 + 2)
        probe.abort = True
        probe.cond.notify_all()
    for th in threads:
        th.join()


def _replay_lease_floor_stall(hazard: Any, cert: Any, mg: MemGraph,
                              timeout_s: float) -> str:
    from .analyze import recover_residencies
    cfg = cert.pool
    pool, leases = _pool_of(cfg)
    plan = leases[hazard.lease]
    guaranteed = int(hazard.capacity or 0)
    _occupy_slack(cfg, leases, hazard.lease, guaranteed)
    host, _ = recover_residencies(mg)
    admit_units = {r.admit: r.units for r in host}
    release_units = {r.release: r.units
                     for r in host if r.release is not None}
    probe = _StallProbe()
    deadline = time.monotonic() + timeout_s

    def worker() -> None:
        for m in hazard.witness[:hazard.prefix]:
            if m in admit_units:
                if not _blocking_charge(plan, admit_units[m], m, probe,
                                        deadline):
                    return
            elif m in release_units:
                plan.release(release_units[m])
        with probe.cond:
            probe.done += 1
            probe.cond.notify_all()

    _run_directed([worker], probe, timeout_s)
    assert probe.stalled, (
        f"witness prefix replayed to completion without stalling — the "
        f"lease-floor hazard did not confirm: {hazard}")
    snap = pool.snapshot()
    return (f"admission {probe.stalled[0]} stalled {timeout_s}s on lease "
            f"{hazard.lease!r} with the pool static at "
            f"{snap['used_bytes']}/{snap['capacity']} B")


def _replay_disk_credit_stall(hazard: Any, cert: Any, mg: MemGraph,
                              timeout_s: float) -> str:
    from .analyze import recover_residencies
    assert cert.disk_capacity is not None
    gate = _DiskGate(cert.disk_capacity)
    _, disk = recover_residencies(mg)
    admit_units = {r.admit: r.units for r in disk}
    release_units = {r.release: r.units
                     for r in disk if r.release is not None}
    probe = _StallProbe()
    deadline = time.monotonic() + timeout_s

    def worker() -> None:
        for m in hazard.witness[:hazard.prefix + 1]:
            if m in admit_units:
                if not _blocking_charge(gate, admit_units[m], m, probe,
                                        deadline):
                    return
            elif m in release_units:
                gate.release(release_units[m])
        with probe.cond:
            probe.done += 1
            probe.cond.notify_all()

    _run_directed([worker], probe, timeout_s)
    assert probe.stalled, (
        f"witness prefix replayed to completion without stalling — the "
        f"disk-credit hazard did not confirm: {hazard}")
    return (f"spill {probe.stalled[0]} stalled {timeout_s}s with "
            f"{gate.used}/{gate.capacity} disk unit(s) held by blobs "
            f"whose drops are all downstream")


def _replay_revocation_cycle(hazard: Any, cert: Any,
                             timeout_s: float) -> str:
    cfg = cert.pool
    pool, leases = _pool_of(cfg)
    # recover the drain cycle starting from the flagged lease
    cycle = [hazard.lease]
    while True:
        spec = cfg.spec(cycle[-1])
        nxt = next((t for t in spec.drains_via
                    if cfg.spec(t) is not None), None)
        assert nxt is not None, f"no drain edge out of {cycle[-1]!r}"
        if nxt in cycle:
            cycle = cycle[cycle.index(nxt):]
            break
        cycle.append(nxt)
    # wedge: fill the pool across the cycle so every drain's charge must
    # wait for room only the next drain can free
    share = cfg.capacity // len(cycle)
    for i, name in enumerate(cycle):
        extra = cfg.capacity - share * len(cycle) if i == 0 else 0
        leases[name].account(share + extra)
    probe = _StallProbe()
    deadline = time.monotonic() + timeout_s

    def drain(name: str, nxt: str) -> Callable[[], None]:
        def worker() -> None:
            l = leases[name]
            with pool.draining(l):
                if not _blocking_charge(leases[nxt], 1, name, probe,
                                        deadline):
                    return
            with probe.cond:
                probe.done += 1
                probe.cond.notify_all()
        return worker

    workers = [drain(name, cycle[(i + 1) % len(cycle)])
               for i, name in enumerate(cycle)]
    _run_directed(workers, probe, timeout_s)
    assert len(probe.stalled) == len(cycle) and probe.done == 0, (
        f"some drain on the cycle made progress — the revocation-cycle "
        f"hazard did not confirm: stalled={probe.stalled} "
        f"done={probe.done}")
    return (f"all {len(cycle)} drains on {' -> '.join(cycle)} stalled "
            f"{timeout_s}s with the pool full "
            f"({pool.snapshot()['used_bytes']}/{cfg.capacity} B)")


def _replay_atomic_stall(hazard: Any, cert: Any, timeout_s: float) -> str:
    cfg = cert.pool
    pool, leases = _pool_of(cfg)
    guaranteed = int(hazard.capacity or 0)
    _occupy_slack(cfg, leases, hazard.lease, guaranteed)
    probe = _StallProbe()
    deadline = time.monotonic() + timeout_s

    def worker() -> None:
        if not _blocking_charge(leases[hazard.lease], hazard.expect_units,
                                "batch", probe, deadline):
            return
        with probe.cond:
            probe.done += 1
            probe.cond.notify_all()

    _run_directed([worker], probe, timeout_s)
    assert probe.stalled, (
        f"the all-or-nothing batch was admitted — the atomic-admission "
        f"hazard did not confirm: {hazard}")
    return (f"{hazard.expect_units} B all-or-nothing batch stalled "
            f"{timeout_s}s against a {guaranteed} B guarantee")


def replay_stall(hazard: Any, cert: Any, mg: MemGraph | None = None, *,
                 timeout_s: float = 0.5) -> str:
    """Replay a liveness hazard's stuck-state witness to an *actual*
    bounded-timeout stall: the directed scheduler executes the witness
    prefix against a real :class:`~repro.core.pool.HostPool` (or a
    bounded disk gate) with the blocking admission discipline, and the
    flagged admission must still be refused after ``timeout_s`` of
    retries with the pool static — the dynamic confirmation for
    ``witness_kind == 'stall'`` findings, the way ``run_in_order``
    replays §13's race witnesses. Returns a one-line description of the
    observed stall; raises AssertionError if the replay makes progress
    instead."""
    kind = hazard.kind
    if kind == _lv.REVOCATION_CYCLE:
        return _replay_revocation_cycle(hazard, cert, timeout_s)
    if kind == _lv.ATOMIC_ADMISSION_STALL:
        return _replay_atomic_stall(hazard, cert, timeout_s)
    if kind == _lv.LEASE_FLOOR_STALL:
        assert mg is not None, "lease-floor replay needs the memgraph"
        return _replay_lease_floor_stall(hazard, cert, mg, timeout_s)
    if kind == _lv.DISK_CREDIT_STALL:
        assert mg is not None, "disk-credit replay needs the memgraph"
        return _replay_disk_credit_stall(hazard, cert, mg, timeout_s)
    raise AssertionError(f"no stall replay for hazard kind {kind!r}")
