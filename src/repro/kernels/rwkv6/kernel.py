"""RWKV6 chunked-WKV Pallas TPU kernel.

Grid (B, H, n_chunks), chunks innermost; per-(batch, head) WKV state [P, P]
carried in VMEM scratch. The per-channel decay requires the [c, c, P]
exponent tensor — kept entirely in VMEM by choosing a small chunk (32), all
exponents non-positive (differences of cumulative log-decays), mirroring
:func:`repro.models.rwkv.wkv6_chunked`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_scr, *,
                 chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)             # [c, P]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)           # [c, P] (log decay ≤ 0)
    u = u_ref[0].astype(jnp.float32)                # [P]

    lcw = jnp.cumsum(lw, axis=0)                    # [c, P]
    prev = lcw - lw
    # intra-chunk A[t,s] = Σ_p r_t k_s e^{prev_t - lcw_s}, s < t
    diff = prev[:, None, :] - lcw[None, :, :]       # [c, c, P] ≤ 0 masked
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    E = jnp.exp(jnp.where(tri[..., None], diff, -1e30))
    A = jnp.einsum("tp,tsp,sp->ts", r, E, k,
                   preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # diagonal bonus
    du = jnp.sum(r * u[None, :] * k, axis=-1)       # [c]
    y = y + du[:, None] * v
    # incoming state
    y = y + jax.lax.dot_general(r * jnp.exp(prev), s_scr[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state passing
    tailw = jnp.exp(lcw[-1:, :] - lcw)              # [c, P] ≤ 1
    upd = jax.lax.dot_general(k * tailw, v, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P, P]
    s_scr[...] = jnp.exp(lcw[-1])[:, None] * s_scr[...] + upd


def wkv6_kernel(r, k, v, lw, u, *, chunk: int = 32,
                interpret: bool = False):
    """r/k/v/lw: [B, S, H, P] (lw = log decay, ≤0); u: [H, P].
    Returns y: [B, S, H, P]. S must be chunk-padded by the wrapper."""
    B, S, H, P = r.shape
    assert S % chunk == 0
    nc = S // chunk
    from jax.experimental.pallas import tpu as pltpu
    tr = lambda t: t.transpose(0, 2, 1, 3)          # [B, H, S, P]
    y = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=(B, H, nc),
        in_specs=[pl.BlockSpec((1, 1, chunk, P),
                               lambda b, h, ic: (b, h, ic, 0))] * 4
        + [pl.BlockSpec((1, P), lambda b, h, ic: (h, 0))],
        out_specs=pl.BlockSpec((1, 1, chunk, P),
                               lambda b, h, ic: (b, h, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), r.dtype),
        scratch_shapes=[pltpu.VMEM((P, P), jnp.float32)],
        interpret=interpret,
    )(tr(r), tr(k), tr(v), tr(lw), u)
    return y.transpose(0, 2, 1, 3)
