"""jit'd public wrapper for the flash attention kernel.

Accepts model-layout tensors ([B, S, H, Dh]) and handles padding to block
multiples; ``interpret=True`` runs the kernel body in Python on CPU (the
validation mode used by the test suite on this container)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = False):
    """q: [B, Sq, Hq, Dh]; k/v: [B, Skv, Hkv, Dh] → [B, Sq, Hq, Dh]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    Sq, Skv = qt.shape[2], kt.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_kv, Skv)
    pq = (-Sq) % bq
    pk = (-Skv) % bk
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    o = flash_attention_kernel(qt, kt, vt, causal=causal, block_q=bq,
                               block_kv=bk, interpret=interpret,
                               true_skv=Skv)
    if pq:
        o = o[:, :, :Sq]
    return o.transpose(0, 2, 1, 3)
