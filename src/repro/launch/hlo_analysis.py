"""Collective-bytes accounting from optimized HLO text (§Roofline input).

``cost_analysis()`` does not report collective traffic, so we parse the
compiled module: sum the *result* bytes of every collective instruction
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
including their async ``-start`` forms), and multiply instructions inside
``while`` bodies by the loop trip count (scan-over-layers!). Trip counts are
recovered from the canonical XLA counter pattern (compare against a
constant in the loop condition).

This is a *model* of traffic, not a measurement: all-reduce is counted once
(ring cost ≈ 2·bytes·(N-1)/N — noted in the roofline write-up), and
reduce-scatter/all-gather result bytes match their per-device payload.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[a,b,c]' or a '(t1, t2, ...)' tuple string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        # computation header: `[ENTRY] %name (args...) -> result {`
        # (args may contain nested parens — match lazily up to `->`)
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip().startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def collective_bytes_from_hlo(hlo: str) -> dict:
    comps = _split_computations(hlo)

    # trip count per while body: find `while` instrs, map body comp -> count
    # canonical counter: condition compares s32 iterator to constant.
    def find_const(comp_lines: list[str]) -> int | None:
        consts = [int(m.group(1)) for ln in comp_lines
                  for m in [re.search(r"constant\((\d+)\)", ln)] if m]
        return max(consts) if consts else None

    while_info: list[tuple[str, str, str]] = []   # (comp, body, cond)
    for cname, lines in comps.items():
        for ln in lines:
            m = re.search(r"while\(.*?\).*?condition=%?([\w.\-]+).*?"
                          r"body=%?([\w.\-]+)", ln)
            if not m:
                m2 = re.search(r"while\(.*?\).*?body=%?([\w.\-]+).*?"
                               r"condition=%?([\w.\-]+)", ln)
                if not m2:
                    continue
                cond, body = m2.group(2), m2.group(1)
            else:
                cond, body = m.group(1), m.group(2)
            while_info.append((cname, body, cond))

    trip: dict[str, int] = {}
    for _c, body, cond in while_info:
        n = find_const(comps.get(cond, []))
        trip[body] = n if n and n > 0 else 1

    # direct collective bytes per computation
    direct: dict[str, dict[str, int]] = defaultdict(lambda: defaultdict(int))
    counts: dict[str, int] = defaultdict(int)
    for cname, lines in comps.items():
        for ln in lines:
            for kind in _COLL_KINDS:
                if re.search(rf"\b{kind}(-start)?\(", ln):
                    lhs = ln.split("=", 1)
                    if len(lhs) != 2:
                        continue
                    shape_part = lhs[1].strip().split(f" {kind}")[0]
                    b = _shape_bytes(shape_part)
                    direct[cname][kind] += b
                    counts[kind] += 1
                    break

    # fold while multipliers: bytes(comp) = direct + Σ trip(body)*bytes(body)
    children: dict[str, list[str]] = defaultdict(list)
    for cname, body, _cond in while_info:
        children[cname].append(body)

    memo: dict[str, dict[str, int]] = {}

    def total(comp: str, stack=()) -> dict[str, int]:
        if comp in memo:
            return memo[comp]
        if comp in stack:
            return defaultdict(int)
        out: dict[str, int] = defaultdict(int)
        for k, v in direct.get(comp, {}).items():
            out[k] += v
        for body in children.get(comp, []):
            sub = total(body, stack + (comp,))
            for k, v in sub.items():
                out[k] += v * trip.get(body, 1)
        memo[comp] = out
        return out

    # entry computation = the one containing ENTRY, else the largest
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
        if entry:
            break
    if entry is None or entry not in comps:
        # fallback: sum everything without multipliers
        agg: dict[str, int] = defaultdict(int)
        for c in comps:
            for k, v in direct.get(c, {}).items():
                agg[k] += v
        by_kind = dict(agg)
    else:
        by_kind = dict(total(entry))

    return {
        "by_kind": {k: int(v) for k, v in by_kind.items()},
        "counts": {k: int(v) for k, v in counts.items()},
        "total_bytes": int(sum(by_kind.values())),
    }


# ---------------------------------------------------------------------------
# Full HLO cost model with while-trip folding.
#
# XLA's ``compiled.cost_analysis()`` counts a while body ONCE — under
# scan-over-layers that understates FLOPs/bytes by ~n_layers. We re-derive
# both from the optimized HLO text: dot FLOPs from result × contracted dims,
# bytes as result+operand bytes per instruction, folding loop trip counts
# exactly like the collective accounting above.
# ---------------------------------------------------------------------------
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:\w+\[[\d,]*\]"
    r"(?:\{[^}]*\})?))\s*([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "reshape", "copy-start", "copy-done",
                 "after-all", "partition-id", "replica-id", "iota",
                 "custom-call"}


def _parse_dims(shape_str: str) -> list[int]:
    m = re.search(r"\[([\d,]*)\]", shape_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


def hlo_cost_with_trips(hlo: str) -> dict:
    """Returns {'flops', 'bytes_accessed', 'collectives': {...}} with while
    bodies multiplied by their trip counts."""
    comps = _split_computations(hlo)

    # symbol tables: per computation, instr name -> shape string
    shapes: dict[str, dict[str, str]] = {}
    for cname, lines in comps.items():
        tab: dict[str, str] = {}
        for ln in lines:
            m = _INSTR_RE.match(ln)
            if m:
                tab[m.group(1)] = m.group(2)
        shapes[cname] = tab

    def comp_cost(cname: str, *, fusion_body: bool = False
                  ) -> tuple[float, float]:
        """flops: all dots/elementwise. bytes: HBM-touching instructions
        only — inside fusion bodies intermediates live in registers/VMEM, so
        a fusion body contributes flops but no bytes (the fusion *call*
        accounts for its operands+result at the caller's level)."""
        flops = 0.0
        byts = 0.0
        tab = shapes.get(cname, {})
        for ln in comps.get(cname, []):
            m = _INSTR_RE.match(ln)
            if not m:
                continue
            name, rshape, op, rest = m.groups()
            if op in _NO_BYTES_OPS:
                continue
            if not fusion_body:
                rbytes = _shape_bytes(rshape)
                obytes = 0
                for on in _OPERAND_RE.findall(rest.split(")")[0]):
                    if on in tab:
                        obytes += _shape_bytes(tab[on])
                byts += rbytes + obytes
            if op == "dot":
                cd = _CDIMS_RE.search(rest)
                k = 1
                ops = _OPERAND_RE.findall(rest.split(")")[0])
                if cd and ops and ops[0] in tab:
                    ldims = _parse_dims(tab[ops[0]])
                    for d in (cd.group(1).split(",") if cd.group(1) else []):
                        di = int(d)
                        if di < len(ldims):
                            k *= ldims[di]
                n = 1
                for d in _parse_dims(rshape):
                    n *= d
                flops += 2.0 * n * k
            elif op in ("add", "multiply", "subtract", "divide", "exponential",
                        "tanh", "rsqrt", "maximum", "minimum", "compare",
                        "select", "convert", "negate", "power", "log",
                        "reduce", "sqrt"):
                n = 1
                for d in _parse_dims(rshape):
                    n *= d
                flops += float(n)
        return flops, byts

    # while structure (reuse the collective machinery's discovery)
    while_info = []
    for cname, lines in comps.items():
        for ln in lines:
            m = re.search(r"while\(.*?\).*?condition=%?([\w.\-]+).*?"
                          r"body=%?([\w.\-]+)", ln)
            if not m:
                m2 = re.search(r"while\(.*?\).*?body=%?([\w.\-]+).*?"
                               r"condition=%?([\w.\-]+)", ln)
                if not m2:
                    continue
                cond, body = m2.group(2), m2.group(1)
            else:
                cond, body = m.group(1), m.group(2)
            while_info.append((cname, body, cond))
    trip: dict[str, int] = {}
    for _c, body, cond in while_info:
        consts = [int(mm.group(1)) for ln in comps.get(cond, [])
                  for mm in [re.search(r"constant\((\d+)\)", ln)] if mm]
        trip[body] = max(consts) if consts else 1
    children: dict[str, list[str]] = defaultdict(list)
    called: set[str] = set()
    for cname, body, cond in while_info:
        children[cname].append(body)
        called.add(body)
        called.add(cond)
    # computations invoked via fusion/call/reduce run inline — their cost
    # must attach to the caller. Approximation: attribute fusion bodies to
    # whichever computation references them by name.
    ref_children: dict[str, list[str]] = defaultdict(list)
    for cname, lines in comps.items():
        for ln in lines:
            for ref in re.findall(r"(?:calls=|to_apply=|fusion\s*=?)%?"
                                  r"([\w.\-]+)", ln):
                if ref in comps and ref != cname:
                    ref_children[cname].append(ref)
                    called.add(ref)

    memo: dict[tuple[str, bool], tuple[float, float]] = {}

    def total(comp: str, stack=(), fusion_body: bool = False
              ) -> tuple[float, float]:
        key = (comp, fusion_body)
        if key in memo:
            return memo[key]
        if comp in stack:
            return (0.0, 0.0)
        f, b = comp_cost(comp, fusion_body=fusion_body)
        for body in children.get(comp, []):   # while bodies: real HBM loops
            sf, sb = total(body, stack + (comp,), fusion_body)
            t = trip.get(body, 1)
            f += sf * t
            b += sb * t
        for sub in ref_children.get(comp, []):  # fusion/call/reduce bodies
            sf, _sb = total(sub, stack + (comp,), True)
            f += sf
        memo[key] = (f, b)
        return f, b

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            mm = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if mm:
                entry = mm.group(1)
            break
    if entry is None or entry not in comps:
        roots = [c for c in comps if c not in called]
        f = b = 0.0
        for c in roots:
            cf, cb = total(c)
            f += cf
            b += cb
    else:
        f, b = total(entry)
    return {"flops": f, "bytes_accessed": b,
            "collectives": collective_bytes_from_hlo(hlo)}
