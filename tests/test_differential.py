"""Schedule-fuzz differential harness (the paper's §7 claims as a
cross-executor equivalence relation).

For random TASKGRAPHs × all four dispatch policies × random host/disk
capacities, three independent executions of every buildable plan must
agree **byte-exactly**:

* the *in-memory oracle* — direct dataflow evaluation, no memory plan;
* a *simulator replay* — the discrete-event simulator picks a schedule
  under jittered hardware, and that exact schedule (``SimResult.start_at``)
  is replayed through the sequential interpreter, so the simulator's
  scheduling choices are proven execution-valid, not just priced;
* the *threaded runtime* — real threads, condition variables, real disk
  files for SPILL/LOAD plans.

Spill plans additionally run a **shared-pool lane**: the same plan over a
store leased from an arbitrated :class:`~repro.core.pool.HostPool` with a
second consumer charging a random share under a random arbitration policy
(DESIGN.md §12) — grants move, outputs must not. The nightly hypothesis
lane (``FUZZ_EXAMPLES``) sweeps these pool configurations with generated
graphs and budgets.

And ``validate()`` must accept exactly the schedules the executors can
run: every buildable plan validates under the budgets it was compiled
for, any budget below the replayed peak is rejected (``RaceError``), and
an infeasible three-level footprint is rejected at *compile* time
(``MemgraphOOM``) before any executor sees it.

Two lanes share one checker and one generator (``helpers.py``):

* the **fast lane** (no extra deps, pinned seeds) runs in CI on every
  push;
* the **slow lane** is hypothesis-driven (``-m slow``, nightly CI);
  ``FUZZ_EXAMPLES`` scales the example count.
"""
import os
import random as pyrandom

import numpy as np
import pytest

from repro.core import (BuildConfig, HostPool, MemgraphOOM, build_memgraph,
                        certify)
from repro.core.dispatch import POLICY_NAMES
from repro.core.memgraph import DepKind, RaceError
from repro.core.runtime import TurnipRuntime, eval_taskgraph, run_in_order
from repro.core.simulate import HardwareModel, simulate

from helpers import confirm_hazard, graph_inputs, random_taskgraph

UNITS = dict(size_fn=lambda v: 1)
ARB_POLICIES = ("static", "demand", "priority")

# capacity draw spaces: None = unbounded tier; small ints force real
# spill/load traffic; 0 disk makes any spill infeasible (must reject)
HOST_CAPS = (None, 1, 2, 3)
DISK_CAPS = (None, 0, 2, 4, 50)


def _assert_equal(out, ref, what):
    assert set(out) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(out[k], ref[k],
                                      err_msg=f"{what}: output {k}")


def check_case(tg, seed: int, host_cap, disk_cap, *,
               policies=POLICY_NAMES) -> str:
    """One fuzz case; returns 'oom' | 'host' | 'disk' for coverage stats."""
    cfg = BuildConfig(capacity=3, host_capacity=host_cap,
                      disk_capacity=disk_cap, rng_seed=seed, **UNITS)
    try:
        res = build_memgraph(tg, cfg)
    except MemgraphOOM as e:
        # the compile-time feasibility check must say *which* tier cannot
        # fit — a rejected program needs an actionable error
        assert any(t in str(e) for t in ("device", "host tier", "disk tier"))
        return "oom"
    mg = res.memgraph

    # validate() accepts what the executors are about to run...
    mg.validate(check_races=True, host_capacity=host_cap,
                disk_capacity=disk_cap)
    prof = mg.host_tier_profile()
    # ...and rejects any budget below the schedule's replayed peaks: the
    # acceptance set equals the runnable set, in both directions
    if host_cap is not None and prof["peak_units"] > 0:
        with pytest.raises(RaceError, match="host-tier budget"):
            mg.validate(check_races=False,
                        host_capacity=prof["peak_units"] - 1)
    if prof["peak_disk_units"] > 0:
        with pytest.raises(RaceError, match="disk-tier budget"):
            mg.validate(check_races=False,
                        disk_capacity=prof["peak_disk_units"] - 1)

    # the static certifier (DESIGN.md §13) must prove the plan clean for
    # ALL legal orders, not just the ones sampled below — and its
    # worst-case occupancy bounds must dominate the single-order replay
    cert = certify(mg, host_capacity=host_cap, disk_capacity=disk_cap)
    assert cert.ok, f"built plan failed certification:\n{cert.summary()}"
    assert cert.worst_host_units >= prof["peak_units"]
    assert cert.worst_disk_units >= prof["peak_disk_units"]

    inputs = graph_inputs(tg, seed)
    ref = eval_taskgraph(tg, inputs)          # the in-memory oracle
    hw = HardwareModel(transfer_jitter=0.5, compute_jitter=0.2, seed=seed)
    for policy in policies:
        # simulator replay: execute exactly the schedule the simulator
        # chose (ties broken deterministically by mid)
        sim = simulate(mg, hw, mode="nondet", policy=policy)
        order = mg.topo_order(key=lambda m: (sim.start_at[m], m))
        _assert_equal(run_in_order(tg, res, inputs, order), ref,
                      f"sim-replay/{policy}")
        # threaded runtime, event-driven nondeterministic dispatch
        rr = TurnipRuntime(tg, res, mode="nondet", policy=policy,
                           seed=seed).run(inputs)
        _assert_equal(rr.outputs, ref, f"threaded/{policy}")
    # the head-of-line issue-order ablation on one policy (cost-bounded)
    rr = TurnipRuntime(tg, res, mode="fixed", policy="fixed",
                       seed=seed).run(inputs)
    _assert_equal(rr.outputs, ref, "threaded/fixed-mode")

    # compiled lane (DESIGN.md §15): the same plan lowered to a
    # straight-line CompiledPlan — static regions run with zero dispatch,
    # nondet regions hand off to the interpreter at seam vertices — must
    # reproduce the oracle byte-exactly under every policy, and every
    # vertex must be accounted to exactly one executor
    for policy in policies:
        rr = TurnipRuntime(tg, res, mode="nondet", policy=policy,
                           seed=seed, exec_backend="compiled").run(inputs)
        _assert_equal(rr.outputs, ref, f"compiled/{policy}")
        assert rr.n_compiled + rr.n_interpreted == len(mg.vertices)
        assert rr.n_inline + rr.n_threaded == rr.n_interpreted

    # forced-backend lane (DESIGN.md §17): the same compiled plan with
    # every seam forced onto ONE backend — the thread-free inline
    # executor and the threaded fleet — must stay byte-exact under every
    # policy, and the counters must show the forcing actually happened
    # (inline-forced runs spin up zero seam threads).
    for backend in ("inline", "threaded"):
        for policy in policies:
            rr = TurnipRuntime(tg, res, mode="nondet", policy=policy,
                               seed=seed, exec_backend="compiled",
                               seam_backend=backend).run(inputs)
            _assert_equal(rr.outputs, ref, f"compiled/{backend}/{policy}")
            assert rr.n_inline + rr.n_threaded == rr.n_interpreted
            if backend == "inline":
                assert rr.n_threaded == 0
            else:
                assert rr.n_inline == 0

    # shared-pool lane (DESIGN.md §12): the same plan over a store whose
    # host arena is a lease of an arbitrated HostPool, with a second
    # consumer charging a random share under a random arbitration policy.
    # Arbitration moves grants and fires revocations; it must never move
    # bytes the plan depends on — outputs stay byte-exact, and the lease
    # drains once the runtime releases its store.
    if host_cap is not None and res.n_spills:
        rngp = pyrandom.Random(seed * 31 + 7)
        pool = HostPool(1 << 20, policy=rngp.choice(ARB_POLICIES))
        mem_lease = pool.lease("memgraph", min_bytes=rngp.choice(
            (0, 1 << 16)), weight=1.0, priority=1)
        other = pool.lease("kv", weight=rngp.random() * 4 + 0.1, priority=2)
        other.try_charge(rngp.randrange(1 << 19))      # the random split
        for policy in ("random", "critical-path"):
            rr = TurnipRuntime(tg, res, mode="nondet", policy=policy,
                               seed=seed, host_lease=mem_lease).run(inputs)
            _assert_equal(rr.outputs, ref, f"pooled/{policy}")
            assert pool.used_bytes == other.used, \
                "runtime store release did not drain its lease"
        assert mem_lease.peak > 0          # the lane really accounted bytes
        assert pool.peak_bytes <= pool.capacity + mem_lease.peak
    return "disk" if res.n_loads else "host"


# ------------------------------------------------------------- fast lane
def test_fuzz_seeded_differential():
    """Pinned-seed sweep (CI fast lane): the sweep must exercise real
    disk-tier plans, at least one compile-time rejection, and every
    dispatch policy — all byte-exact."""
    outcomes = {"oom": 0, "host": 0, "disk": 0}
    for seed in range(14):
        rng = pyrandom.Random(1000 + seed)
        tg = random_taskgraph(rng)
        host_cap = rng.choice(HOST_CAPS)
        disk_cap = rng.choice(DISK_CAPS) if host_cap is not None else None
        outcomes[check_case(tg, seed, host_cap, disk_cap)] += 1
    assert outcomes["disk"] >= 3, outcomes    # disk tier really exercised
    assert outcomes["oom"] >= 1, outcomes     # rejection path exercised


def test_certifier_counterexamples_feed_the_harness():
    """The loop the certifier closes (DESIGN.md §13): seed a hazard into a
    built plan by deleting one safe-overwrite MEM edge, and the witness
    schedule the certifier emits must be a *real* counterexample — the
    harness replays it through the sequential interpreter and watches it
    raise or diverge from the oracle."""
    n_confirmed = 0
    for seed in range(8):
        rng = pyrandom.Random(1000 + seed)
        tg = random_taskgraph(rng)
        try:
            res = build_memgraph(tg, BuildConfig(capacity=3, host_capacity=2,
                                                 rng_seed=seed, **UNITS))
        except MemgraphOOM:
            continue
        mg = res.memgraph
        mem_edges = [(u, v) for u in mg.vertices for v, k in
                     mg.succs[u].items() if k == DepKind.MEM]
        for u, v in mem_edges:
            mg.remove_dep(u, v)
            cert = certify(mg, host_capacity=2)
            for h in cert.hazards:
                if not h.confirmable:
                    continue
                try:
                    confirm_hazard(tg, res, h, seed=seed)
                except AssertionError:
                    continue      # statically real but value-coincident
                n_confirmed += 1
                break
            mg.add_dep(u, v, DepKind.MEM)
            if n_confirmed >= 3:
                return
    assert n_confirmed >= 3, "edge-deletion sweep never produced a " \
        "confirmable hazard — the certifier or the generator regressed"


def test_disk_budget_rejection_is_exact():
    """A plan whose spilled working set needs N disk units builds under a
    budget of N, and is rejected under N-1 — the feasibility check is
    tight, not merely conservative."""
    from helpers import fig3_taskgraph
    tg = fig3_taskgraph()
    res = build_memgraph(tg, BuildConfig(capacity=3, host_capacity=1,
                                         **UNITS))
    need = res.peak_disk
    assert need > 0
    ok = build_memgraph(tg, BuildConfig(capacity=3, host_capacity=1,
                                        disk_capacity=need, **UNITS))
    ok.memgraph.validate(host_capacity=1, disk_capacity=need)
    with pytest.raises(MemgraphOOM, match="disk tier"):
        build_memgraph(tg, BuildConfig(capacity=3, host_capacity=1,
                                       disk_capacity=need - 1, **UNITS))


def test_prefetch_plans_profile_like_reactive_plans():
    """Prefetch moves LOADs earlier in the schedule; it must never move
    the budgets: hoisted plans still validate under the same host/disk
    capacities, and hide real bytes."""
    n_hoisted = 0
    for seed in range(10):
        tg = random_taskgraph(pyrandom.Random(2000 + seed))
        try:
            on = build_memgraph(tg, BuildConfig(
                capacity=3, host_capacity=1 + seed % 3, **UNITS))
            off = build_memgraph(tg, BuildConfig(
                capacity=3, host_capacity=1 + seed % 3,
                prefetch_distance=0, **UNITS))
        except MemgraphOOM:
            continue
        assert off.n_prefetches == 0
        on.memgraph.validate(check_races=True,
                             host_capacity=1 + seed % 3)
        if on.n_prefetches:
            n_hoisted += 1
            assert on.stall_bytes_hidden > 0
            prof = on.memgraph.host_tier_profile()
            assert prof["n_prefetches"] == on.n_prefetches
    assert n_hoisted >= 2      # the sweep must hit real prefetch plans


def test_compiled_seams_exercised_on_unbounded_host_plans():
    """An unbounded-host plan opens with many INPUT streams racing on the
    h2d engine — the paper's legitimately nondeterministic core. The
    compiled backend must mark those as seam regions (interpreted), run
    the rest straight-line, and still match the oracle."""
    from helpers import fig3_taskgraph
    tg = fig3_taskgraph()
    res = build_memgraph(tg, BuildConfig(capacity=3, rng_seed=0, **UNITS))
    inputs = graph_inputs(tg, 0)
    ref = eval_taskgraph(tg, inputs)
    for policy in POLICY_NAMES:
        rr = TurnipRuntime(tg, res, mode="nondet", policy=policy, seed=0,
                           exec_backend="compiled").run(inputs)
        _assert_equal(rr.outputs, ref, f"compiled-seams/{policy}")
        assert rr.n_interpreted > 0, "no seam region was interpreted"
        assert rr.n_compiled > 0, "nothing ran straight-line"


# ---------------------------------------------- migration byte-exactness
# The fleet's inter-replica wire (serve/router.py) reuses the disk tier's
# spill.log framed-record format. This lane proves the codec is a bit-exact
# round trip over adversarial KV payloads — every dtype/shape the cache
# families produce, including blocks whose bytes are resident on the DISK
# tier at export time (read back through the spill.log frame, then framed
# again for the wire).

_KV_DTYPES = ("float32", "float16", "bfloat16", "int8", "int32")


def _random_kv_ticket(rng, *, rid):
    """A migration ticket over a randomized but internally consistent leaf
    spec: every block carries the same leaves/shapes/dtypes, like a real
    ``PagedKVCache.leaf_spec`` contract."""
    from repro.serve import MigrationTicket
    import jax.numpy as jnp
    block = rng.choice((2, 4, 8))
    spec = {}
    for j in range(rng.randint(1, 4)):
        shape = (rng.randint(1, 3), block) + tuple(
            rng.randint(1, 5) for _ in range(rng.randint(0, 2)))
        spec[f"leaf{j}"] = (shape, rng.choice(_KV_DTYPES))
    np_rng = np.random.default_rng(rng.randrange(2**31))

    def draw(shape, dtype):
        raw = np_rng.integers(-120, 120, size=shape)
        if dtype == "bfloat16":       # not a numpy dtype: go through jax
            return np.asarray(jnp.asarray(raw, dtype=jnp.bfloat16))
        return raw.astype(dtype)

    n_blocks = rng.randint(1, 5)
    blocks = [{k: draw(shape, dt) for k, (shape, dt) in spec.items()}
              for _ in range(n_blocks)]
    out = [rng.randrange(100) for _ in range(rng.randint(0, 6))]
    return MigrationTicket(
        rid=rid, prompt=[rng.randrange(100) for _ in range(rng.randint(1, 9))],
        out=out, max_new=len(out) + rng.randint(1, 8),
        pos=n_blocks * block, last=out[-1] if out else 0,
        block_size=block, t_submit=0.125, t_first=0.25, blocks=blocks)


def _assert_ticket_bit_exact(got, want):
    from repro.serve import MigrationTicket
    assert isinstance(got, MigrationTicket)
    for f in ("rid", "prompt", "out", "max_new", "pos", "last",
              "block_size", "t_submit", "t_first"):
        assert getattr(got, f) == getattr(want, f), f
    assert len(got.blocks) == len(want.blocks)
    for g, w in zip(got.blocks, want.blocks):
        assert set(g) == set(w)
        for k in w:
            a, b = g[k], np.ascontiguousarray(w[k])
            assert str(a.dtype) == str(b.dtype) and a.shape == b.shape
            assert a.tobytes() == b.tobytes(), f"leaf {k} bytes diverged"


def test_migration_codec_roundtrip_bit_exact():
    """Pinned-seed sweep: serialize → decode restores every KV block
    byte-identical across the cache dtypes (incl. bfloat16/int8 scales)."""
    from repro.serve import decode_ticket, encode_ticket
    for seed in range(24):
        rng = pyrandom.Random(4000 + seed)
        want = _random_kv_ticket(rng, rid=seed)
        _assert_ticket_bit_exact(decode_ticket(encode_ticket(want)), want)
    # cold tickets (no payload) survive the wire too
    from repro.serve import MigrationTicket
    cold = MigrationTicket(rid=9, prompt=[1], out=[2, 3], max_new=5, pos=0,
                           last=3, block_size=4)
    got = decode_ticket(encode_ticket(cold))
    assert got.blocks is None and got.out == [2, 3]


def test_migration_roundtrip_through_disk_tier():
    """The ship-from-disk path: KV blocks forced down to the disk tier
    (spill.log framed records), read back via ``peek_offload`` with no
    restaging, and shipped — the decoded payload must match the original
    arrays bit-exactly even though the bytes crossed the frame twice."""
    from repro.core.stores import TieredStore
    from repro.serve import decode_ticket, encode_ticket
    for seed in range(6):
        rng = pyrandom.Random(5000 + seed)
        want = _random_kv_ticket(rng, rid=seed)
        store = TieredStore({}, host_capacity=1, auto_spill=True)
        try:
            originals = [{k: np.ascontiguousarray(v).copy()
                          for k, v in blk.items()}
                         for blk in want.blocks]
            for blk_i, blk in enumerate(want.blocks):
                store.put_offload((want.rid, blk_i), blk)
                store.spill((want.rid, blk_i))    # force disk residency
            # every block's bytes went through spill.log and left the host
            assert store.disk.write_bytes > 0
            assert all(store.tier_of((want.rid, b)) == "disk"
                       for b in range(len(want.blocks)))
            # the disk tier restores extended dtypes (bfloat16) as raw
            # void words; relabel from the known spec before shipping,
            # exactly as Engine._warm_payload_locked does at export
            peeked = [_relabel(store.peek_offload((want.rid, b)), orig)
                      for b, orig in enumerate(originals)]
            shipped = dataclasses_replace_blocks(want, peeked)
            assert all(b is not None for b in shipped.blocks)
            got = decode_ticket(encode_ticket(shipped))
            shipped_ref = dataclasses_replace_blocks(want, originals)
            _assert_ticket_bit_exact(got, shipped_ref)
        finally:
            store.close()


def dataclasses_replace_blocks(t, blocks):
    import dataclasses as _dc
    return _dc.replace(t, blocks=blocks)


def _relabel(block, reference):
    """View void-typed disk reads back to their true dtypes (a relabel,
    never a cast — the bytes are already exact)."""
    out = {}
    for k, v in block.items():
        arr = np.asarray(v)
        want = np.asarray(reference[k]).dtype
        if arr.dtype != want and arr.dtype.kind == "V" \
                and arr.dtype.itemsize == want.itemsize:
            arr = arr.view(want)
        out[k] = arr
    return out


# ------------------------------------------------------------- slow lane
@pytest.mark.slow
def test_fuzz_hypothesis_differential():
    """Hypothesis-driven lane (nightly CI: ``-m slow`` with a larger
    ``FUZZ_EXAMPLES``): same checker, generated graphs and budgets."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st
    from helpers import taskgraphs

    max_examples = int(os.environ.get("FUZZ_EXAMPLES", "25"))

    @settings(max_examples=max_examples, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(tg=taskgraphs(), seed=st.integers(0, 2**16),
           host_cap=st.sampled_from(HOST_CAPS),
           disk_cap=st.sampled_from(DISK_CAPS))
    def inner(tg, seed, host_cap, disk_cap):
        if host_cap is None:
            disk_cap = None       # an unbounded host never spills to disk
        check_case(tg, seed, host_cap, disk_cap,
                   policies=("random", "critical-path"))

    inner()


@pytest.mark.slow
def test_fuzz_hypothesis_migration_codec():
    """Nightly widening of the migration byte-exactness lane: generated
    leaf specs, dtypes, and disk-tier residency — serialize → ship →
    restore stays bit-exact everywhere."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st
    from repro.core.stores import TieredStore
    from repro.serve import decode_ticket, encode_ticket

    max_examples = int(os.environ.get("FUZZ_EXAMPLES", "25"))

    @settings(max_examples=max_examples, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 2**16), via_disk=st.booleans())
    def inner(seed, via_disk):
        rng = pyrandom.Random(seed)
        want = _random_kv_ticket(rng, rid=seed)
        if via_disk:
            store = TieredStore({}, host_capacity=1, auto_spill=True)
            try:
                originals = [{k: np.ascontiguousarray(v).copy()
                              for k, v in blk.items()}
                             for blk in want.blocks]
                for i, blk in enumerate(want.blocks):
                    store.put_offload((want.rid, i), blk)
                    store.spill((want.rid, i))
                shipped = dataclasses_replace_blocks(
                    want, [_relabel(store.peek_offload((want.rid, b)), o)
                           for b, o in enumerate(originals)])
                got = decode_ticket(encode_ticket(shipped))
                _assert_ticket_bit_exact(
                    got, dataclasses_replace_blocks(want, originals))
            finally:
                store.close()
        else:
            _assert_ticket_bit_exact(decode_ticket(encode_ticket(want)),
                                     want)

    inner()
