"""Discrete-event simulator for MEMGRAPH execution (paper §2/§8 ablation).

The container has no accelerator, so wall-clock runs cannot show the paper's
headline effect (GPU stalls while a transfer finishes). This simulator models
it hardware-neutrally: each device has a compute engine plus three DMA
channels (host→device, device→host, device→device) and a disk I/O engine
(host↔disk spills/loads of the tiered hierarchy, DESIGN.md §10) that run
concurrently — the same concurrency structure as CUDA streams +
``cudaMemcpyAsync`` or TPU DMA engines. Durations come from a
:class:`HardwareModel`.

Two dispatch modes reproduce the paper's ablation (§8, "Fixed execution"):

* ``nondet`` — the TURNIP event loop: any vertex whose deps are complete is
  launched as soon as its engine frees up; *which* queued vertex an engine
  picks is ranked by a :class:`~repro.core.dispatch.DispatchPolicy` — the
  same vocabulary the threaded :class:`~repro.core.runtime.TurnipRuntime`
  uses, so simulated and real-thread schedules are comparable;
* ``fixed``  — vertices are *launched* strictly in the compile-time
  simulation order; a launched vertex still executes asynchronously on its
  engine, but no later vertex may launch before it (head-of-line blocking —
  exactly what makes a fixed order stall on unpredictable transfers).

Outputs makespan + per-device compute busy/stall, the quantities behind the
paper's Figures 10–15 and its ≤3× fixed-order slowdown claim.
"""
from __future__ import annotations

import dataclasses
import heapq

from .dispatch import (COMPUTE as _COMPUTE, D2D as _D2D, D2H as _D2H,
                       DISK as _DISK, DispatchPolicy, ENGINE_OF as _ENGINE_OF,
                       H2D as _H2D, NIC as _NIC,
                       TRANSFER_KINDS as _TRANSFER_KINDS,
                       get_policy)
from .memgraph import DepKind, MemGraph, MemOp, MemVertex

__all__ = ["HardwareModel", "SimResult", "simulate",
           "price_migration", "price_reprefill", "migration_crossover"]


@dataclasses.dataclass
class HardwareModel:
    """Latency/bandwidth constants. Defaults ≈ the paper's P100 server
    (PCIe gen3 x16 ≈ 12 GB/s, fp16 ≈ 18.7 TFLOP/s but sliced kernels reach a
    fraction of peak). TPU v5e profile: flops=197e12 (bf16), hbm_bw=819e9,
    pcie ≈ 32e9, ici d2d ≈ 50e9 per link."""

    flops: float = 8e12              # effective FLOP/s per device
    hbm_bw: float = 500e9            # bytes/s — memory-bound floor for kernels
    h2d_bw: float = 12e9
    d2h_bw: float = 12e9
    d2d_bw: float = 12e9
    disk_bw: float = 2.4e9           # host<->disk tier (NVMe-class)
    nic_bw: float = 3.1e9            # host<->remote-host (25 GbE-class) —
    #                                  the sixth priced channel: inter-replica
    #                                  KV migration (serve/router.py)
    kernel_overhead: float = 5e-6    # fixed per-kernel launch cost (s)
    dma_latency: float = 10e-6       # fixed per-transfer cost (s)
    disk_latency: float = 100e-6     # fixed per disk spill/load cost (s)
    nic_latency: float = 50e-6       # fixed per inter-replica transfer (s)
    # The paper's core hypothesis (§2): offload/reload latencies are
    # "seemingly nondeterministic". jitter is the sigma of a lognormal
    # multiplier on transfer durations (0 = deterministic). The same seeded
    # per-vertex draw is used in both dispatch modes (common random numbers)
    # so fixed-vs-nondet comparisons are paired.
    transfer_jitter: float = 0.0
    compute_jitter: float = 0.0
    # Shared-pool contention (DESIGN.md §12): when the host arena is an
    # arbitrated HostPool, another consumer's pressure can revoke this
    # plan's slack mid-flight, turning a host-resident staging into a
    # re-stage from disk. pool_contention is the probability a disk-tier
    # op hits a revoked extent and pays revoke_stall extra seconds — a
    # seeded per-vertex Bernoulli draw (common random numbers, like the
    # jitter), so fixed-vs-nondet and pooled-vs-isolated comparisons are
    # paired. 0 (default) prices an isolated pool exactly as before.
    pool_contention: float = 0.0
    revoke_stall: float = 500e-6
    seed: int = 0

    def duration(self, v: MemVertex, *, fused: bool = False) -> float:
        """Execution seconds of ``v``. ``fused=True`` prices a non-head
        member of a fused DMA batch (core/compile.py): the submission
        rides its batch head's enqueue, so the fixed per-transfer latency
        term (``dma_latency``/``disk_latency``) is dropped and only the
        wire time remains — one launch cost per batch, paid by the head.
        Jitter stays per-vertex so fused-vs-unfused comparisons are
        common-random-numbers paired."""
        eng = _ENGINE_OF[v.op]
        if v.op == MemOp.JOIN:
            return 0.0
        if eng == _COMPUTE:
            t_flops = v.flops / self.flops
            t_mem = 3.0 * v.nbytes / self.hbm_bw   # read 2 operands + write
            base = self.kernel_overhead + max(t_flops, t_mem)
            return base * self._jit(v.mid, self.compute_jitter)
        if eng == _DISK:
            if v.nbytes == 0:          # dedup/drop spill: no bytes move
                return 0.0
            # same paired per-vertex jitter draw as the DMA lanes, so
            # fixed-vs-nondet comparisons stay common-random-numbers even
            # when the nondeterminism source is the disk tier
            base = (0.0 if fused else self.disk_latency) \
                + v.nbytes / self.disk_bw
            base += self._revoked(v.mid) * self.revoke_stall
            return base * self._jit(v.mid, self.transfer_jitter)
        if eng == _NIC:
            # same paired jitter stream as the other transfer channels —
            # the inter-replica wire is priced like any DMA lane, with its
            # own latency/bandwidth constants (arXiv 2502.15712's stance)
            base = (0.0 if fused else self.nic_latency) \
                + v.nbytes / self.nic_bw
            return base * self._jit(v.mid, self.transfer_jitter)
        bw = {_H2D: self.h2d_bw, _D2H: self.d2h_bw, _D2D: self.d2d_bw}[eng]
        base = (0.0 if fused else self.dma_latency) + v.nbytes / bw
        return base * self._jit(v.mid, self.transfer_jitter)

    def _jit(self, mid: int, sigma: float) -> float:
        if sigma <= 0.0:
            return 1.0
        import math
        import random
        r = random.Random((self.seed << 20) ^ mid)
        return math.exp(r.gauss(0.0, sigma) - sigma * sigma / 2.0)

    def _revoked(self, mid: int) -> int:
        """Paired per-vertex draw: does this disk op hit a revoked extent?
        (Distinct stream from the jitter draw so enabling contention never
        reshuffles the jitter multipliers.)"""
        if self.pool_contention <= 0.0:
            return 0
        import random
        r = random.Random((self.seed << 21) ^ (mid * 2654435761))
        return int(r.random() < self.pool_contention)


@dataclasses.dataclass
class SimResult:
    makespan: float
    busy: dict[int, float]           # per device: compute-engine busy seconds
    stall: dict[int, float]          # per device: makespan - busy
    transfer_time: dict[str, float]  # per channel kind: total busy seconds
    n_vertices: int
    timeline: list[tuple[float, float, int, str, str]]  # t0,t1,dev,engine,name
    # per-vertex launch/completion instants: the simulator's schedule as
    # data, so a differential harness can *replay* exactly the order the
    # simulator chose through the sequential interpreter (topo_order keyed
    # by start_at) and prove it byte-exact against the oracle
    start_at: dict[int, float] = dataclasses.field(default_factory=dict)
    done_at: dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def total_stall(self) -> float:
        return sum(self.stall.values())


def simulate(mg: MemGraph, hw: HardwareModel | None = None, *,
             mode: str = "nondet",
             policy: str | DispatchPolicy | None = "fixed",
             record_timeline: bool = False,
             fused: dict[int, int] | None = None) -> SimResult:
    """Simulate one execution of ``mg`` under ``hw``; see module docstring.

    ``policy`` ranks the ready vertices queued on each engine in ``nondet``
    mode (default ``fixed`` = compile-order tie-break, the conservative
    baseline); it is ignored in ``fixed`` mode, which bypasses the ready
    queues entirely.

    ``fused`` prices the compiled backend's batched DMA submissions
    (DESIGN.md §15): a ``CompiledPlan.fused_map`` (member mid → batch-head
    mid). Non-head members ride the head's enqueue, so they skip the
    fixed per-transfer latency term; dependency structure is unchanged —
    fusion is a submission-cost optimisation, not a reordering.
    """
    hw = hw or HardwareModel()
    if mode not in ("nondet", "fixed"):
        raise ValueError(mode)
    # cost-aware policies rank by *this* machine's durations (jitter
    # included — it is deterministic per vertex), not the generic estimate.
    pol = get_policy(policy, seed=hw.seed, cost_fn=hw.duration)
    pol.prepare(mg)

    verts = mg.vertices
    devices = sorted({v.device for v in verts.values()})
    engines = [(d, k) for d in devices
               for k in (_COMPUTE, _H2D, _D2H, _D2D, _DISK, _NIC)]
    free_at = {e: 0.0 for e in engines}
    queue: dict[tuple[int, str], list] = {e: [] for e in engines}  # ready heaps
    remaining = {m: len(mg.preds[m]) for m in verts}
    launched: set[int] = set()
    done_at: dict[int, float] = {}
    events: list[tuple[float, int]] = []   # (completion time, mid)
    timeline: list[tuple[float, float, int, str, str]] = []
    busy = {d: 0.0 for d in devices}
    chan = {k: 0.0 for k in _TRANSFER_KINDS}

    by_seq = sorted(verts, key=lambda m: verts[m].seq)
    seq_ready: dict[int, float] = {}       # mid -> time deps completed
    next_issue = 0                          # fixed mode pointer into by_seq
    start_at: dict[int, float] = {}

    def engine_of(m: int) -> tuple[int, str]:
        v = verts[m]
        return (v.device, _ENGINE_OF[v.op])

    def start(m: int, now: float) -> None:
        e = engine_of(m)
        v = verts[m]
        t0 = max(now, free_at[e])
        dur = hw.duration(v, fused=fused is not None
                          and fused.get(m, m) != m)
        t1 = t0 + dur
        free_at[e] = t1
        start_at[m] = t0
        if e[1] == _COMPUTE:
            busy[v.device] += dur
        else:
            chan[e[1]] += dur
        if record_timeline:
            timeline.append((t0, t1, v.device, e[1], v.name or str(m)))
        heapq.heappush(events, (t1, m))
        launched.add(m)

    def on_ready(m: int, now: float) -> None:
        if mode == "fixed":
            seq_ready[m] = now
            return
        heapq.heappush(queue[engine_of(m)],
                       (pol.priority(m), verts[m].seq, m))

    def drain(now: float) -> None:
        if mode == "fixed":
            nonlocal next_issue
            while next_issue < len(by_seq) and by_seq[next_issue] in seq_ready:
                start(by_seq[next_issue], now)
                next_issue += 1
            return
        for e in engines:
            q = queue[e]
            while q and free_at[e] <= now:
                _, _, m = heapq.heappop(q)
                start(m, now)
            # engine busy past `now`: leave rest queued; they start when the
            # engine's current op completes (handled on that event)

    now = 0.0
    for m, r in remaining.items():
        if r == 0:
            on_ready(m, 0.0)
    drain(0.0)
    while events:
        now, m = heapq.heappop(events)
        if m in done_at:
            continue
        done_at[m] = now
        for s in mg.succs[m]:
            remaining[s] -= 1
            if remaining[s] == 0:
                on_ready(s, now)
        drain(now)
        # engines that just freed may have queued work
        if mode == "nondet":
            for e in engines:
                q = queue[e]
                while q and free_at[e] <= now:
                    _, _, mm = heapq.heappop(q)
                    start(mm, now)

    if len(done_at) != len(verts):
        raise AssertionError("simulation deadlocked — memgraph not runnable")
    makespan = now
    stall = {d: makespan - busy[d] for d in devices}
    return SimResult(makespan=makespan, busy=busy, stall=stall,
                     transfer_time=chan, n_vertices=len(verts),
                     timeline=sorted(timeline),
                     start_at=start_at, done_at=done_at)


# -- migration vs re-prefill pricing (serve/router.py, DESIGN.md §16) -------
# When a replica dies, every in-flight request must land on a survivor in
# one of two ways: *migrate* its host/disk-resident KV blocks over the NIC
# (warm) or *re-prefill* its prompt + emitted tokens from scratch (cold).
# Both paths are priced through `simulate()` on purpose-built micro-plans so
# the prediction shares the channel model (latencies, bandwidths, jitter)
# with every other figure instead of a parallel analytic formula.

def price_migration(hw: HardwareModel | None = None, *,
                    n_blocks: int,
                    block_nbytes: int,
                    disk_blocks: int = 0) -> float:
    """Predicted seconds to warm-migrate one request's KV state and make it
    decode-ready on the destination: per block, an optional disk LOAD (for
    the ``disk_blocks`` blocks resident on the source's disk tier at
    migration time), the NIC XFER, then the destination's h2d RELOAD. The
    three stages run on three independent engines, so the micro-plan
    pipelines exactly like the real transfer streams do."""
    hw = hw or HardwareModel()
    if not 0 <= disk_blocks <= n_blocks:
        raise ValueError(f"disk_blocks={disk_blocks} not in [0, {n_blocks}]")
    mg = MemGraph()
    seq = 0
    for b in range(n_blocks):
        prev = None
        stages = ([MemOp.LOAD] if b < disk_blocks else []) \
            + [MemOp.XFER, MemOp.RELOAD]
        for op in stages:
            m = mg.add_vertex(op, 0, nbytes=block_nbytes, seq=seq,
                              name=f"{op.value}:blk{b}")
            seq += 1
            if prev is not None:
                mg.add_dep(prev, m, DepKind.DATA)
            prev = m
    return simulate(mg, hw).makespan


def price_reprefill(hw: HardwareModel | None = None, *,
                    tokens: int,
                    flops_per_token: float,
                    kv_nbytes: int = 0) -> float:
    """Predicted seconds to cold-resume one request by re-prefilling its
    prompt plus already-emitted tokens on the destination (one batched
    prefill kernel; the KV bytes are produced on-device as a side effect,
    so no transfer channel is touched)."""
    hw = hw or HardwareModel()
    mg = MemGraph()
    mg.add_vertex(MemOp.COMPUTE, 0, flops=tokens * flops_per_token,
                  nbytes=kv_nbytes, seq=0, name=f"reprefill:{tokens}tok")
    return simulate(mg, hw).makespan


def migration_crossover(hw: HardwareModel | None = None, *,
                        block_size: int,
                        block_nbytes: int,
                        flops_per_token: float,
                        n_blocks_sweep: "list[int] | None" = None,
                        disk_frac: float = 0.0) -> list[dict]:
    """Sweep request sizes and report, per size, whether warm migration
    beats cold re-prefill on this hardware — the router's eviction-choice
    table and the BENCH crossover rows. ``disk_frac`` is the fraction of
    the request's blocks sitting on the source's disk tier at kill time."""
    hw = hw or HardwareModel()
    rows = []
    for nb in (n_blocks_sweep or [1, 2, 4, 8, 16, 32, 64]):
        tokens = nb * block_size
        t_mig = price_migration(hw, n_blocks=nb, block_nbytes=block_nbytes,
                                disk_blocks=int(round(nb * disk_frac)))
        t_pre = price_reprefill(hw, tokens=tokens,
                                flops_per_token=flops_per_token,
                                kv_nbytes=nb * block_nbytes)
        rows.append({
            "n_blocks": nb,
            "tokens": tokens,
            "migrate_s": t_mig,
            "reprefill_s": t_pre,
            "winner": "migrate" if t_mig <= t_pre else "reprefill",
        })
    return rows
