"""jit'd wrapper: pads C/F/D up to tile multiples."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import moe_gmm_kernel


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def moe_gmm(x, w, *, block_c: int = 128, block_f: int = 128,
            block_d: int = 512, interpret: bool = False):
    E, C, D = x.shape
    _, _, F = w.shape
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    pc, pf, pd = (-C) % bc, (-F) % bf, (-D) % bd
    if pc or pd:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        w = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    o = moe_gmm_kernel(x, w, block_c=bc, block_f=bf, block_d=bd,
                       interpret=interpret)
    return o[:, :C, :F]
