"""BUILDMEMGRAPH unit tests: the paper's running example + invariants."""
import numpy as np
import pytest

from repro.core import (BuildConfig, MemgraphOOM, MemOp, OpKind, TaskGraph,
                        build_memgraph)
from repro.core.runtime import eval_taskgraph, run_in_order

from helpers import fig3_taskgraph, int_inputs

SLOT = dict(size_fn=lambda v: 1)


class TestFig3:
    """Paper §4 example: 3 GPUs, shrinking slot budgets."""

    @pytest.mark.parametrize("cap", [5, 4, 3])
    def test_compiles_and_validates(self, cap):
        tg = fig3_taskgraph()
        res = build_memgraph(tg, BuildConfig(capacity=cap, **SLOT))
        res.memgraph.validate(check_races=True)
        assert max(res.peak_used.values()) <= cap

    def test_five_slots_needs_no_offload(self):
        res = build_memgraph(fig3_taskgraph(),
                             BuildConfig(capacity=5, **SLOT))
        assert res.n_offloads == 0 and res.n_reloads == 0

    def test_three_slots_offloads(self):
        res = build_memgraph(fig3_taskgraph(),
                             BuildConfig(capacity=3, **SLOT))
        assert res.n_reloads > 0

    def test_two_slots_ooms(self):
        # v4 needs two live inputs + its output on one device: 3 slots.
        with pytest.raises(MemgraphOOM):
            build_memgraph(fig3_taskgraph(), BuildConfig(capacity=2, **SLOT))

    @pytest.mark.parametrize("cap", [5, 4, 3])
    def test_outputs_match_oracle(self, cap):
        tg = fig3_taskgraph()
        inputs = int_inputs(tg)
        ref = eval_taskgraph(tg, inputs)
        res = build_memgraph(tg, BuildConfig(capacity=cap, **SLOT))
        out = run_in_order(tg, res, inputs)
        for k in ref:
            np.testing.assert_array_equal(out[k], ref[k])

    @pytest.mark.parametrize("policy", ["belady", "lru", "random"])
    def test_victim_policies(self, policy):
        tg = fig3_taskgraph()
        res = build_memgraph(tg, BuildConfig(
            capacity=3, victim_policy=policy, **SLOT))
        res.memgraph.validate(check_races=True)
        out = run_in_order(tg, res, int_inputs(tg))
        ref = eval_taskgraph(tg, int_inputs(tg))
        for k in ref:
            np.testing.assert_array_equal(out[k], ref[k])

    def test_paper_faithful_mode_offloads_inputs_too(self):
        """reuse_host_copy=False re-offloads evicted tensors even when a
        host copy exists (the paper's always-offload behaviour)."""
        tg = fig3_taskgraph()
        res_faithful = build_memgraph(tg, BuildConfig(
            capacity=3, reuse_host_copy=False, **SLOT))
        res_opt = build_memgraph(tg, BuildConfig(
            capacity=3, reuse_host_copy=True, **SLOT))
        assert res_faithful.n_offloads >= res_opt.n_offloads
        res_faithful.memgraph.validate(check_races=True)

    def test_superfluous_mem_deps_counted(self):
        """Paper Fig. 5: a mem dep duplicating a data dep is superfluous."""
        res = build_memgraph(fig3_taskgraph(),
                             BuildConfig(capacity=5, **SLOT))
        assert res.memgraph.superfluous_mem_deps >= 1

    def test_every_data_dep_preserved(self):
        """Correctness requirement (a) of §6: TASKGRAPH data deps appear in
        the MEMGRAPH, possibly via offload→reload chains."""
        tg = fig3_taskgraph()
        res = build_memgraph(tg, BuildConfig(capacity=3, **SLOT))
        mg = res.memgraph
        for tid, v in tg.vertices.items():
            for i in v.inputs:
                cons_mid = res.mid_of[tid]
                # walk data preds transitively through reloads
                frontier = set(mg.data_preds(cons_mid))
                seen = set(frontier)
                ok = False
                while frontier:
                    m = frontier.pop()
                    if mg.vertices[m].src_tid == i:
                        ok = True
                        break
                    if mg.vertices[m].op in (MemOp.RELOAD, MemOp.OFFLOAD):
                        for p in mg.data_preds(m):
                            if p not in seen:
                                seen.add(p)
                                frontier.add(p)
                assert ok, f"data dep {i}->{tid} lost"


class TestStreamingReduce:
    """§B: n-ary sum lowered to a locked sum-into group."""

    def _graph(self, n=6, width=8):
        tg = TaskGraph()
        ws = [tg.add_input(0, (width,), name=f"w{i}") for i in range(n)]
        ps = [tg.add_compute(0, (w,), (width,), op="relu", name=f"p{i}")
              for i, w in enumerate(ws)]
        r = tg.add_reduce(0, ps, streaming=True, name="acc")
        tg.add_compute(0, (r,), (width,), op="scale", params={"alpha": 2.0})
        return tg

    @pytest.mark.parametrize("cap_units", [64, 16, 8])
    def test_streams_under_pressure(self, cap_units):
        tg = self._graph()
        res = build_memgraph(tg, BuildConfig(capacity=cap_units * 8))
        res.memgraph.validate(check_races=True)
        ops = [v.op for v in res.memgraph.vertices.values()]
        assert ops.count(MemOp.ADD_INTO) == 6
        assert ops.count(MemOp.ALLOC0) == 1
        inputs = int_inputs(tg)
        out = run_in_order(tg, res, inputs)
        ref = eval_taskgraph(tg, inputs)
        for k in ref:
            np.testing.assert_array_equal(out[k], ref[k])

    def test_two_slots_stream(self):
        """Accumulator + one partial at a time — the paper's 'run them in
        sequence and offload' mode (§8)."""
        tg = self._graph()
        res = build_memgraph(tg, BuildConfig(capacity=2 * 8 * 8))
        assert res.n_reloads > 0
        res.memgraph.validate(check_races=True)


class TestVariableSizes:
    def test_mixed_sizes_fit_exactly(self):
        tg = TaskGraph()
        a = tg.add_input(0, (16,), name="a")
        b = tg.add_compute(0, (a,), (32,), op="concat", name="b")
        tg.vertices[b].op = "relu"
        tg.vertices[b].out = tg.vertices[b].out
        c = tg.add_compute(0, (a,), (8,), op="relu", name="c")
        d = tg.add_compute(0, (b, c), (8,), op="slice_rows", name="d")
        res = build_memgraph(
            tg, BuildConfig(capacity=64 * 8,
                            size_fn=lambda v: v.out.shape[0] * 8))
        res.memgraph.validate(check_races=True)

    def test_fragmentation_forces_eviction(self):
        tg = TaskGraph()
        h = tg.add_input(0, (4,), name="x0")
        for i in range(12):
            h = tg.add_compute(0, (h,), (4 if i % 2 else 6,), op="relu",
                               name=f"v{i}")
        res = build_memgraph(
            tg, BuildConfig(capacity=16, size_fn=lambda v: v.out.shape[0]))
        res.memgraph.validate(check_races=True)
        assert max(res.peak_used.values()) <= 16


def test_order_must_be_topological():
    tg = fig3_taskgraph()
    bad = list(reversed(sorted(tg.vertices)))
    with pytest.raises(ValueError):
        build_memgraph(tg, BuildConfig(capacity=5, **SLOT), order=bad)


def test_stats_shape():
    res = build_memgraph(fig3_taskgraph(), BuildConfig(capacity=3, **SLOT))
    s = res.memgraph.stats()
    assert s["n_vertices"] == len(res.memgraph)
    assert s["mem_deps"] > 0 and s["data_deps"] > 0
