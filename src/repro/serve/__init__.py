"""Online serving: continuous batching + block-paged KV-cache CPU offload,
and the fleet layer — an N-replica router with KV migration and
replica-kill fault tolerance (DESIGN.md §16)."""
from .engine import (Engine, ServeConfig, Request, ServeStats,
                     ReloadPolicy, RELOAD_POLICY_NAMES, get_reload_policy,
                     ReplicaKilled, MigrationRefused, MigrationTicket,
                     naive_generate)
from .kv_cache import PagedKVCache
from .router import (Router, RouterStats, PLACEMENT_POLICY_NAMES,
                     PlacementPolicy, get_placement,
                     encode_ticket, decode_ticket)

__all__ = ["Engine", "ServeConfig", "Request", "ServeStats", "ReloadPolicy",
           "RELOAD_POLICY_NAMES", "get_reload_policy", "naive_generate",
           "ReplicaKilled", "MigrationRefused", "MigrationTicket",
           "PagedKVCache", "Router", "RouterStats",
           "PLACEMENT_POLICY_NAMES", "PlacementPolicy", "get_placement",
           "encode_ticket", "decode_ticket"]
