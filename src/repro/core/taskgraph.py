"""TASKGRAPH intermediate representation (paper §4).

A TASKGRAPH is a dataflow DAG describing a multi-device computation:

* vertices are operations over tensors — either graph *inputs* (weights /
  activations, resident in the host store before execution), *compute* kernel
  calls bound to a specific device, device-to-device *transfers*, or n-ary
  commutative *reductions* (which may be lowered to streaming ``sum-into``
  groups per paper §B);
* edges represent data flow (``TaskVertex.inputs``).

TURNIP is agnostic about how the TASKGRAPH is produced (paper: FlexFlow /
Alpa); in this repo :mod:`repro.core.trace` builds them from model configs by
decomposing layer compute into sliced matmul fragments (paper Fig. 2/3).

Sizes are expressed in abstract *units* via a caller-supplied ``size_fn`` so
the same machinery serves the paper's uniform-slot presentation (Fig. 8:
``size_fn = lambda v: 1``) and the byte-granular "real life" variant (§6).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Iterable

import numpy as np

__all__ = ["OpKind", "TensorSpec", "TaskVertex", "TaskGraph", "GraphValidationError"]


class GraphValidationError(ValueError):
    """Raised when a TASKGRAPH violates a structural invariant."""


class OpKind(str, enum.Enum):
    INPUT = "input"        # graph input; lives in the host store pre-execution
    COMPUTE = "compute"    # kernel call on a specific device
    TRANSFER = "transfer"  # device-to-device copy (output lives on `device`)
    REDUCE = "reduce"      # n-ary commutative reduction (may stream, paper §B)


_DTYPE_BYTES = {
    "float32": 4, "bfloat16": 2, "float16": 2, "int32": 4, "int8": 1,
    "uint8": 1, "bool": 1, "float64": 8, "int64": 8,
}


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """Shape/dtype descriptor for a vertex output (no data)."""

    shape: tuple[int, ...]
    dtype: str = "float32"

    def __post_init__(self) -> None:
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        if self.dtype not in _DTYPE_BYTES:
            raise GraphValidationError(f"unknown dtype {self.dtype!r}")

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def nbytes(self) -> int:
        return self.size * _DTYPE_BYTES[self.dtype]

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)


@dataclasses.dataclass
class TaskVertex:
    """One operation in a TASKGRAPH."""

    tid: int
    kind: OpKind
    device: int
    inputs: tuple[int, ...]
    out: TensorSpec
    op: str = ""                 # op-registry name used by the runtime
    params: dict[str, Any] = dataclasses.field(default_factory=dict)
    flops: float = 0.0           # estimate for the simulator / roofline
    name: str = ""
    streaming: bool = False      # REDUCE only: lower to sum-into group (§B)

    def __post_init__(self) -> None:
        self.inputs = tuple(self.inputs)


class TaskGraph:
    """A dataflow DAG of :class:`TaskVertex`."""

    def __init__(self) -> None:
        self.vertices: dict[int, TaskVertex] = {}
        self._consumers: dict[int, list[int]] = {}
        self._next_tid = 0

    # -- construction -----------------------------------------------------
    def add(
        self,
        kind: OpKind | str,
        device: int,
        inputs: Iterable[int] = (),
        out: TensorSpec | tuple = (1,),
        *,
        op: str = "",
        params: dict | None = None,
        flops: float = 0.0,
        name: str = "",
        streaming: bool = False,
    ) -> int:
        kind = OpKind(kind)
        if not isinstance(out, TensorSpec):
            out = TensorSpec(tuple(out))
        tid = self._next_tid
        self._next_tid += 1
        inputs = tuple(inputs)
        for i in inputs:
            if i not in self.vertices:
                raise GraphValidationError(f"vertex {tid}: unknown input {i}")
        if kind == OpKind.INPUT and inputs:
            raise GraphValidationError("INPUT vertices take no inputs")
        if kind != OpKind.INPUT and not inputs:
            raise GraphValidationError(f"{kind} vertex {tid} needs inputs")
        v = TaskVertex(tid, kind, device, inputs, out, op=op,
                       params=dict(params or {}), flops=flops, name=name,
                       streaming=streaming)
        self.vertices[tid] = v
        self._consumers[tid] = []
        for i in inputs:
            self._consumers[i].append(tid)
        return tid

    # convenience wrappers
    def add_input(self, device: int, out, *, name: str = "", op: str = "input",
                  params: dict | None = None) -> int:
        return self.add(OpKind.INPUT, device, (), out, op=op, name=name, params=params)

    def add_compute(self, device: int, inputs, out, *, op: str, flops: float = 0.0,
                    params: dict | None = None, name: str = "") -> int:
        return self.add(OpKind.COMPUTE, device, inputs, out, op=op, flops=flops,
                        params=params, name=name)

    def add_transfer(self, device: int, src: int, *, name: str = "") -> int:
        spec = self.vertices[src].out
        return self.add(OpKind.TRANSFER, device, (src,), spec, op="copy", name=name)

    def add_reduce(self, device: int, inputs, out=None, *, streaming: bool = True,
                   op: str = "sum", name: str = "") -> int:
        inputs = tuple(inputs)
        spec = out if out is not None else self.vertices[inputs[0]].out
        return self.add(OpKind.REDUCE, device, inputs, spec, op=op, name=name,
                        streaming=streaming)

    # -- queries -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.vertices)

    def consumers(self, tid: int) -> tuple[int, ...]:
        return tuple(self._consumers[tid])

    def devices(self) -> tuple[int, ...]:
        return tuple(sorted({v.device for v in self.vertices.values()}))

    def topo_order(self) -> list[int]:
        """Kahn topo sort; raises on cycles. Insertion order is a valid topo
        order by construction (inputs must exist), but we re-derive it for
        validation and to support graph surgery."""
        indeg = {t: len(set(v.inputs)) for t, v in self.vertices.items()}
        ready = [t for t, d in indeg.items() if d == 0]
        order: list[int] = []
        while ready:
            t = ready.pop()
            order.append(t)
            for c in set(self._consumers[t]):
                uses = sum(1 for i in self.vertices[c].inputs if i == t)
                del uses  # duplicate inputs count once in indeg
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(order) != len(self.vertices):
            raise GraphValidationError("TASKGRAPH contains a cycle")
        return order

    def validate(self) -> None:
        self.topo_order()
        for v in self.vertices.values():
            if v.kind == OpKind.TRANSFER:
                src = self.vertices[v.inputs[0]]
                if src.device == v.device:
                    raise GraphValidationError(
                        f"transfer {v.tid} is a same-device copy ({v.device})")

    def total_flops(self) -> float:
        return sum(v.flops for v in self.vertices.values())

    def total_bytes(self, size_fn: Callable[[TaskVertex], int] | None = None) -> int:
        size_fn = size_fn or (lambda v: v.out.nbytes)
        return sum(size_fn(v) for v in self.vertices.values())

    def stats(self) -> dict[str, Any]:
        kinds: dict[str, int] = {}
        for v in self.vertices.values():
            kinds[v.kind.value] = kinds.get(v.kind.value, 0) + 1
        return {
            "n_vertices": len(self),
            "by_kind": kinds,
            "devices": self.devices(),
            "flops": self.total_flops(),
            "out_bytes": self.total_bytes(),
        }
