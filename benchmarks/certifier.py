"""Plan-certifier cost: certification time vs plan size on tiered-offload
plans (DESIGN.md §13), plus the liveness certifier's cost (§14) on the
same plans — vs plan size under the implied single-lease pool model, and
vs arbitration policy under a co-tenanted pool. Both are compile-time
tools — this prices what `BuildConfig(certify=True)` /
`BuildConfig(certify_liveness=True)` add to a build: the reachability
closure, the all-pairs overlap sweep, and the max-weight-antichain
budget/guarantee bounds, per MEMGRAPH vertex. Plans come from the
activation-offload workload (`tiered_offload.activation_workload`) with
the host tier bounded at half its working set, so every plan carries real
OFFLOAD/RELOAD traffic plus disk SPILL/LOAD chains."""
from __future__ import annotations

import time

from repro.core import BuildConfig, build_memgraph, certify
from repro.core.liveness import (LeaseSpec, PoolConfig, certify_progress,
                                 default_pool_config)
from repro.core.pool import ARBITRATION_POLICY_NAMES

from .common import emit
from .tiered_offload import activation_workload


def run(quick=False) -> list[dict]:
    rows = []
    layer_counts = (6, 12) if quick else (6, 12, 24, 48)
    last = None                      # (mg, host_cap) for the policy sweep
    for n_layers in layer_counts:
        tg = activation_workload(n_layers=n_layers)
        act_bytes = tg.vertices[0].out.nbytes
        cap = 6 * act_bytes          # tight device budget: acts offload
        probe = build_memgraph(tg, BuildConfig(capacity=cap))
        host_cap = max(1, probe.peak_host // 2)    # half the working set:
        t0 = time.time()                           # forces disk spills
        res = build_memgraph(tg, BuildConfig(capacity=cap,
                                             host_capacity=host_cap))
        build_s = time.time() - t0
        assert res.n_spills > 0, "workload stopped spilling to disk"
        mg = res.memgraph
        t0 = time.time()
        cert = certify(mg, host_capacity=host_cap)
        cert_s = time.time() - t0
        assert cert.ok, cert.summary()
        t0 = time.time()
        live = certify_progress(mg, default_pool_config(host_cap))
        live_s = time.time() - t0
        assert live.ok, live.summary()
        n = len(mg)
        last = (mg, host_cap)
        rows.append(dict(n_layers=n_layers, verts=n, build_s=build_s,
                         cert_s=cert_s, live_s=live_s,
                         pairs=cert.n_pairs_checked,
                         residencies=cert.n_host_residencies,
                         blobs=cert.n_disk_blobs,
                         worst_host=cert.worst_host_units,
                         worst_lease=live.worst_lease_units))
        emit(f"certifier/layers{n_layers}", cert_s / n * 1e6,
             f"verts={n};pairs={cert.n_pairs_checked};"
             f"res={cert.n_host_residencies};blobs={cert.n_disk_blobs};"
             f"cert_vs_build={cert_s / max(build_s, 1e-9):.2f}x")
        emit(f"liveness/layers{n_layers}", live_s / n * 1e6,
             f"verts={n};lease={live.worst_lease_units}"
             f"/{live.guaranteed_units};"
             f"spills={live.n_spills_checked};"
             f"live_vs_cert={live_s / max(cert_s, 1e-9):.2f}x")
    # liveness cost vs arbitration policy: the same (largest) plan under a
    # co-tenanted pool — the guarantee analysis runs the antichain bound
    # against the plan lease's floor whatever the policy grants above it
    mg, host_cap = last
    n = len(mg)
    for policy in ARBITRATION_POLICY_NAMES:
        pool_cfg = PoolConfig(
            capacity=2 * host_cap,
            leases=(LeaseSpec("plan", min_bytes=host_cap),
                    LeaseSpec("serve", discipline="reserving",
                              priority=1)),
            policy=policy, plan_lease="plan")
        t0 = time.time()
        live = certify_progress(mg, pool_cfg)
        live_s = time.time() - t0
        assert live.ok, live.summary()
        rows.append(dict(policy=policy, verts=n, live_s=live_s,
                         worst_lease=live.worst_lease_units))
        emit(f"liveness/policy_{policy}", live_s / n * 1e6,
             f"verts={n};lease={live.worst_lease_units}"
             f"/{live.guaranteed_units};"
             f"edges={live.n_blocking_edges}")
    return rows


if __name__ == "__main__":
    run()
