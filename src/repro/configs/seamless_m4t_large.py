"""seamless-m4t-large-v2 [audio]: enc-dec transformer backbone; the conformer
audio frontend is a STUB (input_specs provides precomputed frame embeddings).
[arXiv:2308.11596; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    norm="layernorm", mlp="gelu", rope_theta=1e4,
    n_decoder_layers=24,
    source="arXiv:2308.11596; hf",
)
