"""§Roofline: derive the three-term model per (arch × shape × mesh) from the
dry-run artifacts (deliverable g).

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (all-reduce counted once per the ring ≈ 2·(N-1)/N ≈ 2× factor noted in
EXPERIMENTS.md). HLO FLOPs/bytes come from the trip-folded HLO cost model
(XLA's cost_analysis counts scan bodies once — see launch/hlo_analysis.py).
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,           # one token per sequence
    "long_500k": 1,
}


def analyze(record: dict) -> dict:
    n = record["n_devices"]
    flops = record["cost"]["flops"]               # per device (trip-folded)
    byts = record["cost"]["bytes_accessed"]
    coll = record["collectives"]["total_bytes"]   # per device
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    toks = SHAPE_TOKENS[record["shape"]] if record["kind"] != "train" \
        else SHAPE_TOKENS["train_4k"]
    mult = 6 if record["kind"] == "train" else 2
    model_flops = mult * record["model"]["active_params"] * toks / n
    bound = max(t_c, t_m, t_x)
    return {
        "arch": record["arch"], "shape": record["shape"],
        "mesh": record["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": model_flops,
        "useful_ratio": model_flops / flops if flops else 0.0,
        "roofline_frac": (model_flops / PEAK_FLOPS) / bound if bound else 0.0,
        "peak_gib": record["memory"]["peak_bytes_per_device"] / 2**30,
        "fits_v5e": record["memory"]["peak_bytes_per_device"] < 16 * 2**30,
        "tag": record.get("tag", ""),
    }


def run(art_dir: str = "experiments/dryrun_v3", pod: str = "single",
        quick: bool = False) -> list[dict]:
    from .common import emit
    rows = []
    for f in sorted(glob.glob(f"{art_dir}/*__{pod}.json")):
        r = analyze(json.load(open(f)))
        rows.append(r)
        emit(f"roofline/{r['arch']}/{r['shape']}/{pod}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"dom={r['dominant']};frac={r['roofline_frac']:.3f};"
             f"useful={r['useful_ratio']:.2f};fits={r['fits_v5e']}")
    return rows


def table(art_dir: str = "experiments/dryrun_v3",
          pod: str = "single") -> str:
    rows = run(art_dir, pod)
    hdr = ("| arch | shape | compute s | memory s | coll s | dominant | "
           "MODEL/HLO | roofline frac | GiB/dev | fits v5e |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['peak_gib']:.2f} | "
            f"{'✓' if r['fits_v5e'] else '✗'} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(table())
