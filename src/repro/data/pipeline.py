"""Deterministic sharded data pipeline.

Production shape without external deps: a seeded synthetic token stream
(shift-register LM task — next token is a function of the previous ones, so
a real model can actually reduce loss on it), sharded by (host, step) with
O(1) skip-to-step for restart/elastic-rescale: batch contents depend only on
``(seed, step, global_batch)`` — never on worker count — so a checkpoint
restored at step N on a *different* topology still sees the same stream.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DataConfig", "SyntheticLMStream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLMStream:
    """batch(step, shard, n_shards) -> {'tokens','labels'} for that shard."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg

    def _sequence(self, idx: np.ndarray) -> np.ndarray:
        """Deterministic per-sample token sequence [len = seq_len + 1]."""
        cfg = self.cfg
        n = cfg.seq_len + 1
        rng_mat = np.arange(n, dtype=np.int64)[None, :]
        base = (idx[:, None] * 1_000_003 + cfg.seed * 7_777_777) % (2**31 - 1)
        x = (base + rng_mat * 69_069) % (2**31 - 1)
        # shift-register structure: token_t mixes token_{t-1}'s residue
        toks = np.zeros((len(idx), n), np.int64)
        toks[:, 0] = x[:, 0] % cfg.vocab_size
        for t in range(1, n):
            toks[:, t] = (toks[:, t - 1] * 31 + x[:, t]) % cfg.vocab_size
        return toks

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        cfg = self.cfg
        if cfg.global_batch % n_shards:
            raise ValueError("global_batch must divide by n_shards")
        per = cfg.global_batch // n_shards
        first = step * cfg.global_batch + shard * per
        idx = np.arange(first, first + per, dtype=np.int64)
        toks = self._sequence(idx)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
