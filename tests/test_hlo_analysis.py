"""Collective-bytes HLO parser (roofline input): synthetic HLO fixtures with
while-loop trip counts and async collective forms."""
from repro.launch.hlo_analysis import collective_bytes_from_hlo

HLO = """\
HloModule jit_step

%body.1 (arg.1: (s32[], bf16[128,256])) -> (s32[], bf16[128,256]) {
  %p = (s32[], bf16[128,256]) parameter(0)
  %ag.1 = bf16[128,256]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[64]{0} all-reduce(%y), to_apply=%add
}

%cond.1 (arg.2: (s32[], bf16[128,256])) -> pred[] {
  %it = s32[] get-tuple-element(%p), index=0
  %lim = s32[] constant(16)
  ROOT %cmp = pred[] compare(%it, %lim), direction=LT
}

ENTRY %main (a: bf16[128,256]) -> bf16[128,256] {
  %w = (s32[], bf16[128,256]) while(%init), condition=%cond.1, body=%body.1
  %rs = bf16[8,256]{1,0} reduce-scatter(%z), dimensions={0}
  %ags = bf16[512]{0} all-gather-start(%q)
  %agd = bf16[512]{0} all-gather-done(%ags)
  %cp = f32[32,32]{1,0} collective-permute(%r)
}
"""


def test_counts_and_kinds():
    out = collective_bytes_from_hlo(HLO)
    assert set(out["by_kind"]) >= {"all-gather", "all-reduce",
                                   "reduce-scatter", "collective-permute"}


def test_while_trip_count_folded():
    out = collective_bytes_from_hlo(HLO)
    # body all-gather: 128*256*2 bytes × 16 trips
    assert out["by_kind"]["all-gather"] >= 128 * 256 * 2 * 16
    # body all-reduce: 64*4 × 16
    assert out["by_kind"]["all-reduce"] == 64 * 4 * 16


def test_async_start_counted_done_not_double_counted():
    out = collective_bytes_from_hlo(HLO)
    ag = out["by_kind"]["all-gather"]
    assert ag == 128 * 256 * 2 * 16 + 512 * 2   # start counted once


def test_entry_level_ops():
    out = collective_bytes_from_hlo(HLO)
    assert out["by_kind"]["reduce-scatter"] == 8 * 256 * 2
    assert out["by_kind"]["collective-permute"] == 32 * 32 * 4
    assert out["total_bytes"] == sum(out["by_kind"].values())
