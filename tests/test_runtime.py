"""Threaded event-driven runtime (paper §5/§B): nondet vs fixed, latency
injection, ByteArena static placement."""
import numpy as np
import pytest

from repro.core import BuildConfig, MemOp, build_memgraph
from repro.core.runtime import (ByteArena, TurnipRuntime, eval_taskgraph,
                                run_in_order)

from helpers import fig3_taskgraph, int_inputs


@pytest.mark.parametrize("mode", ["nondet", "fixed"])
@pytest.mark.parametrize("cap", [5, 3])
def test_threaded_matches_oracle(mode, cap):
    tg = fig3_taskgraph()
    inputs = int_inputs(tg)
    ref = eval_taskgraph(tg, inputs)
    res = build_memgraph(tg, BuildConfig(capacity=cap, size_fn=lambda v: 1))
    rt = TurnipRuntime(tg, res, mode=mode, seed=0)
    rr = rt.run(inputs)
    for k in ref:
        np.testing.assert_array_equal(rr.outputs[k], ref[k])
    assert rr.makespan > 0
    assert set(rr.busy) == {0, 1, 2}


def test_latency_injection_still_correct():
    """Slow transfers (the paper's nondeterminism source) must not change
    results, only timing."""
    tg = fig3_taskgraph()
    inputs = int_inputs(tg)
    ref = eval_taskgraph(tg, inputs)
    res = build_memgraph(tg, BuildConfig(capacity=3, size_fn=lambda v: 1))

    def latency(v):
        return 0.003 if v.op in (MemOp.OFFLOAD, MemOp.RELOAD,
                                 MemOp.TRANSFER) else 0.0

    rr = TurnipRuntime(tg, res, mode="nondet", latency=latency, seed=1).run(inputs)
    for k in ref:
        np.testing.assert_array_equal(rr.outputs[k], ref[k])
    assert rr.offload_bytes >= 0 and rr.reload_bytes > 0


def test_many_seeds_nondet_equivalence():
    """Dispatch order is randomized by seed; outputs never change."""
    tg = fig3_taskgraph()
    inputs = int_inputs(tg)
    ref = eval_taskgraph(tg, inputs)
    res = build_memgraph(tg, BuildConfig(capacity=3, size_fn=lambda v: 1))
    for seed in range(6):
        rr = TurnipRuntime(tg, res, mode="nondet", seed=seed).run(inputs)
        for k in ref:
            np.testing.assert_array_equal(rr.outputs[k], ref[k])


def test_bytearena_static_placement():
    """Real preallocated per-device buffers: byte-accurate extents, no
    allocation during execution (paper §5)."""
    tg = fig3_taskgraph()
    inputs = int_inputs(tg, dtype=np.float32)
    ref = eval_taskgraph(tg, inputs)
    cap = 5 * 4 * 4 * 4   # five f32 4x4 tensors per device
    res = build_memgraph(tg, BuildConfig(capacity=cap))
    rt = TurnipRuntime(tg, res, backend="bytes",
                       capacities={d: cap for d in tg.devices()})
    rr = rt.run(inputs)
    for k in ref:
        np.testing.assert_allclose(rr.outputs[k], ref[k], rtol=1e-6)


def test_run_in_order_rejects_non_topological():
    tg = fig3_taskgraph()
    res = build_memgraph(tg, BuildConfig(capacity=5, size_fn=lambda v: 1))
    order = sorted(res.memgraph.vertices,
                   key=lambda m: -res.memgraph.vertices[m].seq)
    with pytest.raises(ValueError):
        run_in_order(tg, res, int_inputs(tg), order)
