"""Pure-jnp oracle: the model's chunked WKV6 (itself validated against a
naive per-token recurrence in the test suite)."""
from repro.models.rwkv import wkv6_chunked


def wkv6_ref(r, k, v, lw, u, chunk: int = 32):
    y, _ = wkv6_chunked(r, k, v, lw, u, chunk=chunk)
    return y
