"""Static liveness certifier (DESIGN.md §14): prove no legal schedule can
stall the pool-arbitrated runtime.

The safety certifier (:mod:`~repro.core.analyze`, §13) proves every
dependency-respecting execution order computes the right bytes; nothing
there proves every order *completes*. Since the shared host pool landed
(§12), completion is genuinely at risk: charge-before-submit lease
reservations, revocation drains routed through a consumer's own disk
stream, drop→spill capacity credits, bounded stream-class slots, and the
serving engine's all-or-nothing admission batches form a waits-for
structure that can circular-wait — and the only runtime guard was a
~10-second no-progress timer. This module replaces that band-aid with a
compile-time proof of deadlock freedom.

:func:`certify_progress` builds a **static blocking model** of the
runtime over a built :class:`~repro.core.memgraph.MemGraph` plus a
:class:`PoolConfig` (the lease population, floors, disciplines, and
declared revocation-drain routes) and a :class:`StreamConfig` (bounded
stream-class slots), and proves that from every reachable (down-closed
prefix, pool/lease occupancy) configuration at least one vertex is
enabled. Four theorems, each with a typed hazard on refutation:

1. **Lease-guarantee feasibility** — the plan's worst-case simultaneous
   host occupancy (the max-weight antichain of residency intervals,
   reusing :func:`~repro.core.analyze.max_weight_antichain` over the
   reachability bitsets) must fit the *guaranteed* share of the lease it
   charges: the inviolable floor, since any co-tenant's demand can revoke
   everything above it. An antichain exceeding the floor is a reachable
   configuration where a blocked admission waits on releases that are all
   its own descendants (``lease-floor-stall``).
2. **Disk-credit acyclicity** — a SPILL admitting a blob must, in at
   least one legal order, find its units free. If every blob that could
   free them has its drop *downstream* of the spill (the inverted image
   of the builder's drop→spill credit edges), every order stalls at the
   spill (``disk-credit-stall``).
3. **Revocation-drain acyclicity** — a revocation drain may only charge
   the leases its spec declares (``drains_via``); a cycle among draining
   leases is a configuration where each waits for room only the next can
   free (``revocation-cycle``). All-or-nothing admission batches larger
   than a lease's guaranteed share can refuse forever under revocation
   (``atomic-admission-stall``).
4. **Stream-slot sufficiency** — vertices that can block mid-admission
   under a reserving discipline must not be able to occupy every slot of
   a stream class that the unblocking releases also need
   (``stream-starvation``); the general residue is a cycle search over
   the resource-allocation graph (``waits-for-cycle``).

Every confirmable finding carries a **stuck-state witness**: a full
topological order plus a stall ``prefix`` and expected pool/lease
occupancy. The directed scheduler in :mod:`~repro.core.runtime`
(:func:`~repro.core.runtime.replay_stall`) replays the prefix against a
real :class:`~repro.core.pool.HostPool` with the blocking admission
discipline and confirms an actual bounded-timeout stall — liveness
findings stay falsifiable the same way §13's race witnesses do.

The proof's runtime assumptions (:data:`ASSUMPTIONS`) are threaded
through ``pool.py``/``stores.py``/``runtime.py``/``serve/engine.py`` as
checked invariants: a blocking edge the model does not contain raises
:class:`LivenessModelError` — certifier unsoundness, surfaced loudly.

CLI: ``python -m repro.core.liveness`` certifies progress for the seeded
example-plan corpus (the same distribution as the §13 gate) and exits
nonzero on any hazard; CI gates on it alongside the safety step.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Any, Iterable, Mapping, Sequence

from .analyze import (PlanHazard, Residency, _witness_order,
                      max_weight_antichain, recover_residencies)
from .dispatch import COMPUTE, D2D, D2H, DISK, H2D
from .memgraph import MemGraph, MemOp, RaceError

__all__ = [
    "LeaseSpec", "PoolConfig", "StreamConfig", "LivenessCertificate",
    "ProgressCertificationError", "LivenessModelError", "certify_progress",
    "default_pool_config", "inline_seam_certified", "ASSUMPTIONS", "main",
]

# hazard kinds (PlanHazard.kind; witness_kind == "stall" when confirmable)
LEASE_FLOOR_STALL = "lease-floor-stall"
FLOORS_INFEASIBLE = "lease-floors-infeasible"
REVOCATION_CYCLE = "revocation-cycle"
ATOMIC_ADMISSION_STALL = "atomic-admission-stall"
DISK_CREDIT_STALL = "disk-credit-stall"
STREAM_STARVATION = "stream-starvation"
WAITS_FOR_CYCLE = "waits-for-cycle"
LIVENESS_STRUCTURE = "liveness-structure"

#: The runtime invariants the deadlock-freedom proof assumes. Each is
#: enforced as a checked invariant at the named seam; a violation raises
#: :class:`LivenessModelError` (certifier unsoundness), mirroring
#: ``runtime._certified_reraise`` for §13.
ASSUMPTIONS: tuple[str, ...] = (
    "A1 (stores.py/pool.py): a plan-driven occupancy lease never holds "
    "more than its certified guaranteed share — Lease.certified_floor is "
    "checked on every occupancy mirror.",
    "A2 (pool.py): a revocation drain only charges the leases declared "
    "in its spec's drains_via — HostPool.draining() marks the drain and "
    "try_charge rejects undeclared blocking edges.",
    "A3 (pool.py/lockcheck.py): revocation callbacks fire outside the "
    "pool lock and are non-blocking pressure signals; the lock-order "
    "sanitizer keeps the pool a leaf lock.",
    "A4 (serve/engine.py): the engine's no-progress detector is "
    "statically unreachable for a liveness-certified configuration — if "
    "it fires anyway it raises LivenessModelError with the live "
    "waits-for graph.",
)


class LivenessModelError(RaceError):
    """A blocking edge (or occupancy) outside the static model showed up
    at runtime: the liveness certifier is unsound or the runtime diverged
    from the plan/configuration it certified (DESIGN.md §14)."""


# --------------------------------------------------------------------------
# the static blocking model's inputs
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LeaseSpec:
    """One lease of the modeled pool.

    ``discipline`` names the charge style (DESIGN.md §12): ``"occupancy"``
    mirrors resident bytes unconditionally (a compiled plan — never blocks,
    but must stay within its certified floor, assumption A1);
    ``"reserving"`` charges before moving bytes and *blocks/defers* on
    refusal (the serving engine) — the discipline the stall replays use.

    ``drains_via`` declares every lease this lease's revocation drain may
    charge while freeing bytes (staging buffers, bounce pools). An
    undeclared drain charge at runtime violates assumption A2.
    ``drain_stream`` is the stream class the drain's writes ride.
    ``atomic_bytes`` is the largest all-or-nothing charge batch the
    consumer submits (the serve engine's swap-in/preemption sets)."""

    name: str
    min_bytes: int = 0
    weight: float = 1.0
    priority: int = 0
    discipline: str = "occupancy"
    drains_via: tuple[str, ...] = ()
    drain_stream: str = DISK
    atomic_bytes: int = 0


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """The modeled :class:`~repro.core.pool.HostPool`: capacity, lease
    population, arbitration policy, and which lease the plan's host tier
    charges (``plan_lease``)."""

    capacity: int
    leases: tuple[LeaseSpec, ...] = ()
    policy: str = "static"
    plan_lease: str | None = None

    def spec(self, name: str | None) -> LeaseSpec | None:
        for s in self.leases:
            if s.name == name:
                return s
        return None

    def guaranteed_bytes(self, name: str | None) -> int:
        """The share the arbiter can honor for the lease's whole lifetime
        under *any* co-tenant behavior: with co-tenants, the inviolable
        floor (everything above it is revocable slack); alone, the whole
        pool."""
        s = self.spec(name)
        if s is None:
            return 0
        if len(self.leases) <= 1:
            return self.capacity
        return s.min_bytes


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Bounded stream-class slots — the runtime's engine fleet
    (``TurnipRuntime(n_streams=, n_transfer_streams=)``)."""

    slots: Mapping[str, int]

    @staticmethod
    def default(n_streams: int = 5,
                n_transfer_streams: int = 1) -> "StreamConfig":
        return StreamConfig(slots={
            COMPUTE: n_streams, H2D: n_transfer_streams,
            D2H: n_transfer_streams, D2D: n_transfer_streams,
            DISK: n_transfer_streams})

    def slots_of(self, kind: str) -> int:
        return int(self.slots.get(kind, 1))


def default_pool_config(host_capacity: int | None, *,
                        lease: Any = None) -> PoolConfig | None:
    """The pool model a plain build implies: the compiled plan as the only
    consumer of its private host budget — or, when the build charged a
    real :class:`~repro.core.pool.Lease`, the lease's actual pool
    population (co-tenants modeled as reserving consumers, the worst case
    for the plan's guarantee)."""
    if lease is not None:
        specs = []
        for l in lease.pool.leases():
            specs.append(LeaseSpec(
                name=l.name, min_bytes=l.min_bytes, weight=l.weight,
                priority=l.priority,
                discipline="occupancy" if l.name == lease.name
                else "reserving",
                drains_via=tuple(getattr(l, "drains_via", ()))))
        return PoolConfig(capacity=lease.pool.capacity,
                          leases=tuple(specs),
                          policy=getattr(lease.pool.policy, "name",
                                         "static"),
                          plan_lease=lease.name)
    if host_capacity is None:
        return None
    return PoolConfig(capacity=host_capacity,
                      leases=(LeaseSpec("memgraph",
                                        min_bytes=host_capacity),),
                      plan_lease="memgraph")


# --------------------------------------------------------------------------
# the certificate
# --------------------------------------------------------------------------
@dataclasses.dataclass
class LivenessCertificate:
    """The liveness certifier's verdict over one (plan, pool, streams)
    configuration. ``worst_lease_units`` is the exact worst-case
    simultaneous host occupancy over all legal orders (max-weight
    antichain); ``guaranteed_units`` the share the arbiter can always
    honor; certification requires the first to fit the second."""

    ok: bool
    hazards: list[PlanHazard]
    n_vertices: int
    pool: PoolConfig | None = None
    streams: StreamConfig | None = None
    disk_capacity: int | None = None
    worst_lease_units: int = 0
    guaranteed_units: int | None = None
    n_blocking_edges: int = 0          # edges in the static waits-for graph
    n_spills_checked: int = 0          # disk admissions proven creditable

    def summary(self) -> str:
        head = "LIVE" if self.ok else f"{len(self.hazards)} hazard(s)"
        pool = (f"{len(self.pool.leases)} lease(s) over "
                f"{self.pool.capacity} B" if self.pool else "no pool")
        lines = [
            f"liveness certificate: {head} over {self.n_vertices} "
            f"vertices ({pool}, {self.n_blocking_edges} blocking edges, "
            f"{self.n_spills_checked} disk admissions)",
            f"  worst-case lease occupancy {self.worst_lease_units} units"
            + (f" / guaranteed {self.guaranteed_units}"
               if self.guaranteed_units is not None else " (unarbitrated)"),
        ]
        lines += [f"  {h}" for h in self.hazards]
        lines += [f"  assumes {a}" for a in ASSUMPTIONS]
        return "\n".join(lines)


class ProgressCertificationError(RaceError):
    """A configuration failed liveness certification: some legal schedule
    can stall the pool-arbitrated runtime (fail at compile time, not as a
    10-second timeout in production)."""

    def __init__(self, certificate: LivenessCertificate) -> None:
        super().__init__(certificate.summary())
        self.certificate = certificate


# --------------------------------------------------------------------------
# the certifier
# --------------------------------------------------------------------------
class _Progress:
    def __init__(self, mg: MemGraph, pool: PoolConfig | None,
                 streams: StreamConfig, disk_capacity: int | None,
                 max_hazards: int) -> None:
        self.mg = mg
        self.pool = pool
        self.streams = streams
        self.disk_capacity = disk_capacity
        self.max_hazards = max_hazards
        self.hazards: list[PlanHazard] = []
        self._seen: set[tuple[Any, ...]] = set()
        self.n_blocking_edges = 0
        self.n_spills_checked = 0
        self.worst_lease_units = 0
        self.guaranteed_units: int | None = None
        # per-class blocking-capable vertices (filled by the lease/disk
        # passes, consumed by the stream pass and the RAG search)
        self._blockers: dict[str, list[int]] = {}

    def full(self) -> bool:
        return len(self.hazards) >= self.max_hazards

    def emit(self, kind: str, vertices: tuple[int, ...], detail: str,
             **kw: Any) -> None:
        dedup = (kind,) + tuple(sorted(vertices)) + (kw.get("lease"),)
        if dedup in self._seen or self.full():
            return
        self._seen.add(dedup)
        self.hazards.append(PlanHazard(kind, vertices, detail, **kw))

    # ---- pool-structural checks (no graph needed) --------------------
    def pass_pool_structure(self) -> None:
        pool = self.pool
        if pool is None:
            return
        floors = sum(s.min_bytes for s in pool.leases)
        if floors > pool.capacity:
            self.emit(
                FLOORS_INFEASIBLE, (),
                f"lease floors sum to {floors} B over a {pool.capacity} B "
                f"pool — HostPool refuses the population at lease time, "
                f"so the configuration can never start",
                confirmable=False)
        for s in pool.leases:
            if s.discipline != "reserving" or s.atomic_bytes <= 0:
                continue
            guaranteed = pool.guaranteed_bytes(s.name)
            if s.atomic_bytes > guaranteed:
                self.emit(
                    ATOMIC_ADMISSION_STALL, (),
                    f"lease {s.name!r} submits all-or-nothing batches of "
                    f"{s.atomic_bytes} B but is guaranteed only "
                    f"{guaranteed} B: under full revocation the batch "
                    f"refuses forever and FIFO admission wedges behind it",
                    witness_kind="stall", lease=s.name,
                    expect_units=s.atomic_bytes, capacity=guaranteed)

    # ---- revocation-drain waits-for edges ----------------------------
    def _drain_edges(self) -> list[tuple[str, str]]:
        """lease→lease blocking edges: freeing ``a``'s bytes requires
        first charging ``b``. Only meaningful with co-tenants — a lone
        lease is never revoked."""
        pool = self.pool
        if pool is None or len(pool.leases) <= 1:
            return []
        edges = []
        for s in pool.leases:
            for tgt in s.drains_via:
                if pool.spec(tgt) is not None:
                    edges.append((s.name, tgt))
        return edges

    def pass_revocation_cycles(self) -> None:
        edges = self._drain_edges()
        self.n_blocking_edges += len(edges)
        graph: dict[str, list[str]] = {}
        for a, b in edges:
            graph.setdefault(a, []).append(b)
        cyc = _find_cycle(graph)
        if cyc is not None:
            self.emit(
                REVOCATION_CYCLE, (),
                f"revocation drains form a waits-for cycle "
                f"{' -> '.join(cyc)}: once every lease on the cycle is in "
                f"overage, each can free bytes only by charging the next, "
                f"every charge is refused, and the pool is wedged",
                witness_kind="stall", lease=cyc[0],
                capacity=self.pool.capacity if self.pool else None)

    # ---- lease-guarantee feasibility over the plan -------------------
    def pass_lease_guarantee(
            self, host: list[Residency]) -> None:
        mg, pool = self.mg, self.pool
        if pool is None or pool.plan_lease is None:
            # no arbitration: the safety certifier's host_capacity bound
            # is the only budget story, and nothing can block on a lease
            return
        guaranteed = pool.guaranteed_bytes(pool.plan_lease)
        self.guaranteed_units = guaranteed
        if not host:
            return
        before = mg.happens_before
        prec = [(i, j)
                for i, ri in enumerate(host)
                for j, rj in enumerate(host)
                if i != j and ri.release is not None
                and before(ri.release, rj.admit)]
        weights = [r.units for r in host]
        worst, members = max_weight_antichain(weights, prec)
        self.worst_lease_units = worst
        if worst <= guaranteed:
            return
        admits = [host[i].admit for i in members]
        bitpos, desc = mg.reachability()
        abits = [bitpos[a] for a in admits]
        down = {m for m in mg.vertices
                if m in admits
                or any((desc[m] >> b) & 1 for b in abits)}
        order = tuple(mg.topo_order(
            key=lambda m: (0 if m in down else 1, mg.vertices[m].seq, m)))
        spec = pool.spec(pool.plan_lease)
        style = ("a blocked reserving admission waits on releases that "
                 "are all its own descendants"
                 if spec is not None and spec.discipline == "reserving"
                 else "the certified floor (assumption A1) is broken and "
                      "a reserving co-tenant blocks past its guarantee")
        self.emit(
            LEASE_FLOOR_STALL, tuple(admits),
            f"plan lease {pool.plan_lease!r} can be forced to hold "
            f"{worst} units simultaneously (admits {admits}) but the "
            f"arbiter guarantees only {guaranteed}: under full "
            f"revocation {style}",
            witness=order, witness_kind="stall", tier="host",
            prefix=len(down), expect_units=worst, capacity=guaranteed,
            lease=pool.plan_lease)
        stream = spec.drain_stream if spec is not None else DISK
        self._blockers.setdefault(D2H, []).extend(
            a for a in admits if mg.vertices[a].op == MemOp.OFFLOAD)
        self._blockers.setdefault(stream, []).extend(
            a for a in admits if mg.vertices[a].op == MemOp.LOAD)

    # ---- disk-credit acyclicity --------------------------------------
    def pass_disk_credits(self, disk: list[Residency]) -> None:
        """Every blob admission must find its units free in at least one
        legal order. ``must-live(s)`` — blobs admitted before ``s`` in
        *every* order whose drop can never precede ``s`` — is the part of
        the disk no schedule can clear first; if it plus ``s``'s own
        units exceeds the capacity, every order stalls at ``s``. This is
        the inverted image of the builder's drop→spill credit edges
        (``_disk_admit``): a credit edge pointing the wrong way makes the
        backing drop a descendant of the spill it should precede."""
        mg, cap = self.mg, self.disk_capacity
        if cap is None or not disk:
            return
        before = mg.happens_before
        for s in disk:
            self.n_spills_checked += 1
            must = [r for r in disk
                    if r is not s and before(r.admit, s.admit)
                    and (r.release is None
                         or before(s.admit, r.release))]
            held = sum(r.units for r in must)
            if held + s.units <= cap:
                continue
            order = _witness_order(mg, {s.admit}, set())
            prefix = order.index(s.admit)
            self.emit(
                DISK_CREDIT_STALL,
                (s.admit,) + tuple(r.admit for r in must),
                f"spill {s.admit} needs {s.units} unit(s) of disk but "
                f"blobs {[r.admit for r in must]} ({held} unit(s)) are "
                f"live before it in every order and every drop that "
                f"could free them is downstream of the spill — the "
                f"disk-credit FIFO waits on itself "
                f"({held}+{s.units} > capacity {cap})",
                witness=tuple(order), witness_kind="stall", tier="disk",
                prefix=prefix, expect_units=held + s.units, capacity=cap)
            self._blockers.setdefault(DISK, []).append(s.admit)
            if self.full():
                return

    # ---- stream-slot sufficiency + the RAG residue -------------------
    def pass_streams_and_rag(self) -> None:
        """The unifying cycle search over the resource-allocation graph:
        nodes are leases, stream classes, and the disk tier; an edge
        a → b means "freeing/advancing a can require b". The passes above
        are the cycles with a specific story; anything left is reported
        as a bare waits-for cycle."""
        mg, streams = self.mg, self.streams
        before = mg.happens_before
        # stream starvation: blockers of class k can hold every slot of k
        # while the releases that would unblock them also need class k
        for kind, blockers in sorted(self._blockers.items()):
            blockers = sorted(set(blockers))
            if not blockers:
                continue
            slots = streams.slots_of(kind)
            # pairwise-incomparable blockers are jointly schedulable: each
            # can sit blocked on its own slot at once
            incomp = _max_incomparable(blockers, before)
            if len(incomp) >= slots and kind == DISK:
                self.emit(
                    STREAM_STARVATION, tuple(incomp),
                    f"{len(incomp)} admissions that can block "
                    f"(vertices {incomp}) share the {slots}-slot "
                    f"{kind!r} stream class with the releases that would "
                    f"unblock them: once every slot holds a blocked "
                    f"admission no release can be issued",
                    confirmable=False, tier=kind)
        # the RAG residue
        graph: dict[str, list[str]] = {}
        pool = self.pool
        if pool is not None:
            for a, b in self._drain_edges():
                graph.setdefault(f"lease:{a}", []).append(f"lease:{b}")
            for s in pool.leases:
                if len(pool.leases) > 1:
                    # freeing a revoked lease's overage rides its drain
                    # stream
                    graph.setdefault(f"lease:{s.name}", []).append(
                        f"stream:{s.drain_stream}")
        for kind, blockers in self._blockers.items():
            if not blockers or pool is None:
                continue
            # a slot of `kind` can be held by a vertex blocked on the
            # plan lease (host admits) or the disk tier (spills)
            tgt = (f"lease:{pool.plan_lease}"
                   if pool.plan_lease is not None else None)
            if kind == DISK and self.disk_capacity is not None:
                graph.setdefault(f"stream:{kind}", []).append("disk")
                graph.setdefault("disk", []).append(f"stream:{DISK}")
            if tgt is not None and kind != DISK:
                graph.setdefault(f"stream:{kind}", []).append(tgt)
        self.n_blocking_edges += sum(len(v) for v in graph.values())
        cyc = _find_cycle(graph)
        if cyc is not None and not any(
                h.kind in (REVOCATION_CYCLE, STREAM_STARVATION,
                           DISK_CREDIT_STALL)
                for h in self.hazards):
            self.emit(
                WAITS_FOR_CYCLE, (),
                f"the static waits-for graph has a cycle "
                f"{' -> '.join(cyc)} not discharged by any specific "
                f"theorem — some configuration of blocked holders can "
                f"circular-wait",
                confirmable=False)


def _max_incomparable(vertices: Sequence[int],
                      before: Any) -> list[int]:
    """A maximal pairwise-incomparable subset (greedy — used only to
    compare against a slot count, where any witness set suffices)."""
    out: list[int] = []
    for v in vertices:
        if all(not before(v, u) and not before(u, v) for u in out):
            out.append(v)
    return out


def _find_cycle(graph: Mapping[str, Iterable[str]]) -> list[str] | None:
    """First cycle of a small digraph (3-color DFS), as a node list."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color: dict[str, int] = {}
    stack: list[str] = []

    def visit(n: str) -> list[str] | None:
        color[n] = GRAY
        stack.append(n)
        for m in sorted(graph.get(n, ())):
            c = color.get(m, WHITE)
            if c == GRAY:
                return stack[stack.index(m):] + [m]
            if c == WHITE:
                cyc = visit(m)
                if cyc is not None:
                    return cyc
        color[n] = BLACK
        stack.pop()
        return None

    for n in sorted(graph):
        if color.get(n, WHITE) == WHITE:
            cyc = visit(n)
            if cyc is not None:
                return cyc
    return None


def certify_progress(mg: MemGraph, pool_config: PoolConfig | None = None,
                     stream_config: StreamConfig | None = None, *,
                     disk_capacity: int | None = None,
                     max_hazards: int = 64) -> LivenessCertificate:
    """Certify that no dependency-respecting execution order of ``mg``
    can stall under the modeled pool arbitration and stream fleet: from
    every reachable (down-closed prefix, pool occupancy) configuration at
    least one vertex is enabled."""
    streams = stream_config or StreamConfig.default()
    cert = LivenessCertificate(ok=True, hazards=[], n_vertices=len(mg),
                               pool=pool_config, streams=streams,
                               disk_capacity=disk_capacity)
    try:
        mg.topo_order()
    except RaceError:
        cert.ok = False
        cert.hazards.append(PlanHazard(
            LIVENESS_STRUCTURE, (),
            "MEMGRAPH contains a dependency cycle: the vertices on it "
            "are never enabled in any order", confirmable=False))
        return cert
    p = _Progress(mg, pool_config, streams, disk_capacity, max_hazards)
    p.hazards = cert.hazards
    p.pass_pool_structure()
    p.pass_revocation_cycles()
    host, disk = recover_residencies(mg)
    p.pass_lease_guarantee(host)
    p.pass_disk_credits(disk)
    p.pass_streams_and_rag()
    cert.worst_lease_units = p.worst_lease_units
    cert.guaranteed_units = p.guaranteed_units
    cert.n_blocking_edges = p.n_blocking_edges
    cert.n_spills_checked = p.n_spills_checked
    cert.ok = not cert.hazards
    return cert


# vertices whose execution charges a bounded admission gate (the pool's
# lease accounting or the disk tier's capacity) — the ops the blocking
# model prices as potential waits (§14's blocking edges)
_ADMISSION_OPS = (MemOp.OFFLOAD, MemOp.SPILL, MemOp.LOAD)


def inline_seam_certified(mg: MemGraph, mids: Sequence[int],
                          cert: LivenessCertificate | None) -> bool:
    """Is "no blocking waits on the calling thread" a *certified*
    property for the seam ``mids`` (DESIGN.md §17)?

    The inline executor runs a nondet seam on the calling thread, so a
    vertex that blocks mid-admission would stall the whole runtime loop
    — there is no other worker to free the resource it waits on. The
    claim is certified two ways:

    * the plan carries an ``ok`` liveness certificate: §14's blocking
      model already proved every pool/disk admission in the plan finds
      its bytes free in every legal order, which covers the calling
      thread as a degenerate one-worker schedule; or
    * the seam contains **no admission vertex at all** (no OFFLOAD /
      SPILL / LOAD member): vertices that never charge a bounded gate
      have no blocking edges in the model, vacuously.

    When neither holds, the compiler demotes the seam to the threaded
    backend, where a blocked admission only parks one worker stream.
    """
    if cert is not None and cert.ok:
        return True
    return not any(mg.vertices[m].op in _ADMISSION_OPS for m in mids)


# --------------------------------------------------------------------------
# CLI: liveness-certify the seeded example-plan corpus (CI gate)
# --------------------------------------------------------------------------
def main(argv: Sequence[str] | None = None) -> int:
    import random as pyrandom

    from .analyze import _corpus_taskgraph
    from .build import BuildConfig, MemgraphOOM, build_memgraph

    p = argparse.ArgumentParser(
        prog="python -m repro.core.liveness",
        description="Liveness-certify the seeded example-plan corpus: "
                    "every buildable plan must prove stall-free for all "
                    "execution orders under its implied pool model "
                    "(DESIGN.md §14).")
    p.add_argument("--seeds", type=int, default=24,
                   help="corpus size (default 24)")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="print one summary line per plan")
    args = p.parse_args(argv)

    host_caps = (None, 1, 2, 3)
    disk_caps = (None, 0, 2, 4, 50)
    n_live = n_oom = 0
    failed = 0
    for seed in range(args.seeds):
        rng = pyrandom.Random(1000 + seed)
        tg = _corpus_taskgraph(rng)
        host_cap = rng.choice(host_caps)
        disk_cap = rng.choice(disk_caps) if host_cap is not None else None
        cfg = BuildConfig(capacity=3, host_capacity=host_cap,
                          disk_capacity=disk_cap, rng_seed=seed,
                          size_fn=lambda v: 1)
        try:
            res = build_memgraph(tg, cfg)
        except MemgraphOOM:
            n_oom += 1
            if args.verbose:
                print(f"seed {seed}: rejected at compile time (OOM)")
            continue
        cert = certify_progress(
            res.memgraph, default_pool_config(host_cap),
            disk_capacity=disk_cap)
        if cert.ok:
            n_live += 1
            if args.verbose:
                g = cert.guaranteed_units
                print(f"seed {seed}: live "
                      f"(lease≤{cert.worst_lease_units}"
                      f"/{g if g is not None else '∞'}, "
                      f"{cert.n_spills_checked} disk admissions)")
        else:
            failed += 1
            print(f"seed {seed}: FAILED liveness certification")
            print(cert.summary())
    print(f"corpus: {n_live} plans certified live, {n_oom} rejected at "
          f"compile time, {failed} failed")
    return 1 if failed else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
