"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block invoked
periodically. [arXiv:2411.15242; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="zamba",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    norm="rmsnorm", mlp="swiglu", rope_theta=1e4,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, zamba_group=6,
    subquadratic=True,
    source="arXiv:2411.15242; unverified",
)
