"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, q_offset: int = 0):
    """q: [B, Hq, Sq, Dh]; k/v: [B, Hkv, Skv, Dh] — materializes the full
    score matrix (f32), the correctness oracle for the Pallas kernel."""
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) / math.sqrt(Dh)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Skv)
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, Hq, Sq, Dh).astype(q.dtype)
