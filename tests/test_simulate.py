"""Discrete-event simulator: modes, determinism, stall accounting."""
import pytest

from repro.core import BuildConfig, TaskGraph, build_memgraph
from repro.core.simulate import HardwareModel, simulate

from helpers import fig3_taskgraph


def layered_graph(L=6, T=4, D=512, B=256):
    tg = TaskGraph()
    x = tg.add_input(0, (B, D), name="x")
    h = x
    tile = D // T
    for l in range(L):
        tiles = []
        for t in range(T):
            w = tg.add_input(0, (D, tile), name=f"w{l}.{t}")
            tiles.append(tg.add_compute(0, (h, w), (B, tile), op="matmul",
                                        flops=2 * B * D * tile,
                                        name=f"mm{l}.{t}"))
        cat = tg.add_compute(0, tuple(tiles), (B, D), op="concat",
                             params={"axis": -1}, name=f"cat{l}")
        h = tg.add_compute(0, (cat,), (B, D), op="gelu", flops=8 * B * D,
                           name=f"act{l}")
    return tg


def test_simulates_all_vertices():
    tg = fig3_taskgraph()
    res = build_memgraph(tg, BuildConfig(capacity=3, size_fn=lambda v: 1))
    sim = simulate(res.memgraph, HardwareModel())
    assert sim.n_vertices == len(res.memgraph)
    assert sim.makespan > 0


@pytest.mark.parametrize("mode", ["nondet", "fixed"])
def test_deterministic_given_seed(mode):
    tg = layered_graph()
    res = build_memgraph(tg, BuildConfig(capacity=2 * 512 * 256 * 4))
    hw = HardwareModel(transfer_jitter=0.7, seed=3)
    a = simulate(res.memgraph, hw, mode=mode)
    b = simulate(res.memgraph, hw, mode=mode)
    assert a.makespan == b.makespan


def test_fixed_never_faster_than_nondet_with_jitter():
    tg = layered_graph(L=8, T=8)
    res = build_memgraph(tg, BuildConfig(capacity=3 * 512 * 256 * 4))
    worse = 0
    for seed in range(5):
        hw = HardwareModel(transfer_jitter=1.0, seed=seed)
        nd = simulate(res.memgraph, hw, mode="nondet")
        fx = simulate(res.memgraph, hw, mode="fixed")
        assert fx.makespan >= nd.makespan * 0.999
        worse += fx.makespan > nd.makespan * 1.001
    assert worse >= 1   # jitter must hurt the fixed order somewhere


def test_memory_pressure_increases_makespan():
    tg = layered_graph(L=8, T=8)
    big = build_memgraph(tg, BuildConfig(capacity=64 * 512 * 256 * 4))
    small = build_memgraph(tg, BuildConfig(capacity=int(3 * 512 * 256 * 4)))
    hw = HardwareModel()
    assert simulate(small.memgraph, hw).makespan >= \
        simulate(big.memgraph, hw).makespan


def test_timeline_recording():
    tg = fig3_taskgraph()
    res = build_memgraph(tg, BuildConfig(capacity=5, size_fn=lambda v: 1))
    sim = simulate(res.memgraph, HardwareModel(), record_timeline=True)
    assert len(sim.timeline) == sim.n_vertices
    for t0, t1, dev, eng, _name in sim.timeline:
        assert t1 >= t0 >= 0
