"""Fused RMSNorm Pallas TPU kernel: one HBM read, one write per row block
(XLA would otherwise emit separate square/mean/rsqrt/mul passes for f32
accumulation of a bf16 input)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # [br, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_kernel(x, g, *, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = False):
    """x: [N, D] (caller flattens leading dims); g: [D]."""
    N, D = x.shape
    br = min(block_rows, N)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(pl.cdiv(N, br),),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(x, g)
