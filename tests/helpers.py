"""Shared test fixtures and graph generators.

One generator module for every suite (the differential fuzz harness, the
dispatch sweeps, the tiering tests, and the hypothesis property tests all
draw from here), so "a random TASKGRAPH" means the same distribution
everywhere:

* :func:`fig3_taskgraph` — the paper's running example (3-device matmul
  decomposition);
* :func:`random_taskgraph` — seeded ``random.Random`` generator (runs
  without the optional hypothesis dependency — the CI fast lane);
* :func:`taskgraphs` — the same distribution as a hypothesis strategy
  (imported lazily so this module stays importable without hypothesis);
* :func:`int_inputs` / :func:`graph_inputs` — integer-valued float inputs:
  every op in the vocabulary is then exact, so order-invariance checks can
  demand *bitwise* equality instead of tolerances.
"""
import numpy as np

from repro.core import TaskGraph

SHAPE = (4, 4)
UNARY = ["relu", "transpose", "copy"]
BINARY = ["add", "mul", "matmul", "matmul_t"]


def fig3_taskgraph(shape=(4, 4)):
    """The paper's running example: 3-device matmul decomposition."""
    tg = TaskGraph()
    A = tg.add_input(0, shape, name="A")
    B = tg.add_input(0, shape, name="B")
    C = tg.add_input(1, shape, name="C")
    D = tg.add_input(1, shape, name="D")
    v1 = tg.add_compute(0, (A, B), shape, op="matmul", name="1")
    v2 = tg.add_compute(0, (A, B), shape, op="matmul_t", name="2")
    v5 = tg.add_compute(1, (C, D), shape, op="matmul", name="5")
    v6 = tg.add_compute(1, (C, D), shape, op="matmul_t", name="6")
    t25 = tg.add_transfer(1, v2)
    t61 = tg.add_transfer(0, v6)
    v3 = tg.add_compute(0, (v1, t61), shape, op="add", name="3")
    v7 = tg.add_compute(1, (v5, t25), shape, op="add", name="7")
    t7 = tg.add_transfer(2, v7)
    v4 = tg.add_compute(0, (v3, t61), shape, op="mul", name="4")
    v8 = tg.add_compute(0, (v4, v3), shape, op="mul", name="8")
    return tg


def int_inputs(tg, seed=0, lo=-3, hi=4, dtype=np.float64):
    """Integer-valued inputs → float ops are exact → bitwise order-invariance."""
    rng = np.random.default_rng(seed)
    from repro.core import OpKind
    return {t: rng.integers(lo, hi, v.out.shape).astype(dtype)
            for t, v in tg.vertices.items() if v.kind == OpKind.INPUT}


def graph_inputs(tg, seed: int):
    """Integer-valued inputs for a generated graph (alias of
    :func:`int_inputs` with the generators' historical signature)."""
    return int_inputs(tg, seed)


def random_taskgraph(rng, *, min_ops: int = 6, max_ops: int = 18):
    """Seeded random TASKGRAPH: 1-3 devices, unary/binary compute over the
    exact-arithmetic op vocabulary, with occasional streaming reductions
    (§B) folded over recent tensors. ``rng`` is a ``random.Random``."""
    n_dev = rng.randint(1, 3)
    tg = TaskGraph()
    tids = []
    for i in range(rng.randint(1, 3)):
        for d in range(n_dev):
            tids.append(tg.add_input(d, SHAPE, name=f"in{d}.{i}"))
    for i in range(rng.randint(min_ops, max_ops)):
        d = rng.randrange(n_dev)
        if rng.random() < 0.5:
            tids.append(tg.add_compute(d, (rng.choice(tids),), SHAPE,
                                       op=rng.choice(UNARY), name=f"v{i}"))
        else:
            tids.append(tg.add_compute(
                d, (rng.choice(tids), rng.choice(tids)), SHAPE,
                op=rng.choice(BINARY), name=f"v{i}"))
        if i % 7 == 6 and len(tids) >= 4:
            parts = rng.sample(tids, k=min(len(tids), rng.randint(2, 4)))
            tids.append(tg.add_reduce(d, parts, streaming=True, name=f"r{i}"))
    return tg


def confirm_hazard(tg, res, hazard, *, seed: int = 0, cert=None) -> str:
    """Dynamically confirm a certifier finding by replaying its witness
    schedule through the differential harness's executors (DESIGN.md §13:
    every counterexample the static analysis emits must be a real fuzz
    case). Liveness findings (``witness_kind == "stall"``, §14) replay
    through the directed stuck-state scheduler instead: the flagged
    admission must still be refused after a bounded timeout against a
    real HostPool. ``cert`` is the LivenessCertificate that carries the
    pool/stream model (defaults to ``res.liveness_certificate``).
    Returns a short description of how the witness manifested; raises
    ``AssertionError`` if the replay stays healthy."""
    from repro.core.analyze import replay_occupancy
    from repro.core.runtime import eval_taskgraph, replay_stall, \
        run_in_order

    assert hazard.confirmable, f"hazard is not replay-falsifiable: {hazard}"
    if hazard.witness_kind == "stall":
        if cert is None:
            cert = res.liveness_certificate
        assert cert is not None, "stall replay needs the certificate"
        mg = getattr(res, "memgraph", res) if res is not None else None
        return replay_stall(hazard, cert, mg)
    assert hazard.witness, f"hazard carries no witness schedule: {hazard}"
    if hazard.witness_kind == "occupancy":
        occ = replay_occupancy(res.memgraph, hazard.witness,
                               tier=hazard.tier)
        peak = max(occ[:hazard.prefix])
        assert hazard.capacity is not None and peak > hazard.capacity, \
            f"witness prefix peaks at {peak} ≤ capacity {hazard.capacity}"
        return f"occupancy {peak} > capacity {hazard.capacity}"
    inputs = graph_inputs(tg, seed)
    ref = eval_taskgraph(tg, inputs)
    try:
        out = run_in_order(tg, res, inputs, list(hazard.witness))
    except Exception as e:                     # RaceError, KeyError, ...
        return f"raised {type(e).__name__}"
    for k in ref:
        if not np.array_equal(out[k], ref[k]):
            return f"diverged from the oracle on output {k}"
    raise AssertionError(f"witness replay did not confirm hazard: {hazard}")


def taskgraphs(*, min_ops: int = 3, max_ops: int = 18):
    """Hypothesis strategy over the same TASKGRAPH distribution as
    :func:`random_taskgraph`. Imported lazily: calling this requires
    hypothesis, merely importing this module does not."""
    from hypothesis import strategies as st

    @st.composite
    def _graphs(draw):
        n_dev = draw(st.integers(1, 3))
        n_inputs = draw(st.integers(1, 3))
        n_ops = draw(st.integers(min_ops, max_ops))
        tg = TaskGraph()
        tids = []
        for i in range(n_inputs):
            for d in range(n_dev):
                tids.append(tg.add_input(d, SHAPE, name=f"in{d}.{i}"))
        for i in range(n_ops):
            d = draw(st.integers(0, n_dev - 1))
            arity = draw(st.integers(1, 2))
            if arity == 1:
                op = draw(st.sampled_from(UNARY))
                a = draw(st.sampled_from(tids))
                tids.append(tg.add_compute(d, (a,), SHAPE, op=op,
                                           name=f"v{i}"))
            else:
                op = draw(st.sampled_from(BINARY))
                a = draw(st.sampled_from(tids))
                b = draw(st.sampled_from(tids))
                tids.append(tg.add_compute(d, (a, b), SHAPE, op=op,
                                           name=f"v{i}"))
            # occasionally fold a streaming reduction over recent tensors
            if i % 7 == 6 and len(tids) >= 4:
                parts = draw(st.lists(st.sampled_from(tids), min_size=2,
                                      max_size=4, unique=True))
                tids.append(tg.add_reduce(d, parts, streaming=True,
                                          name=f"r{i}"))
        return tg

    return _graphs()
