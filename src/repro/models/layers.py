"""Transformer building blocks (pure functional JAX).

Everything here is shape-polymorphic, scan-friendly and GSPMD-compatible.
Attention uses an online-softmax *blockwise* formulation by default (no
[S, S] materialization — mandatory for the 32k prefill shapes), switchable to
the Pallas flash kernel via ``use_pallas`` for TPU targets.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..sharding.rules import constrain

Array = jax.Array

# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rmsnorm(x: Array, gamma: Array | None, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y if gamma is None else y * gamma


def layernorm(x: Array, gamma: Array | None = None, beta: Array | None = None,
              eps: float = 1e-5) -> Array:
    """Non-parametric when gamma/beta are None (OLMo §'non-parametric LN')."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    if gamma is not None:
        y = y * gamma
    if beta is not None:
        y = y + beta
    return y


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------
def rope(x: Array, positions: Array, theta: float = 1e4) -> Array:
    """x: [..., S, H, Dh]; positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32)
                    / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def _gqa_scores(q: Array, k: Array, scale: float) -> Array:
    """q: [B,Sq,Hq,Dh] grouped as [B,Sq,Hkv,G,Dh]; k: [B,Skv,Hkv,Dh]."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k) * scale


def blockwise_attention(q: Array, k: Array, v: Array, *, causal: bool,
                        q_offset: Array | int = 0,
                        block_kv: int = 1024) -> Array:
    """Online-softmax attention over KV blocks — O(block) memory, no [S,S]
    intermediate (flash-attention algorithm expressed in XLA; the Pallas
    kernel in :mod:`repro.kernels.flash_attention` is the TPU-tiled twin).

    q: [B, Sq, Hq, Dh], k/v: [B, Skv, Hkv, Dh] with Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (for causal masking of a suffix
    chunk against a longer KV, e.g. chunked prefill / decode)."""
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Sq, Hkv, G, Dh)

    nblk = max(1, (Skv + block_kv - 1) // block_kv)
    pad = nblk * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block_kv, Hkv, Dh)
    vb = v.reshape(B, nblk, block_kv, Hkv, Dh)

    q_pos = jnp.arange(Sq) + q_offset                       # [Sq]

    def step(carry, blk):
        m, l, o = carry
        kj, vj, j = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj) * scale  # [B,Hkv,G,Sq,bk]
        kv_pos = j * block_kv + jnp.arange(block_kv)
        mask = jnp.broadcast_to((kv_pos < Skv)[None, :], (Sq, block_kv))
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask[None, None, None], s.astype(jnp.float32), -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, Hkv, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, Hkv, G, Sq, Dh), jnp.float32)
    # checkpoint the block step: backward recomputes the [.., Sq, bk] score
    # tile instead of storing one per block (flash-attention recompute).
    (m, l, o), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, o0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nblk)))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dh).astype(q.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cache_len: Array | int) -> Array:
    """One-token attention against a [B, Smax, Hkv, Dh] cache."""
    B, Sq, Hq, Dh = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache) * scale
    pos = jnp.arange(Smax)
    mask = pos[None, :] < jnp.asarray(cache_len).reshape(-1, 1)  # [B|1, Smax]
    s = jnp.where(mask[:, None, None, None, :], s.astype(jnp.float32), -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_cache.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dh).astype(q.dtype)


@dataclasses.dataclass(frozen=True)
class AttnParamsSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False

    def init(self, key: Array, dtype=jnp.float32) -> dict:
        d, H, K, Dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        k1, k2, k3, k4 = jax.random.split(key, 4)
        s = 1.0 / math.sqrt(d)
        p = {
            "wq": jax.random.normal(k1, (d, H * Dh), dtype) * s,
            "wk": jax.random.normal(k2, (d, K * Dh), dtype) * s,
            "wv": jax.random.normal(k3, (d, K * Dh), dtype) * s,
            "wo": jax.random.normal(k4, (H * Dh, d), dtype) * s,
        }
        if self.qkv_bias:
            p["bq"] = jnp.zeros((H * Dh,), dtype)
            p["bk"] = jnp.zeros((K * Dh,), dtype)
            p["bv"] = jnp.zeros((K * Dh,), dtype)
        return p


def attention_block(p: dict, x: Array, *, n_heads: int, n_kv_heads: int,
                    d_head: int, positions: Array, causal: bool = True,
                    rope_theta: float = 1e4, kv: Array | None = None,
                    block_kv: int = 1024) -> Array:
    """Self- (or cross-, when ``kv`` given) attention with RoPE + GQA."""
    B, S, _ = x.shape
    src = x if kv is None else kv
    Skv = src.shape[1]
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(B, S, n_heads, d_head)
    k = (src @ p["wk"] + p.get("bk", 0)).reshape(B, Skv, n_kv_heads, d_head)
    v = (src @ p["wv"] + p.get("bv", 0)).reshape(B, Skv, n_kv_heads, d_head)
    # head-sharded attention (Megatron TP): keeps the whole attention local
    # per device; without it GSPMD gathers SP-sharded K/V per block
    # (§Perf iteration B2; constrain no-ops when heads don't divide)
    q = constrain(q, ("pod", "data"), None, "model", None, require="model")
    k = constrain(k, ("pod", "data"), None, "model", None, require="model")
    v = constrain(v, ("pod", "data"), None, "model", None, require="model")
    if kv is None and rope_theta:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    o = blockwise_attention(q, k, v, causal=causal and kv is None,
                            block_kv=block_kv)
    o = constrain(o, ("pod", "data"), None, "model", None, require="model")
    return o.reshape(B, S, n_heads * d_head) @ p["wo"]


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def swiglu_mlp(p: dict, x: Array) -> Array:
    return (jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])) @ p["wo"]


def gelu_mlp(p: dict, x: Array) -> Array:
    return jax.nn.gelu(x @ p["wi"] + p.get("bi", 0), approximate=True) \
        @ p["wo"] + p.get("bo", 0)


def mlp_init(key: Array, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.float32, bias: bool = False) -> dict:
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"wi_gate": jax.random.normal(ks[0], (d_model, d_ff), dtype) * s_in,
                "wi_up": jax.random.normal(ks[1], (d_model, d_ff), dtype) * s_in,
                "wo": jax.random.normal(ks[2], (d_ff, d_model), dtype) * s_out}
    p = {"wi": jax.random.normal(ks[0], (d_model, d_ff), dtype) * s_in,
         "wo": jax.random.normal(ks[1], (d_ff, d_model), dtype) * s_out}
    if bias:
        p["bi"] = jnp.zeros((d_ff,), dtype)
        p["bo"] = jnp.zeros((d_model,), dtype)
    return p


# --------------------------------------------------------------------------
# Mixture of Experts (token-choice top-k, capacity-bounded dropless-ish)
# --------------------------------------------------------------------------
def moe_init(key: Array, d_model: int, d_expert: int, n_experts: int,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_expert)
    return {
        "router": jax.random.normal(ks[0], (d_model, n_experts),
                                    jnp.float32) * s_in,
        "wi_gate": jax.random.normal(ks[1], (n_experts, d_model, d_expert),
                                     dtype) * s_in,
        "wi_up": jax.random.normal(ks[2], (n_experts, d_model, d_expert),
                                   dtype) * s_in,
        "wo": jax.random.normal(ks[3], (n_experts, d_expert, d_model),
                                dtype) * s_out,
    }


def moe_block(p: dict, x: Array, *, n_experts: int, top_k: int,
              capacity_factor: float | None = 1.25) -> tuple[Array, Array]:
    """Top-k token-choice routing with per-expert capacity (GShard-style).

    Tokens are dispatched to [E, C, D] buffers with one-hot combines, so the
    expert compute is a *grouped* einsum whose FLOPs equal the active-expert
    FLOPs (E·C·D·F with E·C ≈ tokens·top_k), not a dense all-experts pass —
    this keeps the roofline's MODEL_FLOPS/HLO_FLOPs ratio honest.
    ``capacity_factor=None`` → dropless (C = T·top_k; used for decode and
    for exactness tests). Returns (output, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt.astype(jnp.float32) @ p["router"])            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    if capacity_factor is None:
        C = T * top_k                                  # dropless
    else:
        C = max(1, int(capacity_factor * T * top_k / n_experts))
    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.int32)  # [T,k,E]
    flat = onehot.reshape(T * top_k, n_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)                # [Tk, E]
    pos = (pos_in_expert * flat).sum(-1).reshape(T, top_k)
    keep = pos < C
    gate_vals = gate_vals * keep

    # dispatch: [E, C, D]
    e_flat = expert_idx.reshape(-1)
    pos_flat = jnp.where(keep.reshape(-1), pos.reshape(-1), C)  # drop → C
    buf = jnp.zeros((n_experts, C + 1, D), x.dtype)
    tok_rep = jnp.repeat(jnp.arange(T), top_k)
    buf = buf.at[e_flat, pos_flat].add(xt[tok_rep])
    # experts over 'model' (EP). NOTE (§Perf iteration D1, REFUTED): also
    # sharding capacity over 'data' should cut expert FLOPs 16×, but GSPMD
    # cannot lower the global-index scatter into a data-sharded buffer —
    # collectives exploded ~1000×. Proper fix: shard_map dispatch with local
    # capacity + explicit all-to-all (future work; see EXPERIMENTS.md §Perf).
    buf = constrain(buf[:, :C], "model", None, None)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    y_e = jnp.einsum("ecf,efd->ecd", h, p["wo"])               # [E, C, D]
    y_e = constrain(y_e, "model", None, None)

    # combine
    y_flat = y_e.reshape(n_experts * C, D)
    gather_idx = jnp.where(keep.reshape(-1), e_flat * C + pos_flat, 0)
    y_tok = y_flat[gather_idx] * gate_vals.reshape(-1, 1).astype(x.dtype)
    y = y_tok.reshape(T, top_k, D).sum(axis=1)

    # load-balance aux loss (Switch): E * mean(frac_tokens * frac_probs)
    frac_tokens = onehot.sum(axis=(0, 1)).astype(jnp.float32) / (T * top_k)
    frac_probs = probs.mean(axis=0)
    aux = n_experts * jnp.sum(frac_tokens * frac_probs)
    return y.reshape(B, S, D), aux


def decode_attention_q8(q: Array, k_cache: Array, v_cache: Array,
                        k_scale: Array, v_scale: Array,
                        cache_len: Array | int) -> Array:
    """decode_attention over an int8 KV cache with per-(token, head) scales
    (KIVI-style, post-RoPE). Dequantization happens inside the einsums so no
    bf16 copy of the cache is materialized."""
    B, Sq, Hq, Dh = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Sq, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                   k_cache.astype(jnp.float32)) * scale
    s = s * k_scale.transpose(0, 2, 1)[:, :, None, None, :]   # [B,Hkv,1,1,S]
    pos = jnp.arange(Smax)
    mask = pos[None, :] < jnp.asarray(cache_len).reshape(-1, 1)
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    pv = p * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    o = jnp.einsum("bhgqk,bkhd->bhgqd", pv, v_cache.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dh).astype(q.dtype)
