"""Unit tests for the plan compiler (core/compile.py, DESIGN.md §15):
region segmentation, fusion legality, tick-count scheduling, stream
pre-assignment, and the verify() invariant checker that guards them."""
import dataclasses
import random as pyrandom

import numpy as np
import pytest

from repro.core import BuildConfig, MemgraphOOM, build_memgraph
from repro.core.compile import (DEFAULT_MERGE_GAP, INLINE, NONDET, STATIC,
                                THREADED, CompiledPlan, PlanCompileError,
                                lower, main)
from repro.core.dispatch import (COMPUTE, DISK, POLICY_NAMES, TRANSFER_KINDS,
                                 engine_key)
from repro.core.memgraph import DepKind
from repro.core.runtime import TurnipRuntime, eval_taskgraph, run_in_order
from repro.core import TaskGraph

from helpers import fig3_taskgraph, int_inputs, random_taskgraph

UNITS = dict(size_fn=lambda v: 1)


def chain_taskgraph(n=8):
    """One input, a unary chain: exactly one legal order — fully static."""
    tg = TaskGraph()
    t = tg.add_input(0, (4, 4), name="in")
    for i in range(n):
        t = tg.add_compute(0, (t,), (4, 4), op="relu", name=f"c{i}")
    return tg


def build(tg, seed=0, **kw):
    cfg = BuildConfig(capacity=3, rng_seed=seed, **UNITS, **kw)
    return build_memgraph(tg, cfg)


def tiered_build(tg, seed=0, **kw):
    """A plan with real SPILL/LOAD disk traffic (or skip the test)."""
    try:
        return build(tg, seed, host_capacity=2, disk_capacity=50, **kw)
    except MemgraphOOM:
        pytest.skip("random plan does not fit the tiered budgets")


# ------------------------------------------------------------ segmentation
class TestSegmentation:
    def test_unary_chain_is_fully_static(self):
        plan = lower(build(chain_taskgraph()), policy="fixed")
        assert plan.certified
        assert plan.n_nondet == 0
        assert plan.seams == ()
        assert [r.kind for r in plan.regions] == [STATIC]
        assert len(plan.regions[0]) == plan.n_vertices

    def test_concurrent_inputs_open_a_nondet_window(self):
        # fig3: two INPUT streams per device race on the h2d engine at
        # t=0 — the paper's legitimately nondeterministic core
        plan = lower(build(fig3_taskgraph()), policy="fixed")
        assert plan.n_nondet > 0
        assert plan.seams, "nondet regions must expose seam vertices"
        # seams are the first vertex of each nondet region, in order
        nondet = [r for r in plan.regions if r.kind == NONDET]
        assert plan.seams == tuple(plan.order[r.start] for r in nondet)

    def test_regions_partition_the_order(self):
        for seed in range(6):
            tg = random_taskgraph(pyrandom.Random(1000 + seed))
            try:
                plan = lower(build(tg, seed), policy="random", seed=seed)
            except MemgraphOOM:
                continue
            at = 0
            for r in plan.regions:
                assert r.start == at and r.end > r.start
                at = r.end
            assert at == plan.n_vertices
            assert plan.n_static + plan.n_nondet == plan.n_vertices

    def test_merge_gap_absorbs_static_slivers(self):
        # with an enormous merge gap every nondet span coalesces into few
        # regions; with gap 0 slivers are kept — region count can only grow
        res = build(fig3_taskgraph())
        merged = lower(res, policy="fixed", merge_gap=10**6)
        split = lower(res, policy="fixed", merge_gap=0)
        n_merged = sum(r.kind == NONDET for r in merged.regions)
        n_split = sum(r.kind == NONDET for r in split.regions)
        assert n_merged <= n_split
        assert merged.n_nondet >= split.n_nondet

    def test_uncertified_plan_is_one_nondet_region(self):
        res = build(fig3_taskgraph())
        mg = res.memgraph
        # delete a safe-overwrite MEM edge until certification fails
        from repro.core import certify
        for u in list(mg.vertices):
            hit = False
            for v, k in list(mg.succs[u].items()):
                if k != DepKind.MEM:
                    continue
                mg.remove_dep(u, v)
                if not certify(mg).ok:
                    hit = True
                    break
                mg.add_dep(u, v, DepKind.MEM)
            if hit:
                break
        else:
            pytest.fail("no MEM edge deletion broke certification")
        res.certificate = None        # force lower() to re-certify
        plan = lower(res, policy="fixed")
        assert not plan.certified
        assert [r.kind for r in plan.regions] == [NONDET]
        assert plan.batches == []     # nondet regions never fuse
        # even the uncertified whole-plan region carries a backend stamp
        assert plan.regions[0].backend in (INLINE, THREADED)


# ------------------------------------------------- seam-backend stamping
class TestBackendStamping:
    def test_every_nondet_region_is_stamped(self):
        for seed in range(8):
            tg = random_taskgraph(pyrandom.Random(1000 + seed))
            try:
                res = build(tg, seed)
            except MemgraphOOM:
                continue
            plan = lower(res, policy="random", seed=seed)
            for r in plan.regions:
                if r.kind == NONDET:
                    assert r.backend in (INLINE, THREADED)
                else:
                    assert r.backend == ""

    def test_small_certified_seam_stamps_inline(self):
        # fig3's h2d races are small, narrow, and admission-free: the
        # canonical inline seam
        plan = lower(build(fig3_taskgraph()), policy="fixed")
        nondet = [r for r in plan.regions if r.kind == NONDET]
        assert nondet
        assert all(r.backend == INLINE for r in nondet
                   if len(r) <= plan.seam_threshold)
        assert any(r.backend == INLINE for r in nondet)

    def test_seam_threshold_zero_demotes_every_seam(self):
        res = build(fig3_taskgraph())
        plan = lower(res, policy="fixed", seam_threshold=0)
        assert plan.seam_threshold == 0
        assert plan.n_inline == 0
        assert all(r.backend == THREADED for r in plan.regions
                   if r.kind == NONDET)

    def test_seam_threshold_flows_from_build_config(self):
        tg = fig3_taskgraph()
        res = build(tg, backend="compiled", seam_threshold=0)
        assert res.seam_threshold == 0
        plan = lower(res, policy="fixed")     # picks up res.seam_threshold
        assert plan.seam_threshold == 0
        assert plan.n_inline == 0
        rr = TurnipRuntime(tg, res, policy="fixed").run(int_inputs(tg))
        assert rr.n_inline == 0
        assert rr.n_threaded == rr.n_interpreted > 0

    def test_admission_seams_demote_without_liveness_certificate(self):
        # a seam containing pool/disk admission vertices may only run
        # inline when §14's proof covers the blocking waits
        from repro.core.memgraph import MemOp
        admission = (MemOp.OFFLOAD, MemOp.SPILL, MemOp.LOAD)
        seen = False
        for seed in range(20):
            tg = random_taskgraph(pyrandom.Random(1000 + seed))
            try:
                res = build(tg, seed, host_capacity=2, disk_capacity=50)
            except MemgraphOOM:
                continue
            assert res.liveness_certificate is None
            plan = lower(res, policy="fixed")
            mg = res.memgraph
            for r in plan.regions:
                if r.kind != NONDET:
                    continue
                if any(mg.vertices[plan.order[i]].op in admission
                       for i in range(r.start, r.end)):
                    assert r.backend == THREADED
                    seen = True
        assert seen, "corpus produced no admission-bearing seam"


# ------------------------------------------------------- tick-count schedule
class TestTickCounts:
    def test_ready_tick_is_one_past_last_pred(self):
        res = build(fig3_taskgraph())
        plan = lower(res, policy="critical-path")
        pos = {m: i for i, m in enumerate(plan.order)}
        for ins in plan.instrs:
            want = max((pos[p] + 1 for p in res.memgraph.preds[ins.mid]),
                       default=0)
            assert ins.ready_tick == want
            assert ins.ready_tick <= ins.pos   # topological ⇒ no waiting

    def test_verify_rejects_corrupted_tick(self):
        res = build(chain_taskgraph())
        plan = lower(res, policy="fixed")
        bad = dataclasses.replace(plan.instrs[-1],
                                  ready_tick=plan.n_vertices + 5)
        plan.instrs[-1] = bad
        with pytest.raises(PlanCompileError, match="ready_tick"):
            plan.verify(res.memgraph)

    def test_verify_rejects_non_permutation(self):
        res = build(chain_taskgraph())
        plan = lower(res, policy="fixed")
        plan.order[0] = plan.order[1]
        with pytest.raises(PlanCompileError, match="permutation"):
            plan.verify(res.memgraph)

    def test_verify_rejects_gapped_regions(self):
        res = build(chain_taskgraph())
        plan = lower(res, policy="fixed")
        r = plan.regions[0]
        plan.regions[0] = dataclasses.replace(r, start=r.start + 1)
        with pytest.raises(PlanCompileError, match="partition"):
            plan.verify(res.memgraph)

    def test_streams_pre_resolved_within_bounds(self):
        res = build(fig3_taskgraph())
        plan = lower(res, policy="fixed", n_streams=3, n_transfer_streams=2)
        for ins in plan.instrs:
            width = 3 if ins.engine == COMPUTE else 2
            assert 0 <= ins.stream < width
            assert (ins.device, ins.engine) == \
                engine_key(res.memgraph.vertices[ins.mid])


# ------------------------------------------------------------ fusion
class TestFusion:
    def _fused_plan(self):
        for seed in range(20):
            tg = random_taskgraph(pyrandom.Random(1000 + seed))
            try:
                res = build(tg, seed, host_capacity=2, disk_capacity=50,
                            certify_liveness=True)
            except MemgraphOOM:
                continue
            plan = lower(res, policy="fixed")
            if plan.batches:
                return res, plan
        pytest.fail("no seed produced a fused plan")

    def test_batches_are_legal(self):
        res, plan = self._fused_plan()
        mg = res.memgraph
        pos = {m: i for i, m in enumerate(plan.order)}
        region_of = [r for r in plan.regions for _ in range(len(r))]
        for a, b in plan.batches:
            assert b - a >= 2
            keys = {engine_key(mg.vertices[plan.order[i]])
                    for i in range(a, b)}
            assert {k for _, k in keys} <= set(TRANSFER_KINDS)
            assert len({d for d, _ in keys}) == 1
            # one engine stream — or, on a liveness-certified plan, one
            # device's H2D/D2H engine pair
            assert (len(keys) == 1
                    or ({k for _, k in keys} <= {"h2d", "d2h"}
                        and plan.liveness_certified))
            assert region_of[a].kind == STATIC
            assert region_of[b - 1] is region_of[a]
            for i in range(a, b):
                # every external predecessor precedes the batch head —
                # all dependencies complete when the batch issues
                for p in mg.preds[plan.order[i]]:
                    assert pos[p] < a or a <= pos[p] < i

    def test_fused_map_points_members_at_heads(self):
        _, plan = self._fused_plan()
        fm = plan.fused_map
        for a, b in plan.batches:
            head = plan.order[a]
            assert fm[head] == head
            for i in range(a, b):
                assert fm[plan.order[i]] == head
        n_members = sum(b - a for a, b in plan.batches)
        assert len(fm) == n_members

    def test_verify_rejects_mixed_engine_batch(self):
        res, plan = self._fused_plan()
        mg = res.memgraph
        a, _b = plan.batches[0]
        # graft a compute neighbour into the batch: compute is never a
        # legal batch member (the only legal mixture is the H2D/D2H DMA
        # pair of one device on a liveness-certified plan)
        for j, m in enumerate(plan.order):
            if engine_key(mg.vertices[m])[1] == COMPUTE:
                break
        else:
            pytest.fail("plan has no compute vertex")
        lo, hi = min(a, j), max(a, j) + 1
        plan.batches[0] = (lo, hi)
        with pytest.raises(PlanCompileError):
            plan.verify(mg)

    def test_disk_fusion_requires_liveness_certificate(self):
        res, plan = self._fused_plan()
        # strip the certificate: disk-engine runs must no longer fuse
        res.liveness_certificate = None
        bare = lower(res, policy="fixed")
        assert not bare.liveness_certified
        mg = res.memgraph
        for a, _ in bare.batches:
            assert engine_key(mg.vertices[bare.order[a]])[1] != DISK
        assert len(bare.batches) <= len(plan.batches)

    def test_pair_fusion_requires_liveness_certificate(self):
        res, plan = self._fused_plan()
        mg = res.memgraph
        # strip the certificate: every remaining batch is single-stream
        res.liveness_certificate = None
        bare = lower(res, policy="fixed")
        for a, b in bare.batches:
            keys = {engine_key(mg.vertices[bare.order[i]])
                    for i in range(a, b)}
            assert len(keys) == 1

    def test_pair_fusion_occurs_in_corpus(self):
        # some certified plan in the seed sweep fuses across one
        # device's H2D/D2H engine pair
        for seed in range(20):
            tg = random_taskgraph(pyrandom.Random(1000 + seed))
            try:
                res = build(tg, seed, host_capacity=2, disk_capacity=50,
                            certify_liveness=True)
            except MemgraphOOM:
                continue
            plan = lower(res, policy="fixed")
            mg = res.memgraph
            for a, b in plan.batches:
                kinds = {engine_key(mg.vertices[plan.order[i]])[1]
                         for i in range(a, b)}
                if kinds == {"h2d", "d2h"}:
                    return
        pytest.fail("no seed produced an H2D/D2H pair batch")

    def test_max_fuse_bounds_batch_length(self):
        res, _ = self._fused_plan()
        plan = lower(res, policy="fixed", max_fuse=2)
        assert all(b - a == 2 for a, b in plan.batches)


# ------------------------------------------------------------ execution
class TestCompiledExecution:
    def test_linearization_replays_byte_exactly(self):
        for pol in POLICY_NAMES:
            tg = fig3_taskgraph()
            res = build(tg)
            plan = lower(res, policy=pol, seed=7)
            inputs = int_inputs(tg, seed=7)
            ref = eval_taskgraph(tg, inputs)
            out = run_in_order(tg, res, inputs, plan.order)
            for k in ref:
                np.testing.assert_array_equal(out[k], ref[k])

    def test_backend_flows_from_build_config(self):
        tg = chain_taskgraph()
        res = build(tg, backend="compiled")
        assert res.backend == "compiled"
        rt = TurnipRuntime(tg, res)
        assert rt.exec_backend == "compiled"
        inputs = int_inputs(tg)
        rr = rt.run(inputs)
        assert rr.n_compiled == len(res.memgraph.vertices)
        assert rr.n_interpreted == 0
        ref = eval_taskgraph(tg, inputs)
        for k in ref:
            np.testing.assert_array_equal(rr.outputs[k], ref[k])

    def test_bad_backend_rejected(self):
        tg = chain_taskgraph()
        with pytest.raises(ValueError, match="backend"):
            build(tg, backend="jit")
        res = build(tg)
        with pytest.raises(ValueError, match="backend"):
            TurnipRuntime(tg, res, exec_backend="jit")

    def test_fused_batches_counted_by_runtime(self):
        for seed in range(20):
            tg = random_taskgraph(pyrandom.Random(1000 + seed))
            try:
                res = build(tg, seed, host_capacity=2, disk_capacity=50,
                            certify_liveness=True)
            except MemgraphOOM:
                continue
            if not lower(res, policy="fixed").batches:
                continue
            inputs = int_inputs(tg, seed=seed)
            ref = eval_taskgraph(tg, inputs)
            rr = TurnipRuntime(tg, res, policy="fixed", seed=seed,
                               exec_backend="compiled").run(inputs)
            assert rr.fused_dma_batches > 0
            for k in ref:
                np.testing.assert_array_equal(rr.outputs[k], ref[k])
            return
        pytest.fail("no seed produced a fused tiered plan")


def test_cli_corpus_lowers_and_replays():
    assert main(["--seeds", "6"]) == 0
