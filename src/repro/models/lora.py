"""LoRA adapters for the JAX model zoo (paper §8 task 2 at framework level).

Wraps a base LM: freezes ``base_params`` and trains rank-r adapters on the
attention projections (wq/wk/wv) and the FFN up-projections. The adapter
pytree mirrors the layer stacking, so the same sharding rules apply (A
replicated — tiny; B sharded like its base weight's output dim).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array

_TARGETS = ("wq", "wk", "wv", "wi", "wi_gate", "wi_up")


def lora_init(key: Array, base_params: Any, *, rank: int = 16,
              dtype=jnp.float32) -> Any:
    """Adapter pytree: for each targeted 2-D (or stacked 3-D) weight
    ``[.., d_in, d_out]`` create A [.., r, d_in] (gaussian) and B
    [.., d_out, r] (zeros — standard LoRA init)."""
    leaves = jax.tree_util.tree_flatten_with_path(base_params)[0]
    flat_adapters: dict[str, dict[str, Array]] = {}
    k = key
    for path, leaf in leaves:
        name = str(getattr(path[-1], "key", path[-1]))
        if name not in _TARGETS or leaf.ndim < 2:
            continue
        k, sub = jax.random.split(k)
        *stack, d_in, d_out = leaf.shape
        a = jax.random.normal(sub, (*stack, rank, d_in), dtype) / math.sqrt(d_in)
        b = jnp.zeros((*stack, d_out, rank), dtype)
        keystr = "/".join(str(getattr(p, "key", p)) for p in path)
        flat_adapters[keystr] = {"A": a, "B": b}
    return flat_adapters


def lora_apply(base_params: Any, adapters: dict, *, alpha: float = 16.0,
               rank: int = 16) -> Any:
    """Return effective params: W' = W + (alpha/r)·(BA)^T  — merged form so
    the base model's ``apply`` runs unchanged (merging is exact for linear
    layers; gradients flow to A/B through the merge)."""
    scale = alpha / rank
    flat = jax.tree_util.tree_flatten_with_path(base_params)
    leaves, treedef = flat
    out = []
    for path, leaf in leaves:
        keystr = "/".join(str(getattr(p, "key", p)) for p in path)
        ad = adapters.get(keystr)
        if ad is None:
            out.append(leaf)
        else:
            delta = jnp.einsum("...or,...ri->...io", ad["B"], ad["A"])
            out.append((leaf + scale * delta).astype(leaf.dtype))
    tdef = jax.tree_util.tree_structure(base_params)
    return jax.tree_util.tree_unflatten(tdef, [o for o in out])


def make_lora_loss(model, base_params: Any, *, alpha: float = 16.0,
                   rank: int = 16):
    """loss(adapters, batch) — differentiates through the merge wrt adapters
    only (base params are a closure constant)."""
    def loss(adapters, batch):
        eff = lora_apply(base_params, adapters, alpha=alpha, rank=rank)
        return model.loss(eff, batch)
    return loss
