"""BUILDMEMGRAPH — compile a TASKGRAPH into a MEMGRAPH (paper §6, Fig. 8/9).

The compiler performs a *simulated execution* of the TASKGRAPH over per-device
:class:`~repro.core.policies.Arena` objects, maintaining two horizons through
the serialized vertex list ``V``:

* ``allocHzn`` — every vertex before it has an output location reserved. The
  compiler greedily pushes this as far ahead of ``execHzn`` as free memory
  allows, so the runtime gains freedom to reorder (paper §6);
* ``execHzn`` — every vertex before it has been "run" in simulation.

Four malloc/free variants (paper Fig. 9):

* ``simMalloc``       — free-space-only placement; on reuse of freed bytes it
  adds the safe-overwrite memory dependencies (readers of the previous writer
  → new writer);
* ``simMallocOffld``  — eviction placement: picks victims (Belady §C), emits
  ``victim → offload → reload`` chains, renames all future uses of the victim
  to its reload, adds ``offload → tenant`` plus executed-reader deps;
* ``simMallocForceReld`` — places an evicted input's reload right before its
  consumer runs (cannot fail short of a genuine OOM);
* ``simFree``         — returns an extent when its tensor's last consumer has
  executed in simulation.

Correctness (paper §7) holds by construction: every dependency edge is created
from an already-simulated vertex to a not-yet-simulated one, so the MEMGRAPH
is acyclic; and safe-overwrite edges are added for every byte of every reuse,
so it is race-free. Both properties are re-checked explicitly by the tests.

Beyond-paper extensions (flagged; documented in DESIGN.md §7):

* ``reuse_host_copy`` (default on) — re-evicting bytes that already exist in
  the host store (graph inputs; previously offloaded tensors) skips the
  redundant offload copy: tensors are immutable, so the first copy stays
  valid. ``False`` gives the paper-faithful always-offload behaviour.
* reservation *cancellation* — when eviction would otherwise have to victimize
  an unexecuted reservation (allocHzn ran ahead), the reservation is cancelled
  and re-made at execution time rather than "offloading" data that does not
  exist yet (which could deadlock the plan).
* terminal outputs evicted to host simply stay there (no orphan reload); the
  runtime serves results from the host store.
* bounded host tier (``host_capacity``; DESIGN.md §10) — host copies are
  tenants of a shared host :class:`~repro.core.policies.Arena`; overflow
  spills the Belady-furthest copy to the disk tier (SPILL vertex on the
  disk engine) and reloads of disk-resident copies become pipelined
  two-hop LOAD→RELOAD chains. Dead host copies are dropped for free, and
  re-spilling bytes with a live disk twin moves nothing (the disk
  analogue of ``reuse_host_copy``). ``host_capacity=None`` (default)
  reproduces the paper's unbounded host store exactly.
* cross-tier prefetch (``prefetch_distance``; DESIGN.md §11) — the build
  runs twice when the host tier is bounded: pass 1 places reloads
  reactively and records the host-occupancy profile; a
  :class:`~repro.core.policies.PrefetchPlan` walks that schedule backward
  to find, for every spilled copy, the earliest point its disk→host LOAD
  fits under ``host_capacity`` through every intervening window; pass 2
  emits the hoisted LOADs there (``MemVertex.prefetch``), turning
  force-reload stalls into pipelined transfers that run ahead of the
  consumer's horizon. Prefetch admissions use free space only — they can
  never force other copies out — so a skipped hint degrades to the
  reactive path, never to a worse plan.
* bounded disk tier (``disk_capacity``; DESIGN.md §11) — the disk rung is
  a budget too: the builder replays blob creation (first SPILL) and
  release (drop vertices — including for dead copies whose bytes already
  live on disk, which previously lingered) and raises
  :class:`MemgraphOOM` at compile time when the three-level footprint
  cannot fit. No plan that validates can overflow the disk at runtime.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Callable

from .analyze import Certificate, PlanCertificationError, certify
from .liveness import (LivenessCertificate, ProgressCertificationError,
                       certify_progress, default_pool_config)
from .memgraph import DepKind, Loc, MemGraph, MemOp
from .policies import (Arena, EvictionDecision, HostEntry, HostPlan,
                       PlacementDecision, PrefetchPlan, PrefetchRecord, INF)
from .taskgraph import OpKind, TaskGraph, TaskVertex

__all__ = ["BuildConfig", "BuildResult", "MemgraphOOM", "build_memgraph"]

_HOST_STORE = None  # sentinel: host source is the immutable input store


class MemgraphOOM(RuntimeError):
    """A single task's working set cannot fit in device memory."""


@dataclasses.dataclass
class BuildConfig:
    """Configuration for BUILDMEMGRAPH."""

    capacity: int | dict[int, int]              # arena size per device, units
    size_fn: Callable[[TaskVertex], int] | None = None  # default: out.nbytes
    reuse_host_copy: bool = True
    victim_policy: str = "belady"                # belady | lru | random  (§C)
    rng_seed: int = 0
    # host-tier budget (same units as `capacity`, shared by all devices).
    # None = unbounded CPU RAM (the paper's implicit assumption). Bounded,
    # the compiler spills Belady-chosen host copies to the disk tier
    # (SPILL vertices) and reloads them through two-hop LOAD→RELOAD
    # chains (DESIGN.md §10).
    host_capacity: int | None = None
    # shared-pool mode (DESIGN.md §12): a repro.core.pool.Lease instead of
    # a private budget. The feasibility check charges the *leased share* —
    # the lease's inviolable floor (min_bytes; a floorless lease is
    # refused, its grant being revocable) — never the whole pool, so a
    # plan compiled under a lease stays feasible no matter how the
    # arbiter moves the other consumers' slack. host_capacity is ignored
    # when a lease is set (the lease IS the capacity request).
    host_lease: Any = None
    # disk-tier budget (same units). None = unbounded disk. Bounded, the
    # builder replays blob creation/release and raises MemgraphOOM at
    # compile time when the three-level footprint cannot fit (§11).
    disk_capacity: int | None = None
    # how many schedule positions ahead of a consumer a disk→host LOAD may
    # be hoisted (PrefetchPlan, §11). 0 disables prefetch and reproduces
    # the reactive force-reload placement exactly. Only meaningful when
    # host_capacity is bounded (otherwise nothing ever spills).
    prefetch_distance: int = 32
    # run the static plan-soundness certifier (DESIGN.md §13) over the
    # finished plan: prove race-freedom, tier coherence, and worst-case
    # budget feasibility for *every* legal execution order. A hazard on a
    # compiled plan is a compiler bug and raises PlanCertificationError.
    certify: bool = False
    # run the static liveness certifier (DESIGN.md §14) over the finished
    # plan: prove no legal execution order can stall under the plan's
    # implied pool model (the host_lease's actual pool population, or a
    # single private lease over host_capacity). A hazard raises
    # ProgressCertificationError carrying a stuck-state witness the
    # directed scheduler (runtime.replay_stall) can replay to a real
    # bounded-timeout stall.
    certify_liveness: bool = False
    # executor backend the runtime should use for this plan (DESIGN.md
    # §15). "interpreted" — the threaded event-driven scheduler for
    # every vertex (the paper's runtime). "compiled" — lower the plan to
    # a CompiledPlan (core/compile.py): certified-static regions run
    # straight-line with pre-resolved streams and fused DMA batches;
    # regions whose order legitimately depends on runtime transfer
    # completion fall back to the interpreter at marked seam vertices.
    backend: str = "interpreted"
    # seam-backend stamping bound (DESIGN.md §17): a compiled plan's
    # nondet region at most this long (and certified blocking-free) runs
    # on the thread-free inline executor instead of the threaded fleet.
    # None defers to compile.DEFAULT_SEAM_THRESHOLD.
    seam_threshold: int | None = None

    def size_of(self, v: TaskVertex) -> int:
        return (self.size_fn or (lambda u: u.out.nbytes))(v)

    def host_budget(self) -> int | None:
        """The host-tier units this plan may charge: the leased share
        under a pool, else the private ``host_capacity``.

        The leased share is the lease's *floor* (``min_bytes``) — the only
        number the arbiter guarantees for the plan's whole lifetime. A
        floorless lease is refused here: its grant is revocable, so a plan
        compiled against it could later hold more than the arbiter can
        honor and silently burst the pool bound (DESIGN.md §12)."""
        if self.host_lease is not None:
            if not self.host_lease.min_bytes:
                raise ValueError(
                    f"host_lease {self.host_lease.name!r} has no floor: "
                    "compile-time feasibility needs an inviolable share — "
                    "request the lease with min_bytes=<host budget>")
            return self.host_lease.min_bytes
        return self.host_capacity

    def cap_of(self, device: int) -> int:
        if isinstance(self.capacity, dict):
            return self.capacity[device]
        return self.capacity


@dataclasses.dataclass
class BuildResult:
    memgraph: MemGraph
    mid_of: dict[int, int]                      # taskgraph tid -> memgraph mid
    order: list[int]                            # serialized V (tids)
    peak_used: dict[int, int]                   # per device
    terminal_host: dict[int, int | None]        # outputs resting in host store
    n_offloads: int = 0
    n_reloads: int = 0
    n_cancelled: int = 0
    peak_host: int = 0                          # host-tier peak (units)
    n_spills: int = 0                           # host→disk spill vertices
    n_loads: int = 0                            # disk→host load vertices
    peak_disk: int = 0                          # disk-tier peak (units)
    n_prefetches: int = 0                       # LOADs hoisted ahead of use
    stall_bytes_hidden: int = 0                 # disk bytes moved off the
    #                                             consumers' critical path
    # ground-truth host-tier tenancies [key, admit_mid, release_mid|None,
    # units] from the HostPlan; the certifier recovers the same intervals
    # from the graph alone (tests cross-check the two)
    host_residencies: list[list[Any]] = dataclasses.field(
        default_factory=list)
    # soundness certificate (BuildConfig.certify; DESIGN.md §13)
    certificate: Certificate | None = None
    # liveness certificate (BuildConfig.certify_liveness; DESIGN.md §14)
    liveness_certificate: LivenessCertificate | None = None
    # executor backend requested by BuildConfig.backend (DESIGN.md §15);
    # TurnipRuntime.run() consults this to pick the compiled lowering
    # path over vertex-by-vertex interpretation
    backend: str = "interpreted"
    # inline-stamping bound carried from BuildConfig.seam_threshold
    # (DESIGN.md §17); None = compile.DEFAULT_SEAM_THRESHOLD
    seam_threshold: int | None = None

    def final_value_location(self, tid: int) -> tuple[str, int]:
        """Where the runtime finds a terminal output: ('host', mid-or-tid) or
        ('device', mid)."""
        if tid in self.terminal_host:
            ref = self.terminal_host[tid]
            return ("host", ref if ref is not None else tid)
        return ("device", self.mid_of[tid])


def build_memgraph(
    tg: TaskGraph,
    config: BuildConfig,
    order: list[int] | None = None,
) -> BuildResult:
    """Compile ``tg`` under ``config``. ``order`` is the serialized vertex
    list V (defaults to a topological order of ``tg``).

    With a bounded host tier and ``prefetch_distance > 0`` the build is
    two-pass: pass 1 places disk→host reloads reactively and records the
    host-occupancy profile; a :class:`~repro.core.policies.PrefetchPlan`
    walks it backward to pick each reload's earliest feasible start; pass 2
    re-runs the simulation emitting the hoisted (``prefetch=True``) LOADs
    at those points. A plan with nothing to hoist returns pass 1 as-is."""
    if config.backend not in ("interpreted", "compiled"):
        raise ValueError(f"unknown executor backend {config.backend!r}; "
                         f"expected 'interpreted' or 'compiled'")
    builder = _Builder(tg, config, order)
    res = builder.run()
    if (config.host_budget() is not None and config.prefetch_distance > 0
            and builder.load_records):
        plan = PrefetchPlan(config.host_budget(), builder.occ_at,
                            config.prefetch_distance)
        hints = plan.compute(builder.load_records)
        if hints:
            try:
                res = _Builder(tg, config, order,
                               prefetch_hints=hints).run()
            except MemgraphOOM:
                # prefetch admissions shift later Belady choices, and a
                # shifted victim set can (rarely) need a blob the reactive
                # schedule never created — overflowing a tight disk budget
                # pass 1 satisfied. Prefetch is an optimization, not a
                # requirement: a program that compiles reactively must
                # always compile, so fall back to pass 1.
                pass
    if config.certify:
        res.certificate = certify(res.memgraph,
                                  host_capacity=config.host_budget(),
                                  disk_capacity=config.disk_capacity)
        if not res.certificate.ok:
            raise PlanCertificationError(res.certificate)
    if config.certify_liveness:
        res.liveness_certificate = certify_progress(
            res.memgraph,
            default_pool_config(config.host_budget(),
                                lease=config.host_lease),
            disk_capacity=config.disk_capacity)
        if not res.liveness_certificate.ok:
            raise ProgressCertificationError(res.liveness_certificate)
    res.backend = config.backend
    res.seam_threshold = config.seam_threshold
    return res


class _Builder:
    def __init__(self, tg: TaskGraph, config: BuildConfig,
                 order: list[int] | None,
                 prefetch_hints: dict[int, list[int]] | None = None) -> None:
        tg.validate()
        self.tg = tg
        self.cfg = config
        # default V = insertion order: a valid topological order by
        # construction (TaskGraph.add requires inputs to exist) that follows
        # natural program order — far better prefetch locality than an
        # arbitrary Kahn order.
        self.V = list(order) if order is not None else sorted(tg.vertices)
        if sorted(self.V) != sorted(tg.vertices):
            raise ValueError("order must be a permutation of the vertices")
        self.pos = {tid: i for i, tid in enumerate(self.V)}
        _check_order(tg, self.pos)

        self.mg = MemGraph()
        self.rng = random.Random(config.rng_seed)
        self.executed_mids: set[int] = set()
        self.arenas: dict[int, Arena] = {}
        for d in tg.devices():
            self.arenas[d] = Arena(d, config.cap_of(d))
            self.arenas[d].bind_executed_set(self.executed_mids)

        # consumer positions per tid, for Belady next-use and simFree
        self.cons_pos: dict[int, list[int]] = {
            t: sorted(self.pos[c] for c in tg.consumers(t)) for t in tg.vertices}
        self.cons_ptr: dict[int, int] = {t: 0 for t in tg.vertices}

        self.mid_of: dict[int, int] = {}         # tid -> primary mem vertex
        self.alias: dict[int, int] = {}           # tid -> mid of live value
        self.tid_of: dict[int, int] = {}          # mid -> tid (incl. reloads)
        self.evicted: set[int] = set()            # tids pending reload
        self.host_src: dict[int, int | None] = {}  # mid -> offload mid | None(=store)
        self.unallocated: set[int] = set()         # cancelled reservations (tids)
        self.terminal_host: dict[int, int | None] = {}
        # streaming-reduce groups: tid -> (alloc0_mid, join_mid)
        self.groups: dict[int, tuple[int, int]] = {}

        # the host tier: one CPU-RAM arena shared by all devices, with
        # Belady-over-the-schedule victim choice (DESIGN.md §10). Under a
        # pool (§12) the budget is the leased share, not the whole pool.
        self.hostplan = HostPlan(config.host_budget(), self._host_next_use)
        self.host_key_of: dict[int, int] = {}      # tid -> host-store key

        self.seq = 0
        self.n_offloads = self.n_reloads = self.n_cancelled = 0
        self.n_spills = self.n_loads = 0

        # ---- cross-tier prefetch + disk budget (DESIGN.md §11) ----------
        # execution windows: window w spans (completion of exec w-1,
        # completion of exec w]. Pass 1 records per-window max host
        # occupancy (occ_at) and every reactive LOAD (load_records) for
        # the PrefetchPlan; pass 2 consumes the resulting hints.
        self.prefetch_hints = prefetch_hints or {}
        self.exec_done = 0                      # current window index
        self.occ_at: list[int] = []             # per-window max occupancy
        self._win_max = 0
        self.load_records: list[PrefetchRecord] = []
        self.spill_window: dict[int, int] = {}  # SPILL mid -> window
        self.n_prefetches = 0
        self.stall_bytes_hidden = 0
        # disk-tier replay: blob units keyed by host key; first SPILL of a
        # key creates its blob, a drop vertex releases it
        self.disk_units = 0
        self.peak_disk = 0
        self.disk_size_of: dict[int, int] = {}
        # all-orders disk soundness (bounded cap only): every unit of a new
        # blob must be backed either by capacity never yet consumed
        # (_disk_free) or by a specific earlier drop, with a MEM dep on
        # that drop — the seq-order replay alone leaves a window where a
        # blob-creating SPILL overtakes the drop it was counting on
        # (certifier pass 3, DESIGN.md §13)
        self._disk_free = config.disk_capacity or 0
        self._disk_credits: list[list[int]] = []   # FIFO of [drop_mid, units]

    # ------------------------------------------------------------------ utils
    def _mark_executed(self, mid: int) -> None:
        self.mg.vertices[mid].seq = self.seq
        self.seq += 1
        self.executed_mids.add(mid)

    def next_use(self, mid: int) -> float:
        """Belady metric: position in V of the next simulated use of the
        tensor occupying ``mid``'s extent. An unexecuted reservation's next
        use is its own position (it still must run)."""
        tid = self.tid_of[mid]
        ptr = self.cons_ptr[tid]
        cp = self.cons_pos[tid]
        nxt: float = cp[ptr] if ptr < len(cp) else INF
        if mid not in self.executed_mids:
            nxt = min(nxt, self.pos[tid])
        return nxt

    def _arena(self, device: int) -> Arena:
        return self.arenas[device]

    # ----------------------------------------------- host tier (§10) utils
    def _host_next_use(self, e: HostEntry) -> float:
        """Belady metric for a host copy: the next position in V where the
        copy will be read back (i.e. the evicted tensor's next consumer).
        A copy whose tensor is device-resident or terminal has no known
        host-side use — it spills first."""
        if e.tid in self.evicted:
            cp, ptr = self.cons_pos[e.tid], self.cons_ptr[e.tid]
            if ptr < len(cp):
                return cp[ptr]
        return INF

    def _emit_spill(self, e: HostEntry, *, drop: bool = False) -> int:
        """SPILL vertex: evict host copy ``e`` to the disk tier (or, with
        ``drop``, release dead bytes). Re-spilling a copy that already has
        an immutable disk twin moves no bytes (nbytes=0) — the disk
        analogue of ``reuse_host_copy``. Ordered after the copy's producer
        and every emitted reader of the host bytes."""
        src = self.mg.vertices[e.producer]
        dedup = e.spill_src is not None
        tname = self.tg.vertices[e.tid].name or str(e.tid)
        smid = self.mg.add_vertex(
            MemOp.SPILL, src.device, src_tid=e.tid, loc=None,
            size=e.size, nbytes=0 if (drop or dedup) else e.nbytes,
            operands=[e.key], params={"drop": True} if drop else {},
            tier="disk", name=("drop:" if drop else "spill:") + tname)
        self.tid_of[smid] = e.tid
        self.mg.add_dep(e.producer, smid, DepKind.DATA)
        for r in e.readers:
            self.mg.add_dep(r, smid, DepKind.MEM)
        if drop:
            # the drop releases *every* copy of the bytes (host + disk
            # blob), so it must wait for anything that ever read them on
            # any tier: LOADs of the blob, readers of earlier residencies,
            # and the spill that retired the latest one — per-residency
            # deps alone leave a window where an old reader's read-through
            # races the blob's deletion
            for r in e.disk_readers | e.all_readers:
                self.mg.add_dep(r, smid, DepKind.MEM)
            if e.last_spill is not None:
                self.mg.add_dep(e.last_spill, smid, DepKind.MEM)
        self._mark_executed(smid)
        self.spill_window[smid] = self.exec_done
        if drop:
            self._disk_release(smid, self.disk_size_of.pop(e.key, 0))
        elif not dedup:
            self.n_spills += 1
            # annotate the originating offload: its payload continues to disk
            self.mg.vertices[e.key].tier = "disk"
            self._disk_admit(e.key, e.size, e.tid, smid)
        return smid

    def _disk_admit(self, key: int, size: int, tid: int, smid: int) -> None:
        """Charge a new blob against the disk budget (compile-time
        feasibility: the last tier has nowhere further to evict to), and —
        bounded — back every unit by unconsumed capacity or a specific
        earlier drop with a MEM dep ``drop → smid``, so *no* legal
        execution order can overflow the disk (not just the replayed one:
        without the dep a blob-creating SPILL may overtake the drop whose
        freed units the replay counted on)."""
        self.disk_size_of[key] = size
        self.disk_units += size
        self.peak_disk = max(self.peak_disk, self.disk_units)
        cap = self.cfg.disk_capacity
        if cap is None:
            return
        if self.disk_units > cap:
            raise MemgraphOOM(
                f"disk tier of {cap} units cannot hold the spilled working "
                f"set: {self.disk_units} units live after spilling task "
                f"{tid} — the three-level footprint does not fit "
                f"(host={self.cfg.host_budget()}, disk={cap})")
        need = size - min(self._disk_free, size)
        self._disk_free -= size - need
        while need > 0:
            # invariant: _disk_free + queued credits == cap - disk_units
            # (+ size here), so the queue covers `need` whenever the
            # feasibility check above passed
            drop_mid, units = self._disk_credits[0]
            take = min(units, need)
            self.mg.add_dep(drop_mid, smid, DepKind.MEM)
            need -= take
            if take == units:
                self._disk_credits.pop(0)
            else:
                self._disk_credits[0][1] = units - take

    def _disk_release(self, drop_mid: int, units: int) -> None:
        """Return a dropped blob's units to the budget as a credit tagged
        with the drop vertex, for later admissions to order after."""
        self.disk_units -= units
        if units and self.cfg.disk_capacity is not None:
            self._disk_credits.append([drop_mid, units])

    def _emit_disk_drop(self, e: HostEntry) -> int:
        """Release a dead, non-resident entry's disk blob: a zero-host-unit
        drop SPILL ordered after the blob's writer and every LOAD that read
        it, so the disk-tier units are reclaimed in any legal order (the
        blob used to linger until store close — an unbounded-disk hole)."""
        tname = self.tg.vertices[e.tid].name or str(e.tid)
        dmid = self.mg.add_vertex(
            MemOp.SPILL, self.mg.vertices[e.key].device, src_tid=e.tid,
            loc=None, size=0, nbytes=0, operands=[e.key],
            params={"drop": True}, tier="disk", name="drop:" + tname)
        self.tid_of[dmid] = e.tid
        self.mg.add_dep(e.spill_src, dmid, DepKind.DATA)
        # same total-ordering discipline as a resident drop: wait for every
        # reader of every residency and the spill that retired the last one
        for r in e.disk_readers | e.all_readers | e.readers:
            self.mg.add_dep(r, dmid, DepKind.MEM)
        if e.last_spill is not None:
            self.mg.add_dep(e.last_spill, dmid, DepKind.MEM)
        self._mark_executed(dmid)
        self._disk_release(dmid, self.disk_size_of.pop(e.key, 0))
        return dmid

    def _host_admit(self, producer_mid: int, key: int, tid: int,
                    size: int, nbytes: int,
                    exclude: frozenset = frozenset()) -> None:
        """Admit ``producer_mid``'s host copy into the host tier, emitting
        SPILL vertices for Belady victims and wiring the safe-overwrite MEM
        deps the producer must wait on."""
        deps = self.hostplan.admit(key, tid, size, nbytes, producer_mid,
                                   self.seq, spill_cb=self._emit_spill,
                                   exclude=exclude)
        if deps is None:
            raise MemgraphOOM(
                f"host tier of {self.cfg.host_budget()} units"
                f"{' (leased share)' if self.cfg.host_lease is not None else ''}"
                f" cannot stage {size} units for task {tid}")
        for d in deps:
            self.mg.add_dep(d, producer_mid, DepKind.MEM)
        self._win_max = max(self._win_max, self.hostplan.used_units)
        if self.hostplan.bounded:
            self.host_key_of[tid] = key

    def _drop_host_entry(self, e: HostEntry) -> None:
        """Release a dead host copy (and its disk twin, wherever it is)."""
        self.host_key_of.pop(e.tid, None)
        if e.resident:
            dmid = self._emit_spill(e, drop=True)
            self.hostplan.dropped(e, dmid, self.seq)
        else:
            if e.spill_src is not None:
                self._emit_disk_drop(e)
            self.hostplan.forget(e.key)

    # ------------------------------------- safe-overwrite deps (simMalloc)
    def _overwrite_deps(self, dec, tenant_mid: int) -> None:
        """Safe-overwrite: every reader of the bytes' previous writers must
        precede the new tenant (paper Fig. 9, simMalloc). ``direct_deps`` are
        ordering-only obligations (a pending offload of evicted bytes, the
        victim's executed readers) and get edges without reader expansion —
        expanding them would pull in *reload* vertices, which read the host
        copy, not the overwritten device bytes."""
        for w in dec.prev_writers:
            self.mg.add_dep(w, tenant_mid, DepKind.MEM)
            for r in self.mg.data_succs(w):
                self.mg.add_dep(r, tenant_mid, DepKind.MEM)
        for d in dec.direct_deps:
            self.mg.add_dep(d, tenant_mid, DepKind.MEM)

    # ------------------------------------------------------- allocation paths
    def _try_alloc(self, tid: int) -> bool:
        """simMalloc for the vertex at allocHzn: free space only."""
        v = self.tg.vertices[tid]
        size = self.cfg.size_of(v)
        arena = self._arena(v.device)
        if size > arena.capacity:
            raise MemgraphOOM(
                f"tensor of {size} units for task {tid} exceeds device "
                f"{v.device} capacity {arena.capacity}")
        dec = arena.place_free(size)
        if dec is None:
            return False
        self._commit_vertex(tid, arena, dec)
        return True

    def _alloc_offld(self, tid: int) -> None:
        """simMallocOffld: eviction placement; cannot fail short of OOM."""
        v = self.tg.vertices[tid]
        arena = self._arena(v.device)
        dec = self._evict_place(arena, self.cfg.size_of(v), f"output of {tid}")
        self._commit_vertex(tid, arena, dec)

    def _evict_place(self, arena: Arena, size: int, why: Any) -> PlacementDecision:
        evd = arena.place_evict(size, self.next_use,
                                victim_policy=self.cfg.victim_policy,
                                rng=self.rng)
        if evd is None:
            evd = arena.place_evict(size, self.next_use, allow_cancel=True,
                                    victim_policy=self.cfg.victim_policy,
                                    rng=self.rng)
        if evd is None:
            raise MemgraphOOM(
                f"device {arena.device}: cannot place {size} units for {why}; "
                f"capacity {arena.capacity}, pinned working set too large")
        extra = self._apply_eviction(arena, evd)
        dec = arena.evict_and_carve(evd, self.seq)
        dec.direct_deps |= extra   # ordering-only deps: no reader expansion
        return dec

    def _apply_eviction(self, arena: Arena, evd: EvictionDecision) -> set[int]:
        """Emit offload/reload chains for victims; cancel reservations.
        Returns extra mids the new tenant must wait on."""
        tenant_deps: set[int] = set()
        for mid in evd.victims:
            tenant_deps |= self._evict_one(arena.device, mid)
        for mid in evd.cancelled:
            tid = self.tid_of[mid]
            self.mg.vertices[mid].loc = None
            self.unallocated.add(tid)
            self.n_cancelled += 1
            # stale safe-overwrite deps on the reservation remain: they are
            # forward edges and merely conservative.
        return tenant_deps

    def _evict_one(self, device: int, victim_mid: int) -> set[int]:
        """victim → offload → reload chain (paper Fig. 9, simMallocOffld)."""
        vv = self.mg.vertices[victim_mid]
        tid = self.tid_of[victim_mid]
        deps: set[int] = {victim_mid}
        deps.update(self.mg.data_succs(victim_mid))  # readers-so-far

        have_host = (self.cfg.reuse_host_copy
                     and victim_mid in self.host_src)
        if have_host:
            off_mid = self.host_src[victim_mid]   # may be None (input store)
            if off_mid is not None:
                deps.add(off_mid)
        else:
            # a superseded host copy (reuse_host_copy=False re-offloads the
            # same tensor) is dead: release its host-tier extent first
            if self.hostplan.bounded:
                old_key = self.host_key_of.get(tid)
                if old_key is not None and old_key in self.hostplan.entries:
                    self._drop_host_entry(self.hostplan.entries[old_key])
            off_mid = self.mg.add_vertex(
                MemOp.OFFLOAD, device, src_tid=tid, loc=None,
                size=vv.size, nbytes=vv.nbytes, operands=[victim_mid],
                name=f"offload:{vv.name or tid}")
            self.tid_of[off_mid] = tid
            self.mg.add_dep(victim_mid, off_mid, DepKind.DATA)
            self._host_admit(off_mid, off_mid, tid, vv.size, vv.nbytes)
            self._mark_executed(off_mid)
            self.n_offloads += 1
            deps.add(off_mid)

        has_future = self.cons_ptr[tid] < len(self.cons_pos[tid])
        if not has_future:
            # terminal output: the host copy is its final resting place
            self.terminal_host[tid] = off_mid
            self.alias[tid] = off_mid if off_mid is not None else victim_mid
            self.evicted.discard(tid)
            return deps

        # rename all future uses of the victim to its reload
        rel_mid = self.mg.add_vertex(
            MemOp.RELOAD, device, src_tid=tid, loc=None,
            size=vv.size, nbytes=vv.nbytes,
            operands=[off_mid] if off_mid is not None else [],
            name=f"reload:{vv.name or tid}")
        self.tid_of[rel_mid] = tid
        if off_mid is not None:
            self.mg.add_dep(off_mid, rel_mid, DepKind.DATA)
        self.n_reloads += 1
        self.alias[tid] = rel_mid
        self.evicted.add(tid)
        self.host_src[rel_mid] = off_mid
        return deps

    def _commit_vertex(self, tid: int, arena: Arena,
                       dec: PlacementDecision) -> None:
        """Create (or re-place, if cancelled) the mem vertex for ``tid`` and
        bind its extent; wire safe-overwrite deps."""
        v = self.tg.vertices[tid]
        mid = self.mid_of.get(tid)
        loc = Loc(arena.device, dec.offset, dec.size)
        if mid is None:
            op = {OpKind.INPUT: MemOp.INPUT, OpKind.COMPUTE: MemOp.COMPUTE,
                  OpKind.TRANSFER: MemOp.TRANSFER,
                  OpKind.REDUCE: MemOp.COMPUTE}[v.kind]
            if v.kind == OpKind.REDUCE and v.streaming:
                op = MemOp.JOIN
            mid = self.mg.add_vertex(
                op, v.device, src_tid=tid, loc=loc, op_name=v.op,
                params=v.params, flops=v.flops, size=dec.size,
                nbytes=v.out.nbytes, name=v.name or str(tid))
            self.mid_of[tid] = mid
            self.tid_of[mid] = tid
            self.alias[tid] = mid
            if v.kind == OpKind.INPUT:
                self.host_src[mid] = _HOST_STORE  # input store holds it
        else:
            self.mg.vertices[mid].loc = loc
            self.unallocated.discard(tid)
        tenant = mid
        if v.kind == OpKind.REDUCE and v.streaming:
            # zero-init is the first writer; extent pinned until JOIN runs
            a0 = self.mg.add_vertex(
                MemOp.ALLOC0, v.device, src_tid=tid, loc=loc,
                op_name="zeros", size=dec.size, nbytes=v.out.nbytes,
                lock_group=loc.key, name=f"alloc0:{v.name or tid}")
            self.tid_of[a0] = tid
            self.mg.vertices[mid].lock_group = loc.key
            self.groups[tid] = (a0, mid)
            self._mark_executed(a0)
            self.mg.add_dep(a0, mid, DepKind.DATA)
            tenant = a0
        self._overwrite_deps(dec, tenant)
        arena.commit(dec, mid)
        if v.kind == OpKind.REDUCE and v.streaming:
            arena.pin(mid)

    # -------------------------------------------------- execution simulation
    def _advance_and_free(self, t: int, mypos: int) -> None:
        """simFree: advance ``t``'s consumer pointer past ``mypos``; free its
        extent once no future consumer remains."""
        cp, ptr = self.cons_pos[t], self.cons_ptr[t]
        while ptr < len(cp) and cp[ptr] <= mypos:
            ptr += 1
        self.cons_ptr[t] = ptr
        if (ptr >= len(cp) and t not in self.evicted
                and t not in self.terminal_host):
            m = self.alias[t]
            if self.mg.vertices[m].loc is not None:
                self._arena(self.mg.vertices[m].loc.device).free(m, self.seq)
            # any host/disk copy of a fully-consumed, non-terminal tensor
            # is dead: give its host-tier extent back (a zero-cost drop)
            if self.hostplan.bounded:
                key = self.host_key_of.get(t)
                if key is not None and key in self.hostplan.entries:
                    self._drop_host_entry(self.hostplan.entries[key])

    def _force_reload(self, tid: int) -> int:
        """simMallocForceReld: place the pending reload of ``tid``."""
        mid = self.alias[tid]
        vv = self.mg.vertices[mid]
        arena = self._arena(vv.device)
        dec = arena.place_free(vv.size)
        if dec is None:
            dec = self._evict_place(arena, vv.size, f"reload of {tid}")
        vv.loc = Loc(arena.device, dec.offset, dec.size)
        arena.commit(dec, mid)
        self._overwrite_deps(dec, mid)
        self._wire_host_source(mid, vv)
        self._mark_executed(mid)
        self.evicted.discard(tid)
        return mid

    def _wire_host_source(self, rel_mid: int, vv) -> None:
        """Bind a RELOAD to the tier currently holding its source copy.

        Host-resident: order after the copy's live producer (the OFFLOAD,
        or the latest LOAD that restaged it). Disk-resident: emit the
        pipelined two-hop chain — a LOAD (disk→host, on the disk engine)
        that restages the copy into the host arena (possibly spilling
        Belady victims to make room), then the RELOAD's h2d hop."""
        if not self.hostplan.bounded:
            return
        key = self.host_src.get(rel_mid)
        if key is None:                    # immutable input store: one hop
            return
        e = self.hostplan.entries.get(key)
        if e is None:                      # pragma: no cover — defensive
            return
        if e.resident:
            self.mg.add_dep(e.producer, rel_mid, DepKind.DATA)
            e.readers.add(rel_mid)
            if self.mg.vertices[e.producer].op == MemOp.LOAD:
                # the copy was restaged from disk (a prefetch LOAD): this
                # reload is the pipelined tail of a two-hop chain
                vv.tier = "disk"
            return
        tid = e.tid
        lmid = self.mg.add_vertex(
            MemOp.LOAD, vv.device, src_tid=tid, loc=None,
            size=e.size, nbytes=e.nbytes, operands=[key], tier="disk",
            name=f"load:{self.tg.vertices[tid].name or tid}")
        self.tid_of[lmid] = tid
        self.mg.add_dep(e.spill_src, lmid, DepKind.DATA)
        self.load_records.append(PrefetchRecord(
            tid=tid, size=e.size, nbytes=e.nbytes,
            spill_pos=self.spill_window.get(e.spill_src, 0),
            reload_pos=self.exec_done))
        self._host_admit(lmid, key, tid, e.size, e.nbytes,
                         exclude=frozenset({key}))
        self._mark_executed(lmid)
        self.n_loads += 1
        self.mg.add_dep(lmid, rel_mid, DepKind.DATA)
        vv.tier = "disk"
        self.hostplan.entries[key].readers.add(rel_mid)
        self.hostplan.entries[key].disk_readers.add(lmid)

    def _close_window(self) -> None:
        """One task finished simulating: seal its execution window's
        occupancy high-water mark (the PrefetchPlan's feasibility input)."""
        self._win_max = max(self._win_max, self.hostplan.used_units)
        self.occ_at.append(self._win_max)
        self.exec_done += 1
        self._win_max = self.hostplan.used_units

    def _try_prefetch(self, tid: int) -> None:
        """Pass-2 hint: restage ``tid``'s disk-resident host copy *now*,
        ahead of its consumer (a ``prefetch=True`` LOAD on the disk
        engine). Best-effort and free-space-only: if the entry is not
        actually spilled at this point (pass divergence) or no free host
        extent fits, the hint is dropped and the reactive force-reload
        path still covers the use — a skipped prefetch can only cost
        timing, never correctness."""
        key = self.host_key_of.get(tid)
        if key is None:
            return
        e = self.hostplan.entries.get(key)
        if e is None or e.resident or e.spill_src is None:
            return
        lmid = self.mg.add_vertex(
            MemOp.LOAD, self.mg.vertices[e.key].device, src_tid=tid,
            loc=None, size=e.size, nbytes=e.nbytes, operands=[key],
            tier="disk", prefetch=True,
            name=f"load:{self.tg.vertices[tid].name or tid}")
        deps = self.hostplan.admit(key, tid, e.size, e.nbytes, lmid,
                                   self.seq, spill_cb=self._emit_spill,
                                   exclude=frozenset({key}),
                                   allow_spill=False)
        if deps is None:                 # no free space here in pass 2
            self.mg.remove_vertex(lmid)
            return
        self.tid_of[lmid] = tid
        self.mg.add_dep(e.spill_src, lmid, DepKind.DATA)
        for d in deps:
            self.mg.add_dep(d, lmid, DepKind.MEM)
        e.disk_readers.add(lmid)
        self._mark_executed(lmid)
        self._win_max = max(self._win_max, self.hostplan.used_units)
        self.n_loads += 1
        self.n_prefetches += 1
        self.stall_bytes_hidden += e.nbytes
        if tid in self.evicted:
            # the pending RELOAD is now the pipelined tail of a two-hop
            # chain whose disk leg runs ahead of the consumer's horizon
            self.mg.vertices[self.alias[tid]].tier = "disk"

    def _execute(self, tid: int) -> None:
        v = self.tg.vertices[tid]
        vmid = self.mid_of.get(tid)
        pins: list[tuple[Arena, int]] = []

        def pin(arena: Arena, mid: int) -> None:
            arena.pin(mid)
            pins.append((arena, mid))

        try:
            # output extent: re-place if the reservation was cancelled
            if vmid is None or self.mg.vertices[vmid].loc is None:
                arena = self._arena(v.device)
                dec = arena.place_free(self.cfg.size_of(v))
                if dec is None:
                    dec = self._evict_place(arena, self.cfg.size_of(v),
                                            f"output of {tid}")
                self._commit_vertex(tid, arena, dec)
                vmid = self.mid_of[tid]
            out_arena = self._arena(v.device)
            streaming = v.kind == OpKind.REDUCE and v.streaming
            if not streaming:
                pin(out_arena, vmid)

            uniq_inputs = list(dict.fromkeys(v.inputs))
            mypos = self.pos[tid]
            if streaming:
                # §B: n partial sums stream into a locked accumulator one at
                # a time; each input is consumed — and its extent freed —
                # immediately, so at most one partial plus the accumulator
                # must be resident. This is what lets TURNIP "force them to
                # be run in sequence and offloaded" (paper §8).
                a0, join = self.groups[tid]
                loc = self.mg.vertices[join].loc
                join_ops: list[int] = []
                for t in uniq_inputs:
                    m = self._force_reload(t) if t in self.evicted else self.alias[t]
                    src_arena = self._arena(self.mg.vertices[m].loc.device)
                    src_arena.pin(m)
                    g = self.mg.add_vertex(
                        MemOp.ADD_INTO, v.device, src_tid=tid, loc=loc,
                        op_name="add_into", size=loc.size,
                        nbytes=v.out.nbytes, lock_group=loc.key,
                        operands=[m], name=f"add_into:{v.name or tid}")
                    self.tid_of[g] = tid
                    self.mg.add_dep(m, g, DepKind.DATA)
                    self.mg.add_dep(a0, g, DepKind.DATA)
                    self.mg.add_dep(g, join, DepKind.DATA)
                    self._mark_executed(g)
                    join_ops.append(g)
                    src_arena.unpin(m)
                    self._advance_and_free(t, mypos)
                self.mg.vertices[vmid].operands = join_ops
            else:
                resolved: dict[int, int] = {}
                for t in uniq_inputs:
                    m = self._force_reload(t) if t in self.evicted else self.alias[t]
                    resolved[t] = m
                    pin(self._arena(self.mg.vertices[m].loc.device), m)
                    self.mg.add_dep(m, vmid, DepKind.DATA)
                self.mg.vertices[vmid].operands = [resolved[t] for t in v.inputs]
        finally:
            for arena, mid in pins:
                arena.unpin(mid)

        # simFree: dead inputs give their extents back
        if not (v.kind == OpKind.REDUCE and v.streaming):
            for t in dict.fromkeys(v.inputs):
                self._advance_and_free(t, self.pos[tid])

        if v.kind == OpKind.REDUCE and v.streaming:
            out_arena.unpin(vmid)   # group pin taken at alloc time
        self._mark_executed(vmid)

    # ------------------------------------------- main loop (paper Fig. 8)
    def run(self) -> BuildResult:
        n = len(self.V)
        alloc_i = exec_i = 0
        while exec_i < n:
            if alloc_i < n and self._try_alloc(self.V[alloc_i]):
                alloc_i += 1            # allocated space for a future result
            elif alloc_i == exec_i:
                self._alloc_offld(self.V[alloc_i])  # must evict to proceed
                alloc_i += 1
            else:
                self._execute(self.V[exec_i])
                self._close_window()
                # the boundary after exec_i: emit the PrefetchPlan's
                # hoisted disk→host restages scheduled for this point
                for t in self.prefetch_hints.get(exec_i, ()):
                    self._try_prefetch(t)
                exec_i += 1
        return BuildResult(
            memgraph=self.mg,
            mid_of=dict(self.mid_of),
            order=list(self.V),
            peak_used={d: a.peak_used for d, a in self.arenas.items()},
            terminal_host=dict(self.terminal_host),
            n_offloads=self.n_offloads,
            n_reloads=self.n_reloads,
            n_cancelled=self.n_cancelled,
            peak_host=self.hostplan.peak_units,
            n_spills=self.n_spills,
            n_loads=self.n_loads,
            peak_disk=self.peak_disk,
            n_prefetches=self.n_prefetches,
            stall_bytes_hidden=self.stall_bytes_hidden,
            host_residencies=[list(r) for r in self.hostplan.residency_log],
        )


def _check_order(tg: TaskGraph, pos: dict[int, int]) -> None:
    for v in tg.vertices.values():
        for i in v.inputs:
            if pos[i] >= pos[v.tid]:
                raise ValueError(f"order violates dataflow: {i} !< {v.tid}")
