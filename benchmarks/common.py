"""Shared benchmark utilities: hardware profiles and workload builders."""
from __future__ import annotations

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.simulate import HardwareModel  # noqa: E402

# Paper machine (i): 4×P100 (16GB each), PCIe gen3, fp16.
P100_SERVER = dict(
    n_devices=4,
    hbm_per_dev=16 * 2**30,
    hw=HardwareModel(flops=9e12, hbm_bw=500e9, h2d_bw=11e9, d2h_bw=11e9,
                     d2d_bw=9e9, transfer_jitter=0.6, seed=0),
)

# Paper machine (ii): 8×A100-40GB (p4d.24xlarge).
A100_SERVER = dict(
    n_devices=8,
    hbm_per_dev=40 * 2**30,
    hw=HardwareModel(flops=60e12, hbm_bw=1500e9, h2d_bw=22e9, d2h_bw=22e9,
                     d2d_bw=50e9, transfer_jitter=0.6, seed=0),
)

# TPU v5e host (the port target): 4 chips/host, 16GB HBM each.
V5E_HOST = dict(
    n_devices=4,
    hbm_per_dev=16 * 2**30,
    hw=HardwareModel(flops=197e12, hbm_bw=819e9, h2d_bw=32e9, d2h_bw=32e9,
                     d2d_bw=50e9, transfer_jitter=0.6, seed=0),
)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The scaffold's CSV contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}")
