"""Model zoo: unified decoder LM (dense / MoE / RWKV6 / Zamba2) + enc-dec."""
from ..configs.base import ArchConfig
from .lm import LM
from .encdec import EncDec


def build_model(cfg: ArchConfig, **kw):
    """Factory: the right model class for an architecture config."""
    if cfg.family == "encdec":
        return EncDec(cfg, **{k: v for k, v in kw.items()
                              if k in ("block_kv", "remat")})
    return LM(cfg, **kw)


__all__ = ["LM", "EncDec", "build_model"]
