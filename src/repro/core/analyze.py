"""Static plan-soundness certifier (DESIGN.md §13).

TURNIP's premise is that the runtime may execute a MEMGRAPH in *any*
dependency-respecting order, so plan correctness is a universally
quantified claim: byte-exactness, tier coherence, and budget feasibility
must hold for **all** topological orders. ``validate()`` replays one
order and the differential harness samples a few more; this module
closes the gap by *proving* the claim over the transitive order itself.

Three passes over a built :class:`~repro.core.memgraph.MemGraph`:

1. **Happens-before race detector** (:func:`_pass_device_races`) — the
   DAG's transitive order is materialized as descendant bitsets
   (``MemGraph.reachability``); every pair of vertices touching
   overlapping device extents with at least one writer, and every
   operand read, must be ordered. Generalizes
   ``MemGraph._check_safe_overwrites`` from overwrites to all
   read/write/overwrite interleavings (lock-group accumulations exempt,
   as at runtime).

2. **Tier-lifetime linter** (:func:`_pass_tier_lifetimes`) — per host
   key, an abstract created → resident ⇄ spilled → freed state machine
   interpreted over *all* orders: every access must be reachable from
   the key's creating OFFLOAD, every copy-releasing drop must be
   reachable from every reader (use-after-drop, drop-before-last-reader,
   stale-twin read-through racing the blob's deletion, double-spill).

3. **Worst-case budget soundness** (:func:`_pass_budgets`) — host/disk
   occupancy under *any* legal order is bounded by the max-weight
   antichain of residency intervals (two residencies can be
   simultaneously live iff neither's release happens-before the other's
   admit; pairwise-incomparable residencies are jointly realizable via
   the down-closure of their admits, so the bound is exact). Computed
   exactly by a min-flow/max-antichain dual (weighted Dilworth) and
   compared against ``host_capacity``/``disk_capacity`` — upgrading the
   single-order replay in ``validate(host_capacity=)``.

Every finding is a typed :class:`PlanHazard` carrying a **witness
schedule**: a full topological order (plus, for budget hazards, a prefix
and expected occupancy) that the differential harness replays to confirm
the hazard dynamically — static findings stay falsifiable.

CLI: ``python -m repro.core.analyze`` certifies the seeded example-plan
corpus (the same taskgraph distribution the fuzz suites draw from) and
exits nonzero on any hazard; CI gates on it.
"""
from __future__ import annotations

import argparse
import dataclasses
from collections import deque
from typing import Any, Callable, Iterable, Sequence

from .memgraph import MemGraph, MemOp, RaceError, STORE_OPS

__all__ = [
    "PlanHazard", "Certificate", "PlanCertificationError", "certify",
    "max_weight_antichain", "recover_residencies", "replay_occupancy",
    "Residency", "main",
]

# hazard kinds (PlanHazard.kind)
DEVICE_RACE = "device-race"                # unordered overlapping accesses
USE_AFTER_OVERWRITE = "use-after-overwrite"  # read ordered after clobber
OPERAND_UNORDERED = "operand-unordered"    # read not ordered after producer
ACCUM_UNINIT = "accumulator-uninitialized"  # ADD_INTO before its ALLOC0
TIER_BEFORE_CREATE = "tier-access-before-create"
USE_AFTER_DROP = "use-after-drop"
STALE_TWIN = "stale-twin"                  # read-through races twin deletion
DOUBLE_SPILL = "double-spill"
HOST_BUDGET = "host-budget"
DISK_BUDGET = "disk-budget"
STRUCTURE = "structure"


@dataclasses.dataclass(frozen=True)
class PlanHazard:
    """One certified finding: the claim, the vertices, and a witness
    schedule that exhibits it dynamically.

    ``witness`` is a full topological order of the graph. For
    ``witness_kind == 'race'`` replaying it through the sequential
    interpreter must raise (or diverge from the oracle); for
    ``'occupancy'`` the ``tier`` occupancy replayed over the witness
    reaches ``expect_units > capacity`` within the first ``prefix``
    vertices; for ``'stall'`` (the liveness certifier, DESIGN.md §14) the
    directed scheduler replaying the first ``prefix`` vertices with the
    blocking admission discipline reaches a bounded-timeout stall, with
    ``lease`` naming the contended pool share. ``confirmable`` is False
    for hazards whose bad interleaving is dynamically silent (e.g. a
    double-spill deduplicated by the store) — still plan bugs, but not
    replay-falsifiable."""

    kind: str
    vertices: tuple[int, ...]
    detail: str
    witness: tuple[int, ...] = ()
    witness_kind: str = "race"
    confirmable: bool = True
    tier: str | None = None
    prefix: int = 0
    expect_units: int = 0
    capacity: int | None = None
    lease: str | None = None

    def __str__(self) -> str:
        return f"[{self.kind}] {self.detail}"


@dataclasses.dataclass
class Certificate:
    """The certifier's verdict over one plan."""

    ok: bool
    hazards: list[PlanHazard]
    n_vertices: int
    host_capacity: int | None = None
    disk_capacity: int | None = None
    worst_host_units: int = 0          # max-antichain host occupancy bound
    worst_disk_units: int = 0
    n_host_residencies: int = 0
    n_disk_blobs: int = 0
    n_pairs_checked: int = 0           # overlapping device pairs examined

    def summary(self) -> str:
        head = ("CLEAN" if self.ok else
                f"{len(self.hazards)} hazard(s)")
        lines = [
            f"certificate: {head} over {self.n_vertices} vertices "
            f"({self.n_pairs_checked} overlapping extent pairs, "
            f"{self.n_host_residencies} host residencies, "
            f"{self.n_disk_blobs} disk blobs)",
            f"  worst-case host occupancy {self.worst_host_units} units"
            + (f" / capacity {self.host_capacity}"
               if self.host_capacity is not None else " (unbounded)"),
            f"  worst-case disk occupancy {self.worst_disk_units} units"
            + (f" / capacity {self.disk_capacity}"
               if self.disk_capacity is not None else " (unbounded)"),
        ]
        lines += [f"  {h}" for h in self.hazards]
        return "\n".join(lines)


class PlanCertificationError(RaceError):
    """A compiled plan failed certification (compiler bug: fail loudly)."""

    def __init__(self, certificate: Certificate) -> None:
        super().__init__(certificate.summary())
        self.certificate = certificate


# --------------------------------------------------------------------------
# witness schedules
# --------------------------------------------------------------------------
def _witness_order(mg: MemGraph, early: Iterable[int],
                   late: Iterable[int]) -> tuple[int, ...]:
    """A topological order scheduling ``early`` (and their ancestor
    closures) as soon as possible and ``late`` (and their descendant
    closures) as late as possible — the adversarial schedule that turns
    an unordered hazard pair into a concrete interleaving. Ties follow
    the compile-time seq so the witness stays close to a real schedule."""
    bitpos, desc = mg.reachability()
    early = set(early)
    late_mask = 0
    for m in late:
        late_mask |= (1 << bitpos[m]) | desc[m]
    ebits = [bitpos[e] for e in early]

    def key(m: int) -> tuple[int, int, int]:
        if (late_mask >> bitpos[m]) & 1:
            tier = 2
        elif m in early or any((desc[m] >> b) & 1 for b in ebits):
            tier = 0
        else:
            tier = 1
        return (tier, mg.vertices[m].seq, m)

    return tuple(mg.topo_order(key=key))


def replay_occupancy(mg: MemGraph, order: Sequence[int],
                     tier: str = "host") -> list[int]:
    """Tier occupancy (units) after each prefix of ``order``, with the
    runtime store's semantics: OFFLOAD/LOAD admit a key's bytes, SPILL
    releases them (a spill of a non-resident key is a no-op, matching
    ``TieredStore``; the first real spill creates the immutable disk
    blob, a drop releases every copy). The dynamic confirmation for
    occupancy witnesses — ``TieredStore`` itself does not enforce plan
    budgets at runtime."""
    occ_host = occ_disk = 0
    res_units: dict[int, int] = {}
    blob_units: dict[int, int] = {}
    out: list[int] = []
    for m in order:
        v = mg.vertices[m]
        if v.op == MemOp.OFFLOAD:
            if m not in res_units:
                res_units[m] = v.size
                occ_host += v.size
        elif v.op == MemOp.LOAD:
            key = v.operands[0] if v.operands else m
            if key not in res_units:
                res_units[key] = v.size
                occ_host += v.size
        elif v.op == MemOp.SPILL:
            key = v.operands[0] if v.operands else m
            if v.params.get("drop"):
                occ_host -= res_units.pop(key, 0)
                occ_disk -= blob_units.pop(key, 0)
            else:
                units = res_units.pop(key, 0)
                occ_host -= units
                if units and key not in blob_units:
                    blob_units[key] = units
                    occ_disk += units
        out.append(occ_host if tier == "host" else occ_disk)
    return out


# --------------------------------------------------------------------------
# max-weight antichain (weighted Dilworth via min-flow)
# --------------------------------------------------------------------------
def _min_flow(weights: Sequence[int], prec: Iterable[tuple[int, int]]) -> int:
    """Minimum flow covering element ``i`` at least ``weights[i]`` times,
    where a unit of flow may traverse any chain of the partial order
    ``prec`` (``(i, j)`` ⇒ i wholly precedes j). By LP duality this
    equals the maximum-weight antichain. Classic reduction: start from
    the feasible flow routing ``w_i`` through each element, then cancel
    as much as possible with a max-flow from sink to source over the
    residual network (lower bounds block cancellation below ``w_i``)."""
    n = len(weights)
    if n == 0:
        return 0
    total = sum(weights)
    big = total + 1
    S, T = 2 * n, 2 * n + 1
    cap: dict[tuple[int, int], int] = {}
    adj: dict[int, set[int]] = {}

    def arc(u: int, v: int, c: int) -> None:
        cap[(u, v)] = cap.get((u, v), 0) + c
        adj.setdefault(u, set()).add(v)
        adj.setdefault(v, set()).add(u)

    for i, w in enumerate(weights):
        arc(S, 2 * i, big)          # s→in: residual of the w-unit route
        arc(2 * i, S, w)
        arc(2 * i, 2 * i + 1, big)  # in→out: flow w at lower bound w
        arc(2 * i + 1, T, big)      # out→t
        arc(T, 2 * i + 1, w)
    for i, j in prec:
        arc(2 * i + 1, 2 * j, big)  # a chain may continue i → j
    cancelled = 0
    while True:                      # Edmonds–Karp from T to S
        parent: dict[int, int | None] = {T: None}
        dq = deque([T])
        while dq and S not in parent:
            u = dq.popleft()
            for v in adj.get(u, ()):
                if v not in parent and cap.get((u, v), 0) > 0:
                    parent[v] = u
                    dq.append(v)
        if S not in parent:
            return total - cancelled
        path = []
        v = S
        while parent[v] is not None:
            u = parent[v]
            assert u is not None
            path.append((u, v))
            v = u
        b = min(cap[(u, w)] for u, w in path)
        for u, w in path:
            cap[(u, w)] -= b
            cap[(w, u)] = cap.get((w, u), 0) + b
        cancelled += b


def max_weight_antichain(
        weights: Sequence[int],
        prec: Iterable[tuple[int, int]]) -> tuple[int, list[int]]:
    """``(best, members)``: the maximum total weight of any antichain of
    the partial order ``prec`` over ``range(len(weights))``, and one
    antichain achieving it. Members are recovered by peeling: an element
    belongs to some optimum iff fixing it (and restricting to its
    incomparables) preserves the target weight."""
    comparable = set()
    prec = list(prec)
    for a, b in prec:
        comparable.add((a, b))
        comparable.add((b, a))

    def value(sub: list[int]) -> int:
        pos = {g: i for i, g in enumerate(sub)}
        return _min_flow([weights[g] for g in sub],
                         [(pos[a], pos[b]) for a, b in prec
                          if a in pos and b in pos])

    live = [i for i in range(len(weights)) if weights[i] > 0]
    best = value(live)
    members: list[int] = []
    target = best
    while target > 0 and live:
        i = live[0]
        rest = [j for j in live[1:] if (i, j) not in comparable]
        if weights[i] + value(rest) == target:
            members.append(i)
            live = rest
            target -= weights[i]
        else:
            live = live[1:]
    return best, members


# --------------------------------------------------------------------------
# residency recovery (tier intervals from the graph alone)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Residency:
    """One tenancy of a tier: ``units`` held from ``admit`` until
    ``release`` (None = held to the end of the plan)."""

    key: int
    admit: int
    release: int | None
    units: int


def recover_residencies(
        mg: MemGraph) -> tuple[list[Residency], list[Residency]]:
    """Recover ``(host, disk)`` residency intervals statically. Host: an
    OFFLOAD/LOAD of a key opens a tenancy, the next SPILL of the key
    that is actually reachable from the admit closes it (an unreachable
    release cannot be relied on in all orders — the tenancy stays open,
    which is exactly the conservative reading the budget pass needs).
    Disk: the first real SPILL creates the blob, a reachable drop
    releases it."""
    events: dict[int, list[tuple[int, str, int]]] = {}
    for m, v in mg.vertices.items():
        if v.op == MemOp.OFFLOAD:
            events.setdefault(m, []).append((v.seq, "admit", m))
        elif v.op == MemOp.LOAD:
            key = v.operands[0] if v.operands else m
            events.setdefault(key, []).append((v.seq, "admit", m))
        elif v.op == MemOp.SPILL:
            key = v.operands[0] if v.operands else m
            kind = "drop" if v.params.get("drop") else "spill"
            events.setdefault(key, []).append((v.seq, kind, m))
    host: list[Residency] = []
    disk: list[Residency] = []
    for key, evs in events.items():
        evs.sort()
        admit: int | None = None
        blob: int | None = None
        blob_done = False
        for _, kind, m in evs:
            if kind == "admit":
                if admit is None:
                    admit = m
            else:
                if admit is not None and mg.happens_before(admit, m):
                    host.append(Residency(key, admit,
                                          m, mg.vertices[admit].size))
                    admit = None
                if kind == "spill" and blob is None and not blob_done:
                    blob = m
                elif kind == "drop" and blob is not None:
                    if mg.happens_before(blob, m):
                        disk.append(Residency(key, blob, m,
                                              mg.vertices[blob].size))
                        blob = None
                        blob_done = True
        if admit is not None:
            host.append(Residency(key, admit, None,
                                  mg.vertices[admit].size))
        if blob is not None:
            disk.append(Residency(key, blob, None, mg.vertices[blob].size))
    return host, disk


# --------------------------------------------------------------------------
# the certifier
# --------------------------------------------------------------------------
class _Cert:
    def __init__(self, mg: MemGraph, host_capacity: int | None,
                 disk_capacity: int | None, max_hazards: int) -> None:
        self.mg = mg
        self.host_capacity = host_capacity
        self.disk_capacity = disk_capacity
        self.max_hazards = max_hazards
        self.hazards: list[PlanHazard] = []
        self.n_pairs = 0
        self._seen: set[tuple[Any, ...]] = set()

    def full(self) -> bool:
        return len(self.hazards) >= self.max_hazards

    def emit(self, kind: str, vertices: tuple[int, ...], detail: str,
             **kw: Any) -> None:
        dedup = (kind,) + tuple(sorted(vertices))
        if dedup in self._seen or self.full():
            return
        self._seen.add(dedup)
        self.hazards.append(PlanHazard(kind, vertices, detail, **kw))

    # ---- pass 1: device extents -------------------------------------
    def pass_device_races(self) -> None:
        mg = self.mg
        before = mg.happens_before
        readers_of: dict[int, list[int]] = {}
        by_dev: dict[int, list[int]] = {}
        for m, v in mg.vertices.items():
            if v.loc is not None:
                by_dev.setdefault(v.loc.device, []).append(m)
            for o in dict.fromkeys(v.operands):
                ov = mg.vertices.get(o)
                if ov is None:
                    self.emit(STRUCTURE, (m,),
                              f"vertex {m} reads unknown operand {o}",
                              confirmable=False)
                    continue
                if ov.loc is None:
                    continue           # a tier access: pass 2's problem
                readers_of.setdefault(o, []).append(m)
                if m != o and not before(o, m):
                    # in some (or every) order m reads o's extent before
                    # o has written it
                    self.emit(
                        OPERAND_UNORDERED, (o, m),
                        f"vertex {m} ({v.op.value}) reads operand {o} "
                        f"without a dependency path from it",
                        witness=_witness_order(mg, {m}, {o}))

        # streaming accumulators: each ADD_INTO reads (and its JOIN
        # publishes) the accumulator extent ALLOC0 must have zeroed first
        alloc0s: dict[Any, list[int]] = {}
        for m, v in mg.vertices.items():
            if v.op == MemOp.ALLOC0:
                alloc0s.setdefault(v.lock_group, []).append(m)
        for m, v in mg.vertices.items():
            if v.op != MemOp.ADD_INTO:
                continue
            inits = alloc0s.get(v.lock_group, [])
            if not any(before(a, m) for a in inits):
                self.emit(
                    ACCUM_UNINIT, (m,) + tuple(inits),
                    f"add_into {m} may run before its accumulator is "
                    f"zero-initialized (lock group {v.lock_group})",
                    witness=_witness_order(mg, {m}, set(inits)))

        for dev, ms in by_dev.items():
            ms.sort(key=lambda m: mg.vertices[m].seq)
            for i, m1 in enumerate(ms):
                v1 = mg.vertices[m1]
                for m2 in ms[i + 1:]:
                    v2 = mg.vertices[m2]
                    if not v1.loc.overlaps(v2.loc):
                        continue
                    if (v1.lock_group is not None
                            and v1.lock_group == v2.lock_group):
                        continue       # commutative accumulation (§B)
                    self.n_pairs += 1
                    if before(m1, m2):
                        self._check_overwrite(m1, m2, readers_of)
                    elif before(m2, m1):
                        self._check_overwrite(m2, m1, readers_of)
                    else:
                        self._ww_race(m1, m2, readers_of)
                    if self.full():
                        return

    def _reader_exempt(self, r: int, w: int) -> bool:
        rv, wv = self.mg.vertices[r], self.mg.vertices[w]
        return (rv.lock_group is not None
                and rv.lock_group == wv.lock_group)

    def _check_overwrite(self, e: int, later: int,
                         readers_of: dict[int, list[int]]) -> None:
        """``later`` overwrites ``e``'s extent: every reader of ``e``
        must happen before it (the safe-overwrite rule, paper §4)."""
        mg, before = self.mg, self.mg.happens_before
        for r in readers_of.get(e, ()):
            if r == later or before(r, later):
                continue
            if self._reader_exempt(r, later):
                continue
            if before(later, r):
                self.emit(
                    USE_AFTER_OVERWRITE, (e, later, r),
                    f"vertex {r} reads {e}'s extent "
                    f"{mg.vertices[e].loc} strictly after writer {later} "
                    f"overwrites it — wrong bytes in every order",
                    witness=_witness_order(mg, {later}, {r}))
            else:
                self.emit(
                    DEVICE_RACE, (e, later, r),
                    f"reader {r} of {e}'s extent {mg.vertices[e].loc} "
                    f"is unordered with overwriting writer {later}",
                    witness=_witness_order(mg, {e}, {r}))
            if self.full():
                return

    def _ww_race(self, m1: int, m2: int,
                 readers_of: dict[int, list[int]]) -> None:
        """Unordered writers of overlapping extents. Witness defers a
        reader of whichever writer can be clobbered first, so the replay
        observes the corruption; with no observable reader the race is
        a dead-store conflict (still a plan bug, silently reordered)."""
        mg, before = self.mg, self.mg.happens_before
        for own, other in ((m1, m2), (m2, m1)):
            for r in readers_of.get(own, ()):
                if (r != other and not before(r, other)
                        and not self._reader_exempt(r, other)):
                    self.emit(
                        DEVICE_RACE, (m1, m2, r),
                        f"writers {m1} and {m2} of overlapping extents "
                        f"{mg.vertices[m1].loc} / {mg.vertices[m2].loc} "
                        f"are unordered (reader {r} observes)",
                        witness=_witness_order(mg, {own}, {r}))
                    return
        self.emit(DEVICE_RACE, (m1, m2),
                  f"writers {m1} and {m2} of overlapping extents "
                  f"{mg.vertices[m1].loc} / {mg.vertices[m2].loc} are "
                  f"unordered (no surviving reader: dead-store race)",
                  witness=_witness_order(mg, {m2}, {m1}),
                  confirmable=False)

    # ---- pass 2: tier lifetimes -------------------------------------
    def pass_tier_lifetimes(self) -> None:
        mg, before = self.mg, self.mg.happens_before
        creators: dict[int, list[int]] = {}
        readers: dict[int, list[int]] = {}    # RELOAD/LOAD: fail loudly
        spills: dict[int, list[int]] = {}
        drops: dict[int, list[int]] = {}
        for m, v in mg.vertices.items():
            if v.op == MemOp.OFFLOAD:
                creators.setdefault(m, []).append(m)
            elif v.op == MemOp.RELOAD and v.operands:
                readers.setdefault(v.operands[0], []).append(m)
            elif v.op == MemOp.LOAD:
                readers.setdefault(
                    v.operands[0] if v.operands else m, []).append(m)
            elif v.op == MemOp.SPILL:
                key = v.operands[0] if v.operands else m
                dst = drops if v.params.get("drop") else spills
                dst.setdefault(key, []).append(m)
        keys = set(creators) | set(readers) | set(spills) | set(drops)
        for key in sorted(keys):
            cs = creators.get(key, [])
            accesses = (readers.get(key, []) + spills.get(key, [])
                        + drops.get(key, []))
            loud = set(readers.get(key, ()))   # raise when key is absent
            if not cs:
                for a in accesses:
                    self.emit(
                        TIER_BEFORE_CREATE, (a,),
                        f"vertex {a} accesses host key {key} which no "
                        f"OFFLOAD ever creates",
                        witness=_witness_order(mg, {a}, set()),
                        confirmable=a in loud)
                continue
            c = cs[0]
            for a in accesses:
                if not before(c, a):
                    self.emit(
                        TIER_BEFORE_CREATE, (c, a),
                        f"vertex {a} ({mg.vertices[a].op.value}) accesses "
                        f"host key {key} without a dependency path from "
                        f"its creating offload {c}",
                        witness=_witness_order(mg, {a}, {c}),
                        confirmable=a in loud)
            for d in drops.get(key, []):
                for a in [c] + [x for x in accesses if x != d]:
                    if before(a, d):
                        continue
                    loud_a = a in loud
                    if before(d, a):
                        self.emit(
                            USE_AFTER_DROP, (d, a),
                            f"vertex {a} accesses host key {key} strictly "
                            f"after drop {d} released every copy "
                            f"(drop-before-last-reader)",
                            witness=_witness_order(mg, {d}, {a}),
                            confirmable=loud_a)
                    else:
                        kind = STALE_TWIN if loud_a else USE_AFTER_DROP
                        self.emit(
                            kind, (d, a),
                            f"vertex {a} ({mg.vertices[a].op.value}) of "
                            f"host key {key} is unordered with drop {d}: "
                            f"its read-through races the twin's deletion",
                            witness=_witness_order(mg, {d}, {a}),
                            confirmable=loud_a)
            ss = spills.get(key, [])
            for i, s1 in enumerate(ss):
                for s2 in ss[i + 1:]:
                    if not (before(s1, s2) or before(s2, s1)):
                        self.emit(
                            DOUBLE_SPILL, (s1, s2),
                            f"spills {s1} and {s2} of host key {key} are "
                            f"unordered: the per-key create/free total "
                            f"order the budget replay relies on breaks",
                            witness=_witness_order(mg, {s2}, {s1}),
                            confirmable=False)
            if self.full():
                return

    # ---- pass 3: worst-case budgets ---------------------------------
    def pass_budgets(self) -> tuple[int, int, int, int]:
        mg, before = self.mg, self.mg.happens_before
        host, disk = recover_residencies(mg)

        def bound(res: list[Residency], cap: int | None, tier: str,
                  kind: str) -> int:
            if not res:
                return 0
            prec = [(i, j)
                    for i, ri in enumerate(res)
                    for j, rj in enumerate(res)
                    if i != j and ri.release is not None
                    and before(ri.release, rj.admit)]
            weights = [r.units for r in res]
            if cap is None:
                worst, _ = max_weight_antichain(weights, prec)
                return worst
            worst, members = max_weight_antichain(weights, prec)
            if worst > cap:
                admits = [res[i].admit for i in members]
                bitpos, desc = mg.reachability()
                abits = [bitpos[a] for a in admits]
                down = {m for m in mg.vertices
                        if m in admits
                        or any((desc[m] >> b) & 1 for b in abits)}
                order = mg.topo_order(
                    key=lambda m: (0 if m in down else 1,
                                   mg.vertices[m].seq, m))
                self.emit(
                    kind, tuple(admits),
                    f"{tier}-tier budget unsound: residencies admitted by "
                    f"{admits} can be simultaneously live "
                    f"({worst} units > capacity {cap})",
                    witness=tuple(order), witness_kind="occupancy",
                    tier=tier, prefix=len(down), expect_units=worst,
                    capacity=cap)
            return worst
        worst_host = bound(host, self.host_capacity, "host", HOST_BUDGET)
        worst_disk = bound(disk, self.disk_capacity, "disk", DISK_BUDGET)
        return worst_host, worst_disk, len(host), len(disk)


def certify(mg: MemGraph, *, host_capacity: int | None = None,
            disk_capacity: int | None = None,
            max_hazards: int = 64) -> Certificate:
    """Certify a built MEMGRAPH: prove (or refute, with witness
    schedules) that every dependency-respecting execution order is
    race-free, tier-coherent, and within the host/disk budgets."""
    cert = Certificate(ok=True, hazards=[], n_vertices=len(mg),
                       host_capacity=host_capacity,
                       disk_capacity=disk_capacity)
    try:
        mg.topo_order()
    except RaceError:
        cert.ok = False
        cert.hazards.append(PlanHazard(
            STRUCTURE, (), "MEMGRAPH contains a cycle", confirmable=False))
        return cert
    for m, v in mg.vertices.items():
        if v.op in STORE_OPS and v.loc is not None:
            cert.hazards.append(PlanHazard(
                STRUCTURE, (m,), f"{v.op.value} {m} has a device loc",
                confirmable=False))
        elif v.op not in STORE_OPS and v.loc is None:
            cert.hazards.append(PlanHazard(
                STRUCTURE, (m,), f"{v.op.value} {m} has no device loc",
                confirmable=False))
    c = _Cert(mg, host_capacity, disk_capacity, max_hazards)
    c.hazards = cert.hazards
    c.pass_device_races()
    c.pass_tier_lifetimes()
    (cert.worst_host_units, cert.worst_disk_units,
     cert.n_host_residencies, cert.n_disk_blobs) = c.pass_budgets()
    cert.n_pairs_checked = c.n_pairs
    cert.ok = not cert.hazards
    return cert


# --------------------------------------------------------------------------
# CLI: certify the seeded example-plan corpus (CI gate)
# --------------------------------------------------------------------------
def _corpus_taskgraph(rng: Any) -> Any:
    """The fuzz suites' taskgraph distribution (tests/helpers.py),
    restated here so the CLI is self-contained for CI."""
    from .taskgraph import TaskGraph
    shape = (4, 4)
    unary = ["relu", "transpose", "copy"]
    binary = ["add", "mul", "matmul", "matmul_t"]
    n_dev = rng.randint(1, 3)
    tg = TaskGraph()
    tids = []
    for i in range(rng.randint(1, 3)):
        for d in range(n_dev):
            tids.append(tg.add_input(d, shape, name=f"in{d}.{i}"))
    for i in range(rng.randint(6, 18)):
        d = rng.randrange(n_dev)
        if rng.random() < 0.5:
            tids.append(tg.add_compute(d, (rng.choice(tids),), shape,
                                       op=rng.choice(unary), name=f"v{i}"))
        else:
            tids.append(tg.add_compute(
                d, (rng.choice(tids), rng.choice(tids)), shape,
                op=rng.choice(binary), name=f"v{i}"))
        if i % 7 == 6 and len(tids) >= 4:
            parts = rng.sample(tids, k=min(len(tids), rng.randint(2, 4)))
            tids.append(tg.add_reduce(d, parts, streaming=True,
                                      name=f"r{i}"))
    return tg


def main(argv: Sequence[str] | None = None) -> int:
    import random as pyrandom

    from .build import BuildConfig, MemgraphOOM, build_memgraph

    p = argparse.ArgumentParser(
        prog="python -m repro.core.analyze",
        description="Certify the seeded example-plan corpus: every "
                    "buildable plan must prove clean for all execution "
                    "orders (DESIGN.md §13).")
    p.add_argument("--seeds", type=int, default=24,
                   help="corpus size (default 24)")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="print one summary line per plan")
    args = p.parse_args(argv)

    host_caps = (None, 1, 2, 3)
    disk_caps = (None, 0, 2, 4, 50)
    n_clean = n_oom = 0
    failed = 0
    for seed in range(args.seeds):
        rng = pyrandom.Random(1000 + seed)
        tg = _corpus_taskgraph(rng)
        host_cap = rng.choice(host_caps)
        disk_cap = rng.choice(disk_caps) if host_cap is not None else None
        cfg = BuildConfig(capacity=3, host_capacity=host_cap,
                          disk_capacity=disk_cap, rng_seed=seed,
                          size_fn=lambda v: 1)
        try:
            res = build_memgraph(tg, cfg)
        except MemgraphOOM:
            n_oom += 1
            if args.verbose:
                print(f"seed {seed}: rejected at compile time (OOM)")
            continue
        cert = certify(res.memgraph, host_capacity=host_cap,
                       disk_capacity=disk_cap)
        prof = res.memgraph.host_tier_profile()
        if cert.ok and cert.worst_host_units < prof["peak_units"]:
            cert.ok = False            # the bound must dominate the replay
            cert.hazards.append(PlanHazard(
                STRUCTURE, (), "antichain bound below replayed peak "
                f"({cert.worst_host_units} < {prof['peak_units']})",
                confirmable=False))
        if cert.ok:
            n_clean += 1
            if args.verbose:
                print(f"seed {seed}: clean "
                      f"(host≤{cert.worst_host_units}"
                      f"/{host_cap if host_cap is not None else '∞'}, "
                      f"disk≤{cert.worst_disk_units}"
                      f"/{disk_cap if disk_cap is not None else '∞'})")
        else:
            failed += 1
            print(f"seed {seed}: FAILED certification")
            print(cert.summary())
    print(f"corpus: {n_clean} plans certified clean, {n_oom} rejected at "
          f"compile time, {failed} failed")
    return 1 if failed else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
