"""MEMGRAPH compiler statistics: build throughput, dependency counts,
offload traffic as memory shrinks (the paper's §6 'as few dependencies as
possible' objective, quantified)."""
from __future__ import annotations

import time

from repro.configs import get_arch
from repro.core import BuildConfig, build_memgraph
from repro.core.trace import TraceConfig, trace_prefill

from .common import P100_SERVER, emit


def run(quick=False) -> list[dict]:
    cfg = get_arch("llama-7b")
    tr = trace_prefill(cfg, seq_len=1024, n_layers=4,
                       trace=TraceConfig(n_devices=4, head_group=8,
                                         q_block=512, mlp_slices=2,
                                         dtype="float16"))
    n = len(tr.tg)
    rows = []
    fracs = (1.0, 0.25) if quick else (1.0, 0.5, 0.25, 0.15)
    # total bytes of all tensors on device 0 as the reference budget
    total = sum(v.out.nbytes for v in tr.tg.vertices.values()
                if v.device == 0)
    for frac in fracs:
        t0 = time.time()
        res = build_memgraph(tr.tg, BuildConfig(capacity=int(total * frac)))
        dt = time.time() - t0
        s = res.memgraph.stats()
        rows.append(dict(frac=frac, verts=s["n_vertices"],
                         mem_deps=s["mem_deps"],
                         superfluous=s["superfluous_mem_deps"],
                         offload_mb=s["offload_bytes"] / 2**20,
                         reload_mb=s["reload_bytes"] / 2**20,
                         build_s=dt, verts_per_s=n / dt))
        emit(f"memgraph_build/frac{frac:g}", dt / n * 1e6,
             f"verts={s['n_vertices']};mem_deps={s['mem_deps']};"
             f"reload_mb={s['reload_bytes']/2**20:.0f}")
    return rows


if __name__ == "__main__":
    run()
