"""The plan certifier (DESIGN.md §13): static proofs that every legal
execution order of a built MEMGRAPH is safe, refuted — when they fail —
by witness schedules the differential harness replays dynamically.

Three families of tests:

* **clean side** — every buildable plan certifies clean, the certifier's
  worst-case occupancy bounds dominate the compile-time replay peaks, and
  the ``BuildConfig.certify`` / runtime-reraise wiring works;
* **hazard side** — seeded hazards (a deleted safe-overwrite edge, a
  forged drop vertex, a tightened budget) are always flagged, and every
  confirmable finding's witness schedule really manifests when replayed
  through the harness executors (``helpers.confirm_hazard``);
* **infrastructure** — ``remove_vertex``/``remove_dep`` detach both edge
  maps and invalidate the memoized reachability (the satellite fix), and
  the builder's dynamic residency log agrees exactly with the certifier's
  static interval recovery.
"""
import os
import random as pyrandom

import numpy as np
import pytest

from repro.core import (BuildConfig, Certificate, MemgraphOOM,
                        PlanCertificationError, build_memgraph, certify)
from repro.core.analyze import (DEVICE_RACE, DISK_BUDGET, HOST_BUDGET,
                                STALE_TWIN, TIER_BEFORE_CREATE,
                                USE_AFTER_DROP, USE_AFTER_OVERWRITE,
                                recover_residencies, replay_occupancy)
from repro.core.memgraph import DepKind, Loc, MemGraph, MemOp, RaceError
from repro.core.runtime import eval_taskgraph, run_in_order

from helpers import (confirm_hazard, fig3_taskgraph, graph_inputs,
                     random_taskgraph)

UNITS = dict(size_fn=lambda v: 1)


def _build(tg, **kw):
    kw.setdefault("capacity", 3)
    return build_memgraph(tg, BuildConfig(**kw, **UNITS))


def _spill_plan():
    """The paper's running example squeezed to 1 host unit: a plan with
    real OFFLOAD/RELOAD traffic and disk-tier SPILL/LOAD vertices."""
    tg = fig3_taskgraph()
    return tg, _build(tg, host_capacity=1)


# ------------------------------------------------------------ clean side
def test_built_plans_certify_clean():
    """No plan the compiler emits may fail certification, and the
    all-orders occupancy bounds must dominate the single-order replay."""
    n = 0
    for seed in range(10):
        tg = random_taskgraph(pyrandom.Random(1000 + seed))
        try:
            res = _build(tg, host_capacity=1 + seed % 3, rng_seed=seed)
        except MemgraphOOM:
            continue
        cert = certify(res.memgraph, host_capacity=1 + seed % 3)
        assert cert.ok, cert.summary()
        prof = res.memgraph.host_tier_profile()
        assert cert.worst_host_units >= prof["peak_units"]
        assert cert.worst_disk_units >= prof["peak_disk_units"]
        n += 1
    assert n >= 5


def test_build_certify_flag_attaches_certificate():
    tg = fig3_taskgraph()
    res = build_memgraph(tg, BuildConfig(capacity=3, host_capacity=1,
                                         certify=True, **UNITS))
    assert res.certificate is not None and res.certificate.ok
    assert "CLEAN" in res.certificate.summary()
    # without the flag the field stays None (certification is opt-in)
    assert _build(tg, host_capacity=1).certificate is None


def test_certified_clean_reraise_is_loud():
    """The runtime debug hook: a RaceError out of a certified-clean plan
    is a certifier/runtime bug and must say so."""
    from types import SimpleNamespace

    from repro.core.runtime import _certified_reraise
    ok = SimpleNamespace(certificate=Certificate(
        ok=True, hazards=[], n_vertices=0))
    with pytest.raises(RaceError, match="certified clean"):
        _certified_reraise(ok, RaceError("boom"))
    plain = SimpleNamespace(certificate=None)
    with pytest.raises(RaceError) as ei:
        _certified_reraise(plain, RaceError("boom"))
    assert "certified" not in str(ei.value)


def test_cli_corpus_gate():
    """The CI gate: the seeded example-plan corpus certifies clean."""
    from repro.core.analyze import main
    assert main(["--seeds", "8"]) == 0


# ----------------------------------------------------------- hazard side
def test_deleted_safe_overwrite_edge_is_flagged_with_witness():
    """Pass 1: retract one safe-overwrite MEM edge from a spill plan and
    the certifier must name the race — and its witness schedule must
    actually corrupt bytes (or crash) when the harness replays it."""
    tg, res = _spill_plan()
    mg = res.memgraph
    mem_edges = [(u, v) for u in mg.vertices
                 for v, k in mg.succs[u].items() if k == DepKind.MEM]
    hazard_kinds = {DEVICE_RACE, USE_AFTER_OVERWRITE, USE_AFTER_DROP,
                    STALE_TWIN, TIER_BEFORE_CREATE, HOST_BUDGET,
                    DISK_BUDGET}
    n_flagged = n_confirmed = 0
    for u, v in mem_edges:
        mg.remove_dep(u, v)
        cert = certify(mg, host_capacity=1)
        if not cert.ok:
            # a retracted ordering edge shows up either as a race or —
            # when it ordered a spill before the next tenant — as a
            # worst-case budget violation
            assert any(h.kind in hazard_kinds for h in cert.hazards), \
                cert.summary()
            n_flagged += 1
            for h in cert.hazards:
                if not h.confirmable:
                    continue
                try:
                    confirm_hazard(tg, res, h)
                    n_confirmed += 1
                except AssertionError:
                    continue      # statically real, value-coincident
                break
        mg.add_dep(u, v, DepKind.MEM)
    assert n_flagged >= 3, "deleting MEM edges never broke certification"
    assert n_confirmed >= 1, "no witness schedule manifested dynamically"


def test_forged_drop_is_flagged_as_stale_twin():
    """Pass 2: forge a drop vertex that races a reload's read-through —
    the injectable stale-twin hazard. The witness replay must crash or
    diverge: the drop deletes every copy the reload was counting on."""
    tg, res = _spill_plan()
    mg = res.memgraph
    # a host key some RELOAD actually reads back
    reload_keys = {v.operands[0] for v in mg.vertices.values()
                   if v.op == MemOp.RELOAD and v.operands}
    assert reload_keys, "spill plan has no reloads — generator regressed"
    key = sorted(reload_keys)[0]
    dmid = mg.add_vertex(MemOp.SPILL, mg.vertices[key].device,
                         src_tid=mg.vertices[key].src_tid, loc=None,
                         size=0, nbytes=0, operands=[key],
                         params={"drop": True}, tier="disk",
                         name="forged-drop")
    mg.vertices[dmid].seq = max(v.seq for v in mg.vertices.values()) + 1
    mg.add_dep(key, dmid, DepKind.DATA)   # created, but readers unordered
    cert = certify(mg, host_capacity=1)
    assert not cert.ok
    twins = [h for h in cert.hazards
             if h.kind in (STALE_TWIN, USE_AFTER_DROP) and dmid in h.vertices]
    assert twins, cert.summary()
    loud = [h for h in twins if h.confirmable]
    assert loud, "a raced reload must be replay-falsifiable"
    how = confirm_hazard(tg, res, loud[0])
    assert how.startswith(("raised", "diverged"))


def test_budget_hazards_carry_occupancy_witnesses():
    """Pass 3: one unit below the certified worst case, the certifier
    must emit a budget hazard whose witness order really drives the tier
    above the capacity — confirmed by the occupancy replay, which is
    runtime-faithful (the stores do not enforce budgets themselves)."""
    tg, res = _spill_plan()
    mg = res.memgraph
    base = certify(mg)
    assert base.ok and base.worst_host_units > 0
    cert = certify(mg, host_capacity=base.worst_host_units - 1)
    hosts = [h for h in cert.hazards if h.kind == HOST_BUDGET]
    assert hosts and hosts[0].expect_units == base.worst_host_units
    assert "occupancy" in confirm_hazard(tg, res, hosts[0])

    if base.worst_disk_units > 0:
        cert = certify(mg, disk_capacity=base.worst_disk_units - 1)
        disks = [h for h in cert.hazards if h.kind == DISK_BUDGET]
        assert disks, cert.summary()
        assert "occupancy" in confirm_hazard(tg, res, disks[0])


def test_certify_on_build_raises_on_seeded_hazard():
    """End to end: a plan mutilated before certification fails loudly
    with the certificate attached to the exception."""
    tg, res = _spill_plan()
    mg = res.memgraph
    mem_edges = [(u, v) for u in mg.vertices
                 for v, k in mg.succs[u].items() if k == DepKind.MEM]
    for u, v in mem_edges:
        mg.remove_dep(u, v)
        cert = certify(mg, host_capacity=1)
        if not cert.ok:
            with pytest.raises(PlanCertificationError) as ei:
                raise PlanCertificationError(cert)
            assert not ei.value.certificate.ok
            return
        mg.add_dep(u, v, DepKind.MEM)
    pytest.fail("no MEM edge was load-bearing")


# -------------------------------------------------------- infrastructure
def test_remove_vertex_detaches_both_edge_maps_and_reachability():
    """The satellite fix: removing a wired vertex must drop its reverse
    edges everywhere and invalidate the memoized reachability bitsets —
    previously the dependent edges and the stale cache survived."""
    mg = MemGraph()
    a = mg.add_vertex(MemOp.INPUT, 0, loc=Loc(0, 0, 4), size=1)
    c = mg.add_vertex(MemOp.INPUT, 0, loc=Loc(0, 8, 4), size=1)
    assert not mg.happens_before(a, c)          # memoize the reachability
    w = mg.add_vertex(MemOp.COMPUTE, 0, loc=Loc(0, 4, 4), size=1,
                      operands=[a])
    mg.add_dep(a, w, DepKind.DATA)
    mg.add_dep(w, c, DepKind.MEM)
    assert mg.happens_before(a, c)              # a -> w -> c, cache rebuilt
    mg.remove_vertex(w)
    assert w not in mg.vertices
    assert w not in mg.preds and w not in mg.succs
    assert all(w not in s for s in mg.succs.values())
    assert all(w not in p for p in mg.preds.values())
    assert not mg.happens_before(a, c)          # stale cache would say True
    mg.validate(check_races=True)               # graph stays self-consistent


def test_remove_vertex_then_revalidate_full_plan():
    """Plan surgery on a real compiled plan: retracting a leaf vertex
    leaves a graph that still validates and certifies."""
    tg, res = _spill_plan()
    mg = res.memgraph
    leaf = next(m for m in mg.topo_order()[::-1] if not mg.succs[m]
                and mg.vertices[m].op == MemOp.SPILL)
    mg.remove_vertex(leaf)
    mg.validate(check_races=True)
    assert leaf not in mg.vertices


def test_residency_log_matches_static_recovery():
    """The builder's dynamic residency log (policies.py) and the
    certifier's static interval recovery must agree exactly on the
    bounded host tier's (key, release) tenancies."""
    n = 0
    for seed in range(8):
        tg = random_taskgraph(pyrandom.Random(1000 + seed))
        try:
            res = _build(tg, host_capacity=2, rng_seed=seed)
        except MemgraphOOM:
            continue
        host, _ = recover_residencies(res.memgraph)
        logged = sorted((e[0], e[2]) for e in res.host_residencies)
        recovered = sorted((r.key, r.release) for r in host)
        assert logged == recovered
        n += 1
    assert n >= 4


def test_replay_occupancy_matches_profile_on_fixed_order():
    """On the compile-time seq order the witness replay and the plan's
    own profile must see the same host peak."""
    _, res = _spill_plan()
    mg = res.memgraph
    order = mg.topo_order(key=lambda m: (mg.vertices[m].seq, m))
    occ = replay_occupancy(mg, order, tier="host")
    assert max(occ) == mg.host_tier_profile()["peak_units"]


# ------------------------------------------------------------- slow lane
@pytest.mark.slow
def test_property_certified_clean_plans_never_fail_fuzzing():
    """Hypothesis lane: a clean certificate means every sampled legal
    order is byte-exact; deleting a random MEM edge either leaves the
    certificate clean (and the orders stay byte-exact — the edge was
    redundant) or is flagged, with any race witness replayed."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    from helpers import taskgraphs

    max_examples = int(os.environ.get("FUZZ_EXAMPLES", "25"))

    @settings(max_examples=max_examples, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(tg=taskgraphs(), seed=st.integers(0, 2**16),
           host_cap=st.sampled_from((1, 2, 3)))
    def inner(tg, seed, host_cap):
        try:
            res = build_memgraph(tg, BuildConfig(
                capacity=3, host_capacity=host_cap, rng_seed=seed, **UNITS))
        except MemgraphOOM:
            return
        mg = res.memgraph
        cert = certify(mg, host_capacity=host_cap)
        assert cert.ok, cert.summary()
        inputs = graph_inputs(tg, seed)
        ref = eval_taskgraph(tg, inputs)
        rng = pyrandom.Random(seed)

        def exact_under_random_orders():
            for _ in range(3):
                order = mg.topo_order(key=lambda m: rng.random())
                out = run_in_order(tg, res, inputs, order)
                for k in ref:
                    np.testing.assert_array_equal(out[k], ref[k])

        exact_under_random_orders()
        mem_edges = [(u, v) for u in mg.vertices
                     for v, k in mg.succs[u].items() if k == DepKind.MEM]
        if not mem_edges:
            return
        u, v = rng.choice(mem_edges)
        mg.remove_dep(u, v)
        try:
            cert2 = certify(mg, host_capacity=host_cap)
            if cert2.ok:
                exact_under_random_orders()   # the edge was redundant
            else:
                for h in cert2.hazards:
                    if h.confirmable and h.witness_kind == "race":
                        try:
                            confirm_hazard(tg, res, h, seed=seed)
                        except AssertionError:
                            pass              # value-coincident clobber
                        break
        finally:
            mg.add_dep(u, v, DepKind.MEM)

    inner()
