"""Minimal pytree optimizers (AdamW, Lion) — f32 moments, param-dtype
updates, pjit-friendly (states are plain pytrees that inherit the param
sharding rules)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params: Any) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(self, grads: Any, state: dict, params: Any) -> tuple[Any, dict]:
        c = state["count"] + 1
        b1c = 1.0 - self.b1 ** c.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = self.b1 * m + (1 - self.b1) * g
            v2 = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m2 / b1c
            vh = v2 / b2c
            step = mh / (jnp.sqrt(vh) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return (-self.lr * step).astype(p.dtype), m2, v2

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        new_state = {"m": tdef.unflatten([o[1] for o in out]),
                     "v": tdef.unflatten([o[2] for o in out]),
                     "count": c}
        return updates, new_state


@dataclasses.dataclass(frozen=True)
class Lion:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.99
    weight_decay: float = 0.1

    def init(self, params: Any) -> dict:
        return {"m": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32)}

    def update(self, grads: Any, state: dict, params: Any) -> tuple[Any, dict]:
        def upd(g, m, p):
            g = g.astype(jnp.float32)
            u = jnp.sign(self.b1 * m + (1 - self.b1) * g) \
                + self.weight_decay * p.astype(jnp.float32)
            m2 = self.b2 * m + (1 - self.b2) * g
            return (-self.lr * u).astype(p.dtype), m2
        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (tdef.unflatten([o[0] for o in out]),
                {"m": tdef.unflatten([o[1] for o in out]),
                 "count": state["count"] + 1})


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)
