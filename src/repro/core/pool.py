"""Pool-level host-RAM arbitration: one pinned budget, many consumers.

TURNIP's premise — "inexpensive CPU RAM is used to increase the amount of
storage available" — made every consumer treat host RAM as *its* budget:
the compiler charged ``BuildConfig.host_capacity``, the serving engine
charged ``ServeConfig.host_kv_bytes``, and nothing arbitrated between them
even though ``Engine(host=...)`` can share a runtime's store. NEO
(PAPERS.md) shows why that matters: online serving hits the host ceiling
first, exactly when offload traffic from a co-resident MEMGRAPH plan is
also peaking. This module owns the *pool*:

* :class:`HostPool` — the single pinned budget. Consumers hold named
  :class:`Lease`\\ s (``memgraph``, ``kv``, ``prefetch``, ...) and a
  pluggable :class:`ArbitrationPolicy` splits the capacity between them.
* :class:`Lease` — one consumer's share. Two charge disciplines, one per
  consumer style (documented per call-site; never mix them on one lease):

  - **reserving** (the serving engine): :meth:`Lease.try_charge` *before*
    moving bytes; a refusal defers the transfer (and records pressure so
    the consumer's own spill stream makes room). Bytes never land
    uncharged, so the pool bound holds by construction.
  - **occupancy** (a plan-driven :class:`~repro.core.stores.TieredStore`):
    the store mirrors its ``resident_bytes`` deltas via
    :meth:`Lease.account`. The compiled plan's feasibility check already
    bounded the peak by the lease's floor (``min_bytes``), so accounting
    is observational — the plan cannot overflow a floor it compiled under.

* Arbitration policies (:func:`get_arbitration_policy`):

  - ``static`` — floors, then the remainder split by ``weight``; grants
    never react to load (the predictable baseline);
  - ``demand`` — floors, then the remainder follows current demand
    (``used`` + the latest request), so an idle consumer's slack flows to
    the busy one;
  - ``priority`` — strict ranking: higher-priority leases are granted
    their demand first (resumable KV blocks outrank far-future MEMGRAPH
    reloads, which are cheap to re-stage), lower ones are squeezed toward
    their floors.

* **Revocation.** When a rebalance shrinks a lease's grant below its
  ``used`` bytes, the pool fires the lease's ``on_revoke(deficit)``
  callback — *outside* the pool lock, and the callback must be a cheap
  pressure signal (set a flag, bump a counter), never a blocking inline
  write: the consumer drains the deficit through its own LRU spill path
  on its own disk stream. Floors are inviolable — ``min_bytes`` is the
  share a consumer compiled or sized against, and no policy may revoke
  below it — so revocation changes *timing* (when spills happen), never
  results.

Counters: every lease tracks ``used``/``peak``/``refusals``/
``revoked_bytes``; the pool tracks ``used_bytes``/``peak_bytes``/
``revocations``. The shared-pool benchmark asserts the headline invariant
on these: combined occupancy never exceeds the pool budget, and outputs
are byte-identical to isolated per-consumer pools.
"""
from __future__ import annotations

import contextlib
import threading

from . import lockcheck
from .liveness import LivenessModelError
from typing import Callable, Iterator

__all__ = ["HostPool", "Lease", "LeaseRefusal", "ArbitrationPolicy",
           "ARBITRATION_POLICY_NAMES", "get_arbitration_policy"]

# thread-local marker: the lease whose revocation drain the current thread
# is running (HostPool.draining; liveness assumption A2, DESIGN.md §14)
_drain_tls = threading.local()


class LeaseRefusal(RuntimeError):
    """A mandatory charge could not fit the lease's arbitrated share."""


class Lease:
    """One consumer's share of a :class:`HostPool`.

    All mutation goes through the owning pool (single lock, single
    source of truth); the attributes here are plain reads — fine for
    scheduling heuristics and stats, exact under the pool lock."""

    def __init__(self, pool: "HostPool", name: str, *, min_bytes: int = 0,
                 weight: float = 1.0, priority: int = 0,
                 on_revoke: Callable[[int], None] | None = None,
                 drains_via: tuple[str, ...] = ()) -> None:
        self.pool = pool
        self.name = name
        self.min_bytes = int(min_bytes)
        self.weight = float(weight)
        self.priority = int(priority)
        self.on_revoke = on_revoke
        # leases this one's revocation drain may charge while draining
        # (liveness assumption A2): a drain that blocks on an undeclared
        # lease is a blocking edge outside the static model
        self.drains_via: tuple[str, ...] = tuple(drains_via)
        # guaranteed share the liveness certifier proved the plan's
        # occupancy stays within (assumption A1); None = not certified
        self.certified_floor: int | None = None
        self.grant = 0            # current arbitrated share (bytes)
        self.used = 0             # bytes charged / resident against us
        self.peak = 0             # high-water mark of `used`
        self.demand = 0           # current want: used + latest request
        self.refusals = 0         # try_charge calls that did not fit
        self.pressure = 0         # deficit of deferred urgent charges
        self.revoked_bytes = 0    # cumulative grant shrinkage below `used`
        self.closed = False

    # thin forwarding surface: consumers hold the lease, not the pool
    def try_charge(self, n: int, *, urgent: bool = True) -> bool:
        return self.pool.try_charge(self, n, urgent=urgent)

    def charge(self, n: int) -> None:
        if not self.try_charge(n):
            raise LeaseRefusal(
                f"lease {self.name!r}: {n} B does not fit share "
                f"{self.grant} B ({self.used} B used, pool "
                f"{self.pool.capacity} B)")

    def release(self, n: int) -> None:
        self.pool.release(self, n)

    def account(self, delta: int) -> None:
        self.pool.account(self, delta)

    @property
    def headroom(self) -> int:
        """Free bytes under the current grant (scheduling heuristic: the
        serving prefetcher sizes its opportunistic staging by this)."""
        return max(0, self.grant - self.used)

    @property
    def overage(self) -> int:
        """Bytes held past the current grant (after a revocation): what
        the consumer's own spill path should drain."""
        return max(0, self.used - self.grant)

    def close(self) -> None:
        self.pool.close_lease(self)


# --------------------------------------------------------------------------
# arbitration policies
# --------------------------------------------------------------------------
class ArbitrationPolicy:
    """Split the pool capacity into per-lease grants.

    ``split`` runs under the pool lock and must be pure: floors
    (``min_bytes``) are already guaranteed feasible by
    :meth:`HostPool.lease`; the returned grants must sum to at most
    ``capacity`` and honor every floor."""

    name = "base"

    def split(self, capacity: int, leases: list[Lease]) -> dict[str, int]:
        raise NotImplementedError

    @staticmethod
    def _floors(capacity: int, leases: list[Lease]) -> tuple[dict[str, int], int]:
        grants = {l.name: l.min_bytes for l in leases}
        return grants, capacity - sum(grants.values())


class StaticSplitPolicy(ArbitrationPolicy):
    """Floors, then the remainder by ``weight`` — load-independent."""

    name = "static"

    def split(self, capacity: int, leases: list[Lease]) -> dict[str, int]:
        grants, rest = self._floors(capacity, leases)
        total_w = sum(l.weight for l in leases) or 1.0
        for l in leases:
            grants[l.name] += int(rest * l.weight / total_w)
        return grants


class DemandProportionalPolicy(ArbitrationPolicy):
    """Floors, then the remainder follows current demand above the floor;
    with no demand anywhere, fall back to the static weights."""

    name = "demand"

    def split(self, capacity: int, leases: list[Lease]) -> dict[str, int]:
        grants, rest = self._floors(capacity, leases)
        wants = {l.name: max(max(l.demand, l.used) - l.min_bytes, 0)
                 for l in leases}
        total = sum(wants.values())
        if total <= 0:
            total_w = sum(l.weight for l in leases) or 1.0
            for l in leases:
                grants[l.name] += int(rest * l.weight / total_w)
            return grants
        for l in leases:
            grants[l.name] += min(int(rest * wants[l.name] / total),
                                  wants[l.name])
        # demand under-consumes the pool when wants < rest: top the
        # leftovers back up by weight so capacity is never stranded
        leftover = capacity - sum(grants.values())
        if leftover > 0:
            total_w = sum(l.weight for l in leases) or 1.0
            for l in leases:
                grants[l.name] += int(leftover * l.weight / total_w)
        return grants


class PriorityPolicy(ArbitrationPolicy):
    """Strict ranking: grant each lease its demand in priority order
    (ties broken by weight, then name, for determinism); lower-priority
    leases are squeezed toward their floors when a higher one's demand
    grows — resumable KV blocks outrank far-future MEMGRAPH reloads."""

    name = "priority"

    def split(self, capacity: int, leases: list[Lease]) -> dict[str, int]:
        grants, rest = self._floors(capacity, leases)
        order = sorted(leases, key=lambda l: (-l.priority, -l.weight, l.name))
        for l in order:
            want = max(max(l.demand, l.used) - l.min_bytes, 0)
            give = min(want, rest)
            grants[l.name] += give
            rest -= give
        if rest > 0 and order:
            grants[order[0].name] += rest     # slack parks on the top rank
        return grants


ARBITRATION_POLICY_NAMES = ("static", "demand", "priority")
_POLICIES = {p.name: p for p in (StaticSplitPolicy, DemandProportionalPolicy,
                                 PriorityPolicy)}


def get_arbitration_policy(policy: str | ArbitrationPolicy) -> ArbitrationPolicy:
    if isinstance(policy, ArbitrationPolicy):
        return policy
    try:
        return _POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown arbitration policy {policy!r}; expected "
                         f"one of {ARBITRATION_POLICY_NAMES}") from None


# --------------------------------------------------------------------------
# the pool
# --------------------------------------------------------------------------
class HostPool:
    """One pinned host-RAM budget arbitrated across named leases.

    The pool lock is a *leaf* lock: consumers call in while holding their
    own locks (store lock, engine lock), and the pool never calls consumer
    code under it — revocation callbacks are collected inside the lock and
    fired after it is released."""

    def __init__(self, capacity: int,
                 policy: str | ArbitrationPolicy = "static") -> None:
        if capacity <= 0:
            raise ValueError("pool capacity must be positive")
        self.capacity = int(capacity)
        self.policy = get_arbitration_policy(policy)
        self._leases: dict[str, Lease] = {}
        self._lock = lockcheck.make_lock("HostPool")
        self.used_bytes = 0
        self.peak_bytes = 0
        self.revocations = 0

    # ------------------------------------------------------------- leases
    def lease(self, name: str, *, min_bytes: int = 0, weight: float = 1.0,
              priority: int = 0,
              on_revoke: Callable[[int], None] | None = None,
              drains_via: tuple[str, ...] = ()) -> Lease:
        """Get-or-create the lease called ``name``. Floors must be jointly
        feasible: the sum of every lease's ``min_bytes`` can never exceed
        the pool — an infeasible floor is refused at lease time, not
        discovered as a silent overcommit under load."""
        with self._lock:
            l = self._leases.get(name)
            if l is not None:
                if on_revoke is not None and l.on_revoke is None:
                    l.on_revoke = on_revoke
                if drains_via and not l.drains_via:
                    l.drains_via = tuple(drains_via)
                return l
            floor_sum = sum(x.min_bytes for x in self._leases.values())
            if floor_sum + min_bytes > self.capacity:
                raise ValueError(
                    f"lease {name!r} floor of {min_bytes} B is infeasible: "
                    f"{floor_sum} B of floors already promised out of "
                    f"{self.capacity} B")
            l = Lease(self, name, min_bytes=min_bytes, weight=weight,
                      priority=priority, on_revoke=on_revoke,
                      drains_via=drains_via)
            self._leases[name] = l
            fire = self._rebalance_locked()
        self._fire(fire)
        return l

    def close_lease(self, l: Lease) -> None:
        """Retire a lease: its bytes must already be drained (or the
        caller accepts losing track of them); its share returns to the
        pool."""
        with self._lock:
            if self._leases.get(l.name) is not l:
                return
            del self._leases[l.name]
            self.used_bytes -= l.used
            l.used = 0
            l.closed = True
            fire = self._rebalance_locked()
        self._fire(fire)

    def leases(self) -> list[Lease]:
        with self._lock:
            return list(self._leases.values())

    @contextlib.contextmanager
    def draining(self, l: Lease) -> Iterator[None]:
        """Mark the current thread as running ``l``'s revocation drain
        (liveness assumption A2, DESIGN.md §14). While active, any
        :meth:`try_charge` against this pool must target ``l`` itself or
        a lease named in ``l.drains_via`` — the edges the static blocking
        model knows about. A charge against any other lease is a blocking
        edge the certifier never saw, so it is reported as certifier
        unsoundness rather than allowed to deadlock silently. Releases
        are always permitted: draining *is* releasing."""
        prev = getattr(_drain_tls, "lease", None)
        _drain_tls.lease = l
        try:
            yield
        finally:
            _drain_tls.lease = prev

    # ------------------------------------------------------------ charges
    def try_charge(self, l: Lease, n: int, *, urgent: bool = True) -> bool:
        """Reserve ``n`` bytes against ``l`` *before* the bytes move.

        Records demand, rebalances (the demand/priority policies may grow
        the grant — possibly revoking someone else's slack), and either
        admits the charge or refuses it. An urgent refusal records the
        deficit as ``pressure`` so the consumer's spill scheduler knows
        how many bytes to free; an opportunistic one (``urgent=False``,
        e.g. predictive prefetch) only counts the refusal."""
        n = int(n)
        if n < 0:
            raise ValueError("charge must be non-negative")
        drain = getattr(_drain_tls, "lease", None)
        if (drain is not None and drain.pool is self
                and l.name != drain.name
                and l.name not in drain.drains_via):
            raise LivenessModelError(
                f"revocation drain of lease {drain.name!r} charged lease "
                f"{l.name!r}, which is not in its declared drains_via "
                f"{drain.drains_via!r}: a blocking edge outside the static "
                f"model — the liveness certifier is unsound for this "
                f"configuration (assumption A2, DESIGN.md §14)")
        with self._lock:
            l.demand = l.used + n
            fire: list[tuple[Callable[[int], None], int]] = []
            if l.used + n > l.grant:
                fire = self._rebalance_locked()
            # the grant admits the charge AND the pool itself has room:
            # a freshly revoked lease still *holds* its overage until its
            # own spill stream drains it, and granting those bytes away
            # before they are physically free would burst the pool bound
            if (l.used + n <= l.grant
                    and self.used_bytes + n <= self.capacity):
                self._apply_locked(l, n)
                l.pressure = 0
                ok = True
            else:
                l.refusals += 1
                if urgent:
                    l.pressure = max(l.pressure, l.used + n - l.grant,
                                     self.used_bytes + n - self.capacity)
                ok = False
        self._fire(fire)
        return ok

    def release(self, l: Lease, n: int) -> None:
        with self._lock:
            self._apply_locked(l, -int(n))
            l.demand = l.used
            fire = self._rebalance_locked()
        self._fire(fire)

    def account(self, l: Lease, delta: int) -> None:
        """Occupancy accounting (the :class:`TieredStore` discipline):
        mirror a resident-bytes delta into the lease unconditionally.
        Growth past the grant is possible only for consumers whose bound
        is enforced elsewhere (a compiled plan's floor); the rebalance
        still runs so other leases see the pressure immediately."""
        with self._lock:
            self._apply_locked(l, int(delta))
            l.demand = l.used
            used, floor = l.used, l.certified_floor
            fire = self._rebalance_locked()
        self._fire(fire)
        if floor is not None and used > floor:
            raise LivenessModelError(
                f"lease {l.name!r} occupancy {used} B exceeded the "
                f"certified guaranteed share of {floor} B the liveness "
                f"proof assumed (assumption A1, DESIGN.md §14): the "
                f"certifier is unsound or the runtime diverged from the "
                f"compiled plan")

    def transfer(self, src: Lease, dst: Lease, n: int) -> None:
        """Move ``n`` charged bytes between leases (no pool-level change):
        e.g. a prefetch-staged KV block becomes a resuming request's
        resident block. Forced — the bytes are already in host RAM, so
        refusing would strand them; ``dst`` may transiently exceed its
        grant and its own spill path drains the overage."""
        with self._lock:
            self._apply_locked(src, -int(n))
            self._apply_locked(dst, int(n))
            src.demand, dst.demand = src.used, dst.used
            fire = self._rebalance_locked()
        self._fire(fire)

    # ------------------------------------------------------------ internals
    def _apply_locked(self, l: Lease, delta: int) -> None:
        l.used += delta
        if l.used < 0:          # release/account drift is a consumer bug;
            l.used = 0          # clamp so one bug cannot corrupt the pool
        l.peak = max(l.peak, l.used)
        self.used_bytes = sum(x.used for x in self._leases.values())
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)

    def _rebalance_locked(self) -> list[tuple[Callable[[int], None], int]]:
        """Recompute grants; returns (callback, deficit) pairs to fire
        *after* the lock is released."""
        leases = list(self._leases.values())
        if not leases:
            return []
        grants = self.policy.split(self.capacity, leases)
        assert sum(grants.values()) <= self.capacity, \
            f"policy {self.policy.name!r} overcommitted the pool"
        fire: list[tuple[Callable[[int], None], int]] = []
        for l in leases:
            g = grants[l.name]
            assert g >= l.min_bytes, \
                f"policy {self.policy.name!r} violated {l.name!r}'s floor"
            shrunk = g < l.grant
            l.grant = g
            deficit = l.used - g
            if shrunk and deficit > 0:
                self.revocations += 1
                l.revoked_bytes += deficit
                if l.on_revoke is not None:
                    fire.append((l.on_revoke, deficit))
        return fire

    @staticmethod
    def _fire(fire: list[tuple[Callable[[int], None], int]]) -> None:
        for cb, deficit in fire:
            cb(deficit)

    @property
    def drained(self) -> bool:
        """True when no lease holds any bytes — the post-teardown invariant
        the fleet chaos harness asserts per surviving replica: a drained
        pool proves every migrated/finished request's reservations were
        released, not leaked."""
        with self._lock:
            return all(l.used == 0 for l in self._leases.values())

    def snapshot(self) -> dict:
        """Counters for benchmarks/monitoring: one dict per lease plus the
        pool totals."""
        with self._lock:
            return {
                "capacity": self.capacity,
                "used_bytes": self.used_bytes,
                "peak_bytes": self.peak_bytes,
                "revocations": self.revocations,
                "leases": {
                    n: {"grant": l.grant, "used": l.used, "peak": l.peak,
                        "min_bytes": l.min_bytes, "refusals": l.refusals,
                        "revoked_bytes": l.revoked_bytes}
                    for n, l in self._leases.items()},
            }
